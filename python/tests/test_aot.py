"""AOT pipeline tests: manifest consistency + artifact lowering contract.

These validate the python side of the Rust<->Python contract without
needing the Rust runtime (the Rust integration tests cover the other
half).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.models import registry, common
from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@needs_artifacts
def test_manifest_covers_registry():
    m = json.load(open(MANIFEST))
    reg = registry()
    for name in reg:
        assert name in m["models"], f"{name} missing from manifest"


@needs_artifacts
def test_manifest_param_counts_match_models():
    m = json.load(open(MANIFEST))
    reg = registry()
    for name, model in reg.items():
        entry = m["models"][name]
        assert entry["param_count"] == model.spec.count()
        assert entry["opt_state_count"] == model.opt.state_count(
            model.spec.count())
        declared = sum(
            int(jnp.prod(jnp.array(p["shape"])))
            for p in entry["param_specs"])
        assert declared == entry["param_count"]


@needs_artifacts
def test_artifact_files_exist_and_are_hlo_text():
    m = json.load(open(MANIFEST))
    for name, entry in m["models"].items():
        for tag, fname in entry["files"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), f"{name}/{tag} missing"
            head = open(path).read(200)
            assert head.startswith("HloModule"), f"{name}/{tag} not HLO text"
            assert "custom-call" not in open(path).read(), (
                f"{name}/{tag} contains a custom-call — Mosaic lowering "
                f"leaked; CPU PJRT cannot run it")


@needs_artifacts
def test_chunk_signature_shapes():
    """The train_chunk entry layout must match the documented contract:
    params, opt, stacked[K,...], shared, q_fwd[K], lr[K], seeds[K], q_bwd."""
    m = json.load(open(MANIFEST))
    k = m["chunk"]
    entry = m["models"]["mlp"]
    path = os.path.join(ART, entry["files"]["train_chunk"])
    header = open(path).read(2000)
    # entry_computation_layout line carries the full signature
    assert f"f32[{entry['param_count']}]" in header
    assert f"f32[{k},32,32]" in header  # stacked x
    assert f"s32[{k},32]" in header     # stacked y
    assert f"s32[{k}]" in header        # seeds


def test_flops_counting_matches_manual_mlp():
    reg = registry()
    mlp = reg["mlp"]

    def probe(params_flat):
        data = {
            "x": jnp.zeros((32, 32), jnp.float32),
            "y": jnp.zeros((32,), jnp.int32),
        }
        p = mlp.spec.unflatten(params_flat)
        return mlp.loss(p, data, 8.0, 8.0, jax.random.PRNGKey(0), True)

    flops = common.count_gemm_flops(
        probe, jax.ShapeDtypeStruct((mlp.spec.count(),), jnp.float32))
    want = 2 * 32 * 32 * 64 + 2 * 32 * 64 * 4
    assert flops["q_gemm"] == want


def test_gnn_agg_flops_counted_separately():
    reg = registry()
    for name in ["gcn_qagg", "gcn_fpagg"]:
        g = reg[name]

        def probe(params_flat, g=g):
            n, d = g.nodes, g.in_dim
            data = {
                "feats": jnp.zeros((n, d), jnp.float32),
                "adj": jnp.zeros((n, n), jnp.float32),
                "labels": jnp.zeros((n,), jnp.int32),
                "mask": jnp.ones((n,), jnp.float32),
            }
            p = g.spec.unflatten(params_flat)
            return g.loss(p, data, 8.0, 8.0, jax.random.PRNGKey(0), True)

        flops = common.count_gemm_flops(
            probe, jax.ShapeDtypeStruct((g.spec.count(),), jnp.float32))
        agg_key = "agg_q_gemm" if g.q_agg else "agg_fp_gemm"
        n = g.nodes
        # 3 layers of n x n @ n x d_out aggregation
        want_agg = 2 * n * n * (64 + 64 + 8)
        assert flops[agg_key] == want_agg, f"{name}: {flops}"
        # transform GEMMs never land in the agg bucket
        assert flops["q_gemm"] > 0


def test_to_hlo_text_smoke():
    text = aot.to_hlo_text(
        lambda x: (x * 2.0,), [jax.ShapeDtypeStruct((4,), jnp.float32)])
    assert text.startswith("HloModule")
    assert "multiply" in text

"""L2 model correctness: shapes, finiteness, and actual learning.

The train-on-tiny-synthetic-data tests are the python-side analog of the
Rust integration tests: each model's train_chunk must reduce its loss on a
fixed batch within a few chunks. These run the *same* jitted callables that
aot.py lowers — if these pass, the artifacts encode a working train loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import registry, common

jax.config.update("jax_platform_name", "cpu")

REG = registry()


def synth_data(model, key, k=None):
    """Random-but-learnable data matching a model's data_inputs."""
    out = []
    for i, (name, shape, dtype, stacked) in enumerate(model.data_inputs):
        kk = jax.random.fold_in(key, i)
        full = (k, *shape) if (stacked and k) else shape
        if dtype == jnp.float32:
            if name == "adj":
                # symmetric normalized adjacency with self loops
                n = shape[0]
                a = (jax.random.uniform(kk, (n, n)) < 0.02).astype(jnp.float32)
                a = jnp.minimum(a + a.T + jnp.eye(n), 1.0)
                d = jnp.sum(a, axis=1, keepdims=True)
                t = a / jnp.sqrt(d) / jnp.sqrt(d.T)
                out.append(jnp.broadcast_to(t, full) if full != shape else t)
            elif name == "mask":
                m = (jax.random.uniform(kk, shape) < 0.5).astype(jnp.float32)
                out.append(jnp.broadcast_to(m, full) if full != shape else m)
            elif name == "y_obj":
                out.append((jax.random.uniform(kk, full) < 0.2).astype(jnp.float32))
            else:
                out.append(jax.random.normal(kk, full))
        else:
            hi = 4
            if name == "x" and model.name.startswith(("lstm", "transformer")):
                hi = 64
            if name == "y":
                if model.name in ("lstm_lm", "transformer_lm"):
                    hi = 64  # token targets
                elif model.name == "transformer_cls":
                    hi = 3  # 3-way entailment labels
            if name == "labels":
                hi = 8
            out.append(jax.random.randint(kk, full, 0, hi, jnp.int32))
    return out


@pytest.mark.parametrize("name", sorted(REG))
def test_init_shapes(name):
    model = REG[name]
    init, _, _ = common.make_step_fns(model, model.opt, 2)
    params, opt_state = init(0)
    assert params.shape == (model.spec.count(),)
    assert opt_state.shape == (model.opt.state_count(model.spec.count()),)
    assert bool(jnp.all(jnp.isfinite(params)))


@pytest.mark.parametrize("name", sorted(REG))
def test_train_chunk_runs_and_is_finite(name):
    model = REG[name]
    k = 2
    init, chunk, _ = common.make_step_fns(model, model.opt, k)
    params, opt_state = init(1)
    key = jax.random.PRNGKey(42)
    stacked = synth_data_stacked(model, key, k)
    shared = synth_data_shared(model, key)
    q_fwd = jnp.full((k,), 8.0)
    lr = jnp.full((k,), 1e-2 if model.opt.name == "sgdm" else 1e-3)
    seeds = jnp.arange(k, dtype=jnp.int32)
    p2, o2, losses, metrics = chunk(
        params, opt_state, *stacked, *shared, q_fwd, lr, seeds, jnp.float32(8.0))
    assert p2.shape == params.shape
    assert losses.shape == (k,) and metrics.shape == (k,)
    assert bool(jnp.all(jnp.isfinite(p2)))
    assert bool(jnp.all(jnp.isfinite(losses)))


def synth_data_stacked(model, key, k):
    vals = synth_data(model, key, k)
    return [v for v, d in zip(vals, model.data_inputs) if d[3]]


def synth_data_shared(model, key):
    vals = synth_data(model, key, None)
    return [v for v, d in zip(vals, model.data_inputs) if not d[3]]


@pytest.mark.parametrize("name", ["mlp", "cnn_tiny", "gcn_qagg", "gcn_fpagg"])
def test_loss_decreases(name):
    """A few chunks on a fixed batch must reduce training loss."""
    model = REG[name]
    k = 4
    init, chunk, _ = common.make_step_fns(model, model.opt, k)
    chunk = jax.jit(chunk)
    params, opt_state = init(3)
    key = jax.random.PRNGKey(7)
    stacked = synth_data_stacked(model, key, k)
    shared = synth_data_shared(model, key)
    q_fwd = jnp.full((k,), 8.0)
    lr = jnp.full((k,), 5e-2 if model.opt.name == "sgdm" else 2e-3)
    seeds = jnp.arange(k, dtype=jnp.int32)

    first = None
    last = None
    for it in range(6):
        params, opt_state, losses, _ = chunk(
            params, opt_state, *stacked, *shared, q_fwd, lr, seeds,
            jnp.float32(8.0))
        if first is None:
            first = float(losses[0])
        last = float(losses[-1])
    assert last < first, f"{name}: loss {first} -> {last} did not decrease"


def test_eval_runs_full_precision():
    model = REG["mlp"]
    init, _, ev = common.make_step_fns(model, model.opt, 2)
    params, _ = init(0)
    key = jax.random.PRNGKey(0)
    data = synth_data(model, key, None)
    loss, metric = ev(params, *data)
    assert np.isfinite(float(loss)) and 0.0 <= float(metric) <= 1.0


def test_q_agg_vs_fp_agg_differ():
    """Q-Agg and FP-Agg must produce different logits at low precision
    (otherwise the Fig 5 ablation would be vacuous) and nearly identical
    ones at high precision."""
    qa, fa = REG["gcn_qagg"], REG["gcn_fpagg"]
    init, _, _ = common.make_step_fns(qa, qa.opt, 1)
    params, _ = init(5)
    key = jax.random.PRNGKey(9)
    feats, adj, labels, mask = synth_data(qa, key, None)
    pq = qa.spec.unflatten(params)
    pf = fa.spec.unflatten(params)
    lo_q = qa.forward(pq, feats, adj, 3.0, 8.0)
    lo_f = fa.forward(pf, feats, adj, 3.0, 8.0)
    assert float(jnp.max(jnp.abs(lo_q - lo_f))) > 1e-4
    hi_q = qa.forward(pq, feats, adj, 24.0, 24.0)
    hi_f = fa.forward(pf, feats, adj, 24.0, 24.0)
    np.testing.assert_allclose(hi_q, hi_f, atol=2e-2)


def test_precision_actually_changes_output():
    """Varying the runtime q input must change model outputs (proves the
    bit-width is live in the compiled graph, not constant-folded)."""
    model = REG["mlp"]
    init, _, _ = common.make_step_fns(model, model.opt, 1)
    params, _ = init(11)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    p = model.spec.unflatten(params)
    f = jax.jit(lambda q: model.forward(p, x, q, 8.0))
    o3, o8 = f(3.0), f(8.0)
    assert float(jnp.max(jnp.abs(o3 - o8))) > 1e-5


def test_flops_counting():
    model = REG["mlp"]
    flops = common.count_gemm_flops(
        lambda x: common.qdot(x, jnp.zeros((32, 64)), 8.0, 8.0),
        jax.ShapeDtypeStruct((16, 32), jnp.float32))
    assert flops["q_gemm"] == 2 * 16 * 32 * 64


def test_grad_clip_bounds_update_norm():
    opt = common.SGDM(momentum=0.0, clip_norm=0.25)
    p = jnp.zeros((10,))
    s = opt.init_state(10)
    g = jnp.full((10,), 100.0)
    p2, _ = opt.update(p, s, g, 1.0)
    assert float(jnp.linalg.norm(p2)) <= 0.25 * (1 + 1e-5)


def test_adam_step_counter_advances():
    opt = common.Adam()
    p = jnp.ones((4,))
    s = opt.init_state(4)
    g = jnp.ones((4,))
    _, s1 = opt.update(p, s, g, 1e-3)
    _, s2 = opt.update(p, s1, g, 1e-3)
    assert float(s1[-1]) == 1.0 and float(s2[-1]) == 2.0

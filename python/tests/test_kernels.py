"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and bit-widths; every case asserts exact agreement
(the kernel and oracle compute the same float expression) plus the analytic
quantization-error properties the paper's cost/fidelity story rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.qmatmul import qmatmul
from compile.kernels.quantize import quantize, quantize_2d, _divisor_block

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=96)
BITS = st.integers(min_value=2, max_value=16)


def rng_array(shape, seed=0, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


# ---------------------------------------------------------------- divisor block

@given(dim=st.integers(1, 4096), pref=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_divisor_block_divides(dim, pref):
    b = _divisor_block(dim, pref)
    assert dim % b == 0
    assert 1 <= b <= dim


def test_divisor_block_prefers_large():
    assert _divisor_block(256, 128) == 128
    assert _divisor_block(48, 32) == 24  # largest divisor <= 32
    assert _divisor_block(7, 128) == 7


# ---------------------------------------------------------------- quantize

@given(m=DIMS, n=DIMS, q=BITS, seed=st.integers(0, 50))
@settings(max_examples=60, deadline=None)
def test_quantize_matches_ref(m, n, q, seed):
    x = rng_array((m, n), seed)
    got = quantize_2d(x, float(q), ref.dynamic_scale(x))
    want = ref.fake_quant(x, float(q))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(q=BITS, seed=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_quantize_nd(q, seed):
    x = rng_array((3, 5, 7), seed)
    got = quantize(x, float(q))
    want = ref.fake_quant(x, float(q))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@given(m=DIMS, n=DIMS, q=BITS, seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_quantize_error_bound(m, n, q, seed):
    """|Q(x) - x| <= s / (2 * levels) whenever |x| <= s (always true for
    dynamic per-tensor scale)."""
    x = rng_array((m, n), seed)
    s = ref.dynamic_scale(x)
    err = jnp.abs(ref.fake_quant(x, float(q), s) - x)
    bound = ref.quant_error_bound(float(q), s)
    # + f32 round-off slop: at high q the analytic bound approaches the
    # arithmetic noise floor of the x/s*lv ... /lv*s chain.
    assert float(jnp.max(err)) <= float(bound) * (1 + 1e-5) + 4e-5 * float(s)


@given(m=DIMS, q=BITS, seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_quantize_idempotent(m, q, seed):
    """Quantizing an already-quantized tensor is a no-op (same scale/bits)."""
    x = rng_array((m, 8), seed)
    s = ref.dynamic_scale(x)
    once = ref.fake_quant(x, float(q), s)
    twice = ref.fake_quant(once, float(q), s)
    np.testing.assert_allclose(once, twice, rtol=0, atol=1e-6)


@given(m=DIMS, seed=st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_quantize_monotone_refinement(m, seed):
    """More bits never increases max quantization error."""
    x = rng_array((m, 16), seed)
    s = ref.dynamic_scale(x)
    errs = [
        float(jnp.max(jnp.abs(ref.fake_quant(x, float(q), s) - x)))
        for q in range(2, 12)
    ]
    for lo, hi in zip(errs, errs[1:]):
        assert hi <= lo * (1 + 1e-5)


def test_quantize_level_count():
    """A q-bit quantizer produces at most 2^q - 1 distinct values."""
    x = jnp.linspace(-1.0, 1.0, 4001)
    for q in [2, 3, 4, 5]:
        vals = np.unique(np.asarray(ref.fake_quant(x, float(q), 1.0)))
        assert len(vals) <= 2 ** q - 1
        # symmetric: -v present for every v
        np.testing.assert_allclose(vals, -vals[::-1], atol=1e-7)


def test_quantize_zero_tensor():
    x = jnp.zeros((4, 4))
    out = quantize(x, 8.0)
    assert bool(jnp.all(out == 0))
    assert bool(jnp.all(jnp.isfinite(out)))


def test_quantize_preserves_sign_and_range():
    x = rng_array((32, 32), 7)
    s = ref.dynamic_scale(x)
    xq = ref.fake_quant(x, 4.0, s)
    assert float(jnp.max(jnp.abs(xq))) <= float(s) * (1 + 1e-6)


# ---------------------------------------------------------------- qmatmul

@given(
    m=DIMS, k=DIMS, n=DIMS,
    qa=BITS, qb=BITS,
    seed=st.integers(0, 50),
)
@settings(max_examples=60, deadline=None)
def test_qmatmul_matches_ref(m, k, n, qa, qb, seed):
    a = rng_array((m, k), seed)
    b = rng_array((k, n), seed + 1)
    got = qmatmul(a, b, float(qa), float(qb))
    want = ref.qmatmul(a, b, float(qa), float(qb))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_qmatmul_multiblock_grid():
    """Shapes large enough to force a >1 grid on every axis."""
    a = rng_array((256, 384), 3)
    b = rng_array((384, 160), 4)
    got = qmatmul(a, b, 5.0, 7.0)
    want = ref.qmatmul(a, b, 5.0, 7.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_qmatmul_high_bits_approaches_exact():
    """At 16+ bits the quantized matmul ~ the exact matmul."""
    a = rng_array((64, 64), 5, scale=1.0)
    b = rng_array((64, 64), 6, scale=1.0)
    got = qmatmul(a, b, 16.0, 16.0)
    exact = a @ b
    np.testing.assert_allclose(got, exact, rtol=0, atol=0.05)


def test_qmatmul_inside_jit_and_hlo():
    """The kernel must lower inside jit to plain HLO (no custom-calls) so
    the CPU PJRT runtime can execute the artifact."""
    from jax._src.lib import xla_client as xc

    f = jax.jit(lambda a, b, q: qmatmul(a, b, q, q))
    lowered = f.lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")),
        use_tuple_args=False,
        return_tuple=True,
    )
    text = comp.as_hlo_text()
    assert "custom-call" not in text, "Mosaic custom-call leaked into HLO"
    # and it actually runs
    a = rng_array((32, 32), 1)
    b = rng_array((32, 32), 2)
    np.testing.assert_allclose(f(a, b, 6.0), ref.qmatmul(a, b, 6.0, 6.0),
                               rtol=1e-5, atol=1e-4)


@given(q=BITS, seed=st.integers(0, 30))
@settings(max_examples=20, deadline=None)
def test_qmatmul_runtime_bits_consistency(q, seed):
    """Same executable, different runtime q: jit once, sweep bits."""
    a = rng_array((40, 24), seed)
    b = rng_array((24, 56), seed + 9)
    f = jax.jit(lambda a, b, qq: qmatmul(a, b, qq, qq))
    got = f(a, b, float(q))
    want = ref.qmatmul(a, b, float(q), float(q))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

"""Differentiable quantized ops: forward values and custom-vjp gradients."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.ops import qdot, quant_ste, bwd_quant

jax.config.update("jax_platform_name", "cpu")

BITS = st.integers(min_value=2, max_value=12)


def rng(shape, seed=0, scale=2.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


# ---------------------------------------------------------------- qdot fwd

@given(q=BITS, seed=st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_qdot_forward_matches_ref(q, seed):
    a = rng((24, 40), seed)
    w = rng((40, 16), seed + 1)
    got = qdot(a, w, float(q), 8.0)
    want = ref.qmatmul(a, w, float(q), float(q))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------- qdot bwd

def test_qdot_grad_shapes_and_finite():
    a = rng((8, 12), 0)
    w = rng((12, 4), 1)

    def loss(a, w):
        return jnp.sum(qdot(a, w, 6.0, 8.0) ** 2)

    da, dw = jax.grad(loss, argnums=(0, 1))(a, w)
    assert da.shape == a.shape and dw.shape == w.shape
    assert bool(jnp.all(jnp.isfinite(da))) and bool(jnp.all(jnp.isfinite(dw)))


def test_qdot_grad_is_ste_quantized_chain():
    """Backward must equal: quantize cotangent at q_bwd, matmul against the
    *quantized* residuals, mask by the STE clip."""
    a = rng((6, 10), 3)
    w = rng((10, 5), 4)
    g = rng((6, 5), 5)
    q_fwd, q_bwd = 4.0, 7.0

    _, vjp = jax.vjp(lambda a, w: qdot(a, w, q_fwd, q_bwd), a, w)
    da, dw = vjp(g)

    gq = ref.fake_quant(g, q_bwd)
    aq = ref.fake_quant(a, q_fwd)
    wq = ref.fake_quant(w, q_fwd)
    want_da = (gq @ wq.T) * ref.ste_mask(a)
    want_dw = (aq.T @ gq) * ref.ste_mask(w)
    np.testing.assert_allclose(da, want_da, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dw, want_dw, rtol=1e-5, atol=1e-5)


def test_qdot_high_bits_grad_close_to_exact():
    """At 16 bits, qdot's gradient ≈ the exact matmul gradient."""
    a = rng((8, 8), 6, scale=1.0)
    w = rng((8, 8), 7, scale=1.0)

    def loss_q(a, w):
        return jnp.sum(qdot(a, w, 16.0, 16.0))

    def loss_x(a, w):
        return jnp.sum(a @ w)

    da_q, dw_q = jax.grad(loss_q, argnums=(0, 1))(a, w)
    da_x, dw_x = jax.grad(loss_x, argnums=(0, 1))(a, w)
    np.testing.assert_allclose(da_q, da_x, atol=0.02)
    np.testing.assert_allclose(dw_q, dw_x, atol=0.02)


def test_qdot_no_grad_wrt_bits():
    """Bit-widths are schedule inputs, not trainable: their grads are None
    (declared nondifferentiable in the vjp)."""
    a = rng((4, 4), 8)
    w = rng((4, 4), 9)
    # grad with respect to a only must not fail even though q is traced
    g = jax.grad(lambda a: jnp.sum(qdot(a, w, 5.0, 8.0)))(a)
    assert g.shape == a.shape


# ---------------------------------------------------------------- quant_ste

@given(q=BITS, seed=st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_quant_ste_forward(q, seed):
    x = rng((16, 16), seed)
    np.testing.assert_allclose(
        quant_ste(x, float(q)), ref.fake_quant(x, float(q)), rtol=0, atol=0
    )


def test_quant_ste_gradient_identity_in_range():
    x = rng((12, 12), 11)
    g = jax.grad(lambda x: jnp.sum(quant_ste(x, 4.0)))(x)
    # dynamic scale = max|x|, so every element is in range: grad == 1
    np.testing.assert_allclose(g, jnp.ones_like(x), rtol=0, atol=0)


# ---------------------------------------------------------------- bwd_quant

def test_bwd_quant_identity_forward():
    x = rng((9, 9), 12)
    np.testing.assert_allclose(bwd_quant(x, 5.0), x, rtol=0, atol=0)


def test_bwd_quant_quantizes_cotangent():
    x = rng((9, 9), 13)
    g_in = rng((9, 9), 14)
    _, vjp = jax.vjp(lambda x: bwd_quant(x, 5.0), x)
    (g_out,) = vjp(g_in)
    np.testing.assert_allclose(g_out, ref.fake_quant(g_in, 5.0), rtol=0, atol=0)

"""AOT pipeline: lower every model to HLO text + write the manifest.

This is the single build-time entry point (`make artifacts`). Python never
runs again after this: the Rust coordinator loads `artifacts/*.hlo.txt`
through PJRT and owns the entire training loop.

Interchange is HLO **text**, not `.serialize()` — jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model (argument order is the contract with rust/src/runtime):

  {name}_init.hlo.txt        (seed i32[]) -> (params f32[P], opt f32[O])
  {name}_train_chunk.hlo.txt (params, opt, stacked data[K,...]..., shared
                              data..., q_fwd f32[K], lr f32[K],
                              seeds i32[K], q_bwd f32[])
                              -> (params, opt, losses f32[K], metrics f32[K])
  {name}_train_step.hlo.txt  same with K=1 (remainder steps)
  {name}_eval.hlo.txt        (params, data...) -> (loss f32[], metric f32[])

The manifest (artifacts/manifest.json) records shapes/dtypes/flops so the
Rust side is fully generic over models.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.models import registry, DEFAULT_CHUNK  # noqa: E402
from compile.models import common  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(fn, arg_specs):
    # keep_unused: some models ignore e.g. the dropout seeds, but the
    # artifact signature is a fixed contract with the Rust runtime.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def dtype_tag(dt):
    return {jnp.float32: "f32", jnp.int32: "i32"}[dt]


def export_model(model, out_dir, chunk=DEFAULT_CHUNK):
    """Lower one model's four artifacts; return its manifest entry."""
    opt = model.opt
    init, train_chunk, eval_step = common.make_step_fns(model, opt, chunk)

    p_count = model.spec.count()
    o_count = opt.state_count(p_count)

    # ---- flops accounting (single forward pass over one training batch)
    def fwd_probe(params_flat):
        data = {}
        for name, shape, dtype, _ in model.data_inputs:
            data[name] = jnp.zeros(shape, dtype)
        p = model.spec.unflatten(params_flat)
        return model.loss(p, data, 8.0, 8.0, jax.random.PRNGKey(0), True)

    flops = common.count_gemm_flops(
        fwd_probe, jax.ShapeDtypeStruct((p_count,), jnp.float32))

    # ---- lower the four entry points
    files = {}

    def emit(tag, fn, specs):
        text = to_hlo_text(fn, specs)
        fname = f"{model.name}_{tag}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[tag] = fname
        return text

    emit("init", init, [jax.ShapeDtypeStruct((), jnp.int32)])
    emit("train_chunk", train_chunk, common.chunk_arg_specs(model, chunk, None))
    emit("train_step", lambda *a: train_chunk_k1(model, opt)(*a),
         common.chunk_arg_specs(model, 1, None))
    emit("eval", eval_step, common.eval_arg_specs(model))

    entry = {
        "name": model.name,
        "files": files,
        "param_count": p_count,
        "opt_state_count": o_count,
        "chunk": chunk,
        "optimizer": opt.name,
        "metric": model.metric,
        "q_gemm_flops_fwd": int(flops.get("q_gemm", 0)),
        "fp_gemm_flops_fwd": int(flops.get("fp_gemm", 0)),
        # GNN aggregation GEMMs: sparse on real graphs, so the BitOps
        # accountant rescales these by the measured graph density.
        "agg_q_gemm_flops_fwd": int(flops.get("agg_q_gemm", 0)),
        "agg_fp_gemm_flops_fwd": int(flops.get("agg_fp_gemm", 0)),
        "data_inputs": [
            {
                "name": name,
                "shape": list(shape),
                "dtype": dtype_tag(dtype),
                "stacked": bool(stacked),
            }
            for name, shape, dtype, stacked in model.data_inputs
        ],
        "param_specs": model.spec.manifest(),
    }
    return entry


def train_chunk_k1(model, opt):
    _, chunk_fn, _ = common.make_step_fns(model, opt, 1)
    return chunk_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma-separated model names, or 'all'")
    ap.add_argument("--chunk", type=int, default=DEFAULT_CHUNK)
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    reg = registry()
    names = list(reg) if args.models == "all" else args.models.split(",")

    entries = []
    for name in names:
        model = reg[name]
        print(f"[aot] lowering {name} (P={model.spec.count()}) ...",
              flush=True)
        entries.append(export_model(model, out_dir, args.chunk))

    path = os.path.join(out_dir, "manifest.json")
    # Partial exports (--models a,b) merge into the existing manifest so a
    # targeted re-lowering never drops other models' entries.
    existing = {}
    if os.path.exists(path) and args.models != "all":
        with open(path) as f:
            existing = json.load(f).get("models", {})
    existing.update({e["name"]: e for e in entries})
    manifest = {
        "version": 1,
        "chunk": args.chunk,
        "models": existing,
    }
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    digest = hashlib.sha256(
        json.dumps(manifest, sort_keys=True).encode()).hexdigest()[:12]
    print(f"[aot] wrote {len(entries)} models -> {path} (sha {digest})")


if __name__ == "__main__":
    main()

"""GNN node classification — the paper's §4.3 contribution (first quantized
*training* study for GNNs), including the FP-Agg / Q-Agg ablation (Fig 5)
and the OGBN-Arxiv / OGBN-Products schedule sweeps (Fig 6).

`GCN` (OGBN-Arxiv stand-in): full-graph H_l = relu(Â H_{l-1} W_{l-1}) on a
dense degree-normalized adjacency with self-loops (paper Eq. 1).

`SAGE` (OGBN-Products stand-in): identical code path but the coordinator
supplies a *sampled*, truncated-neighborhood aggregation matrix per epoch,
reproducing the random-neighbor-sampling regime (and the footnote-4
numerical-stability argument: sampled aggregation truncates the sum).

Aggregation strategies (paper Fig 5):
  FP-Agg — Â @ (H W) in full precision (fdot; counted as fp32 GEMM).
  Q-Agg  — messages quantized to q_t before aggregation, and the
           aggregation GEMM itself runs quantized (qdot).

The graph (features, adjacency, labels, masks) enters as *shared* (non-
stacked) inputs: the lax.scan over the K-step chunk reuses one upload.
"""

import jax.numpy as jnp

from . import common
from .common import ParamSpec, qdot
from .. import ops


def qdot_agg(a, w, q_fwd, q_bwd):
    """Aggregation GEMM, quantized (Q-Agg). Counted separately: on a real
    graph this is a *sparse* matvec whose cost scales with edge count, so
    the BitOps accountant rescales it by the graph density (the dense
    matmul here is just the compute substrate for the simulator)."""
    m, k = a.shape
    _, n = w.shape
    common._record("agg_q_gemm", 2 * m * k * n)
    return ops.qdot(a, w, q_fwd, q_bwd)


def fdot_agg(a, b):
    """Aggregation GEMM, full precision (FP-Agg). Density-rescaled."""
    m, k = a.shape
    _, n = b.shape
    common._record("agg_fp_gemm", 2 * m * k * n)
    return a @ b


class GCN:
    metric = "accuracy"

    def __init__(self, name, nodes=256, in_dim=32, hidden=64, classes=8,
                 layers=3, q_agg=True, lr_kind="adam"):
        self.name = name
        self.nodes, self.in_dim, self.hidden = nodes, in_dim, hidden
        self.classes, self.layers, self.q_agg = classes, layers, q_agg
        self.opt = common.Adam(weight_decay=0.0)

        spec = ParamSpec()
        dims = [in_dim] + [hidden] * (layers - 1) + [classes]
        for i in range(layers):
            spec.add(f"l{i}.w", (dims[i], dims[i + 1]), "xavier")
            spec.add(f"l{i}.b", (dims[i + 1],), "zeros")
        self.spec = spec

        self.data_inputs = [
            ("feats", (nodes, in_dim), jnp.float32, False),
            ("adj", (nodes, nodes), jnp.float32, False),
            ("labels", (nodes,), jnp.int32, False),
            ("mask", (nodes,), jnp.float32, False),
        ]

    def forward(self, p, feats, adj, q_fwd, q_bwd):
        h = feats
        for i in range(self.layers):
            hw = qdot(h, p[f"l{i}.w"], q_fwd, q_bwd) + p[f"l{i}.b"]
            if self.q_agg:
                # Q-Agg: the aggregation GEMM runs quantized — qdot
                # fake-quantizes both the adjacency and the messages to q_t.
                h = qdot_agg(adj, hw, q_fwd, q_bwd)
            else:
                # FP-Agg: aggregation stays full precision
                h = fdot_agg(adj, hw)
            if i < self.layers - 1:
                h = jnp.maximum(h, 0.0)
        return h

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        logits = self.forward(p, data["feats"], data["adj"], q_fwd, q_bwd)
        return (common.masked_xent(logits, data["labels"], data["mask"]),
                common.masked_accuracy(logits, data["labels"], data["mask"]))


def gcn(q_agg, nodes=512, name=None):
    """OGBN-Arxiv stand-in: 3-layer full-graph GCN."""
    nm = name or ("gcn_qagg" if q_agg else "gcn_fpagg")
    return GCN(nm, nodes=nodes, in_dim=32, hidden=64, classes=8, layers=3,
               q_agg=q_agg)


def sage(q_agg, nodes=512, name=None):
    """OGBN-Products stand-in: 2-layer model; the coordinator feeds a
    sampled (truncated-neighborhood) aggregation matrix per epoch."""
    nm = name or ("sage_qagg" if q_agg else "sage_fpagg")
    return GCN(nm, nodes=nodes, in_dim=32, hidden=64, classes=8, layers=2,
               q_agg=q_agg)

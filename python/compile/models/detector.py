"""Grid detector — the PascalVOC / RetinaNet stand-in (paper Fig 4).

Synthetic detection task (DESIGN.md §4): images contain colored object
patches; the model predicts, per cell of a 4x4 grid, an objectness logit
(focal loss, as RetinaNet) and class logits (CE over object cells). The
metric is `map_lite`: F1 of objectness@0.5 × classification accuracy on
object cells — a scalar that moves like mAP for this workload.
"""

import jax
import jax.numpy as jnp

from . import common
from .common import ParamSpec, conv2d_q, groupnorm, qdot


class GridDetector:
    name = "detector"
    metric = "map_lite"

    def __init__(self, img=16, grid=4, classes=4, batch=16):
        self.img, self.grid, self.classes, self.batch = img, grid, classes, batch
        # Paper uses Adam at fixed lr for VOC.
        self.opt = common.Adam(weight_decay=0.0)

        spec = ParamSpec()
        chans = (16, 32)
        cin = 3
        for i, c in enumerate(chans):
            spec.add(f"c{i}.w", (9 * cin, c), "he")
            spec.add(f"c{i}.b", (c,), "zeros")
            spec.add(f"n{i}.g", (c,), "ones")
            spec.add(f"n{i}.b", (c,), "zeros")
            cin = c
        self.chans = chans
        # heads operate on per-cell features
        spec.add("obj.w", (chans[-1], 1), "he")
        spec.add("obj.b", (1,), "zeros")
        spec.add("cls.w", (chans[-1], classes), "he")
        spec.add("cls.b", (classes,), "zeros")
        self.spec = spec

        ncell = grid * grid
        self.data_inputs = [
            ("x", (batch, img, img, 3), jnp.float32, True),
            ("y_obj", (batch, ncell), jnp.float32, True),
            ("y_cls", (batch, ncell), jnp.int32, True),
        ]

    def forward(self, p, x, q_fwd, q_bwd):
        h = x
        for i in range(len(self.chans)):
            stride = 2 if i > 0 else 1
            h = conv2d_q(p, f"c{i}", h, q_fwd, q_bwd, stride=stride)
            h = jnp.maximum(groupnorm(p, f"n{i}", h), 0.0)
        # pool feature map down to the label grid
        b, hh, ww, c = h.shape
        cell = hh // self.grid
        cells = h.reshape(b, self.grid, cell, self.grid, cell, c)
        feats = jnp.mean(cells, axis=(2, 4)).reshape(b * self.grid * self.grid, c)
        obj = qdot(feats, p["obj.w"], q_fwd, q_bwd) + p["obj.b"]
        cls = qdot(feats, p["cls.w"], q_fwd, q_bwd) + p["cls.b"]
        ncell = self.grid * self.grid
        return obj.reshape(b, ncell), cls.reshape(b, ncell, self.classes)

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        obj, cls = self.forward(p, data["x"], q_fwd, q_bwd)
        y_obj, y_cls = data["y_obj"], data["y_cls"]
        l_obj = common.focal_bce(obj, y_obj)
        # class CE only on object cells
        logp = jnp.log(jnp.maximum(jnp.take_along_axis(
            jnp.exp(cls) / jnp.sum(jnp.exp(cls), axis=-1, keepdims=True),
            jnp.maximum(y_cls, 0)[..., None], axis=-1)[..., 0], 1e-8))
        l_cls = -jnp.sum(logp * y_obj) / jnp.maximum(jnp.sum(y_obj), 1.0)
        loss = l_obj + l_cls

        # map_lite: objectness F1 @0.5 times class accuracy on object cells
        pred_obj = jax.nn.sigmoid(obj) > 0.5
        tp = jnp.sum(pred_obj * y_obj)
        prec = tp / jnp.maximum(jnp.sum(pred_obj), 1.0)
        rec = tp / jnp.maximum(jnp.sum(y_obj), 1.0)
        f1 = 2 * prec * rec / jnp.maximum(prec + rec, 1e-8)
        cls_hit = (jnp.argmax(cls, axis=-1) == y_cls).astype(jnp.float32)
        cls_acc = jnp.sum(cls_hit * y_obj) / jnp.maximum(jnp.sum(y_obj), 1.0)
        return loss, f1 * cls_acc

"""Quickstart model: 2-layer quantized MLP classifier.

The smallest end-to-end exercise of the stack: flat-vector params, qdot
GEMMs, CPT-ready runtime bit-widths. Used by examples/quickstart.rs.
"""

import jax.numpy as jnp

from . import common
from .common import ParamSpec, qdot


class MLP:
    name = "mlp"
    metric = "accuracy"

    def __init__(self, in_dim=32, hidden=64, classes=4, batch=32):
        self.in_dim, self.hidden, self.classes, self.batch = (
            in_dim, hidden, classes, batch)
        self.opt = common.SGDM(momentum=0.9, weight_decay=1e-4)
        self.spec = (
            ParamSpec()
            .add("fc1.w", (in_dim, hidden), "he")
            .add("fc1.b", (hidden,), "zeros")
            .add("fc2.w", (hidden, classes), "he")
            .add("fc2.b", (classes,), "zeros")
        )
        self.data_inputs = [
            ("x", (batch, in_dim), jnp.float32, True),
            ("y", (batch,), jnp.int32, True),
        ]

    def forward(self, p, x, q_fwd, q_bwd):
        h = qdot(x, p["fc1.w"], q_fwd, q_bwd) + p["fc1.b"]
        h = jnp.maximum(h, 0.0)
        return qdot(h, p["fc2.w"], q_fwd, q_bwd) + p["fc2.b"]

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        logits = self.forward(p, data["x"], q_fwd, q_bwd)
        return (common.softmax_xent(logits, data["y"]),
                common.accuracy(logits, data["y"]))

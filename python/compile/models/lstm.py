"""One-layer LSTM word-level LM — the Penn Treebank stand-in (paper Fig 7).

Follows the Zaremba et al. regime the paper adopts: single LSTM layer,
dropout on the output, gradient-norm clipping 0.25, SGD whose lr is divided
by 5 on validation plateau — the plateau logic lives in the Rust trainer
(lr is a per-step runtime input, so the schedule decision never touches
python). Metric is mean token cross-entropy; the coordinator reports
perplexity = exp(ce).

All four gate GEMMs are fused into two qdot calls ([x,h] @ W) so the
recurrence exercises the Pallas kernel once per direction per step.
"""

import jax
import jax.numpy as jnp

from . import common
from .common import ParamSpec, qdot


class LstmLM:
    name = "lstm_lm"
    metric = "token_ce"

    def __init__(self, vocab=64, hidden=128, seq=32, batch=16, dropout=0.5):
        self.vocab, self.hidden, self.seq, self.batch = vocab, hidden, seq, batch
        self.dropout_rate = dropout
        # Paper: SGD, grad clip 0.25 (lr schedule driven from Rust).
        self.opt = common.SGDM(momentum=0.0, weight_decay=0.0, clip_norm=0.25)

        spec = ParamSpec()
        spec.add("embed", (vocab, hidden), "embed")
        spec.add("lstm.wx", (hidden, 4 * hidden), "uniform")
        spec.add("lstm.wh", (hidden, 4 * hidden), "uniform")
        spec.add("lstm.b", (4 * hidden,), "zeros")
        spec.add("head.w", (hidden, vocab), "xavier")
        spec.add("head.b", (vocab,), "zeros")
        self.spec = spec

        self.data_inputs = [
            ("x", (batch, seq), jnp.int32, True),
            ("y", (batch, seq), jnp.int32, True),
        ]

    def forward(self, p, x, q_fwd, q_bwd, rng, train):
        b, t = x.shape
        h_dim = self.hidden
        emb = jnp.take(p["embed"], x, axis=0)  # [B, T, H] (kept FP: lookup)

        def cell(carry, xt):
            h, c = carry
            gates = (qdot(xt, p["lstm.wx"], q_fwd, q_bwd)
                     + qdot(h, p["lstm.wh"], q_fwd, q_bwd)
                     + p["lstm.b"])
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        h0 = jnp.zeros((b, h_dim))
        (_, _), hs = jax.lax.scan(cell, (h0, h0), jnp.swapaxes(emb, 0, 1))
        hs = jnp.swapaxes(hs, 0, 1)  # [B, T, H]
        hs = common.dropout(hs, self.dropout_rate, rng, train)
        flat = hs.reshape(b * t, h_dim)
        logits = qdot(flat, p["head.w"], q_fwd, q_bwd) + p["head.b"]
        return logits.reshape(b, t, self.vocab)

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        logits = self.forward(p, data["x"], q_fwd, q_bwd, rng, train)
        b, t, v = logits.shape
        ce = common.softmax_xent(logits.reshape(b * t, v),
                                 data["y"].reshape(b * t))
        # metric = token CE as well (perplexity computed by the coordinator;
        # exp() on device would overflow early in training)
        return ce, ce

"""L2 model registry. Every entry is exported by aot.py as four HLO
artifacts (init / train_chunk / train_step / eval) plus manifest metadata.

Chunk size K: the coordinator advances K optimizer steps per executable
call (lax.scan), passing the CPT schedule as a q_fwd[K] vector. K=8
balances host-roundtrip amortization against artifact compile time.
"""

from .mlp import MLP
from .cnn import resnet_tiny, resnet_deep
from .detector import GridDetector
from .gnn import gcn, sage
from .lstm import LstmLM
from .transformer import transformer_lm, transformer_cls

DEFAULT_CHUNK = 8


def registry():
    """name -> model instance (constructed with default sizes)."""
    models = [
        MLP(),
        resnet_tiny(),
        resnet_deep(),
        GridDetector(),
        gcn(q_agg=True),
        gcn(q_agg=False),
        sage(q_agg=True),
        sage(q_agg=False),
        LstmLM(),
        transformer_lm(),
        transformer_cls(),
    ]
    return {m.name: m for m in models}

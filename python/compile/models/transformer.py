"""Transformer encoder — two exports:

* `transformer_lm`  — causal LM used by the end-to-end example (train a
  real small model on a corpus for a few hundred steps, log the loss
  curve; EXPERIMENTS.md §E2E).
* `transformer_cls` — sequence-pair classifier, the mBERT/XNLI stand-in
  for the paper's Fig 7 right panel (short fine-tuning horizon, n ∈ {1,2}
  cycles). DESIGN.md §4 records the random-init substitution for the
  unavailable pretrained checkpoint.

Attention and MLP GEMMs all route through qdot; softmax/layernorm stay in
full precision (as in the paper's simulated-quantization setup, which
clips GEMM operands only).
"""

import jax
import jax.numpy as jnp

from . import common
from .common import ParamSpec, layernorm, qdot


class Transformer:
    def __init__(self, name, vocab=64, d=128, heads=4, layers=2, seq=32,
                 batch=16, classes=None, dropout=0.1):
        self.name = name
        self.vocab, self.d, self.heads, self.layers = vocab, d, heads, layers
        self.seq, self.batch, self.classes = seq, batch, classes
        self.dropout_rate = dropout
        self.causal = classes is None
        self.metric = "accuracy" if classes else "token_ce"
        self.opt = common.Adam(weight_decay=0.01, clip_norm=1.0)

        spec = ParamSpec()
        spec.add("embed", (vocab, d), "embed")
        spec.add("pos", (seq, d), "embed")
        for i in range(layers):
            pre = f"l{i}"
            spec.add(f"{pre}.qkv.w", (d, 3 * d), "xavier")
            spec.add(f"{pre}.qkv.b", (3 * d,), "zeros")
            spec.add(f"{pre}.proj.w", (d, d), "xavier")
            spec.add(f"{pre}.proj.b", (d,), "zeros")
            spec.add(f"{pre}.n1.g", (d,), "ones")
            spec.add(f"{pre}.n1.b", (d,), "zeros")
            spec.add(f"{pre}.mlp1.w", (d, 4 * d), "xavier")
            spec.add(f"{pre}.mlp1.b", (4 * d,), "zeros")
            spec.add(f"{pre}.mlp2.w", (4 * d, d), "xavier")
            spec.add(f"{pre}.mlp2.b", (d,), "zeros")
            spec.add(f"{pre}.n2.g", (d,), "ones")
            spec.add(f"{pre}.n2.b", (d,), "zeros")
        spec.add("final.g", (d,), "ones")
        spec.add("final.b", (d,), "zeros")
        if classes:
            spec.add("head.w", (d, classes), "xavier")
            spec.add("head.b", (classes,), "zeros")
        else:
            spec.add("head.w", (d, vocab), "xavier")
            spec.add("head.b", (vocab,), "zeros")
        self.spec = spec

        if classes:
            self.data_inputs = [
                ("x", (batch, seq), jnp.int32, True),
                ("y", (batch,), jnp.int32, True),
            ]
        else:
            self.data_inputs = [
                ("x", (batch, seq), jnp.int32, True),
                ("y", (batch, seq), jnp.int32, True),
            ]

    def _attn(self, p, pre, h, q_fwd, q_bwd):
        b, t, d = h.shape
        nh = self.heads
        hd = d // nh
        qkv = qdot(h.reshape(b * t, d), p[f"{pre}.qkv.w"], q_fwd, q_bwd)
        qkv = (qkv + p[f"{pre}.qkv.b"]).reshape(b, t, 3, nh, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [B,T,nh,hd]
        q = jnp.swapaxes(q, 1, 2)  # [B,nh,T,hd]
        k = jnp.swapaxes(k, 1, 2)
        v = jnp.swapaxes(v, 1, 2)
        # attention score/context matmuls stay FP (activation×activation;
        # the paper's simulation quantizes weight-bearing GEMMs) — still
        # counted for the BitOps denominator:
        common._record("fp_gemm", 2 * 2 * b * nh * t * t * hd)
        att = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(float(hd))
        if self.causal:
            mask = jnp.tril(jnp.ones((t, t), bool))
            att = jnp.where(mask[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhts,bhsd->bhtd", att, v)
        out = jnp.swapaxes(out, 1, 2).reshape(b * t, d)
        out = qdot(out, p[f"{pre}.proj.w"], q_fwd, q_bwd) + p[f"{pre}.proj.b"]
        return out.reshape(b, t, d)

    def forward(self, p, x, q_fwd, q_bwd, rng, train):
        b, t = x.shape
        h = jnp.take(p["embed"], x, axis=0) + p["pos"][None, :t]
        for i in range(self.layers):
            pre = f"l{i}"
            a = self._attn(p, pre, layernorm(p, f"{pre}.n1", h), q_fwd, q_bwd)
            a = common.dropout(a, self.dropout_rate,
                               jax.random.fold_in(rng, 2 * i), train)
            h = h + a
            m = layernorm(p, f"{pre}.n2", h)
            m2 = qdot(m.reshape(b * t, self.d), p[f"{pre}.mlp1.w"],
                      q_fwd, q_bwd) + p[f"{pre}.mlp1.b"]
            m2 = jax.nn.gelu(m2)
            m2 = qdot(m2, p[f"{pre}.mlp2.w"], q_fwd, q_bwd) + p[f"{pre}.mlp2.b"]
            m2 = common.dropout(m2.reshape(b, t, self.d), self.dropout_rate,
                                jax.random.fold_in(rng, 2 * i + 1), train)
            h = h + m2
        h = layernorm(p, "final", h)
        if self.classes:
            cls = jnp.mean(h, axis=1)  # mean-pool (no [CLS] in synthetic data)
            return qdot(cls, p["head.w"], q_fwd, q_bwd) + p["head.b"]
        flat = h.reshape(b * t, self.d)
        logits = qdot(flat, p["head.w"], q_fwd, q_bwd) + p["head.b"]
        return logits.reshape(b, t, self.vocab)

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        logits = self.forward(p, data["x"], q_fwd, q_bwd, rng, train)
        if self.classes:
            return (common.softmax_xent(logits, data["y"]),
                    common.accuracy(logits, data["y"]))
        b, t, v = logits.shape
        ce = common.softmax_xent(logits.reshape(b * t, v),
                                 data["y"].reshape(b * t))
        return ce, ce


def transformer_lm(batch=16, seq=32):
    return Transformer("transformer_lm", vocab=64, d=128, heads=4, layers=2,
                       seq=seq, batch=batch, classes=None)


def transformer_cls(batch=16, seq=32):
    """XNLI stand-in: 3-way sequence-pair classification (entail/neutral/
    contradict analog on synthetic pairs)."""
    return Transformer("transformer_cls", vocab=64, d=128, heads=4, layers=2,
                       seq=seq, batch=batch, classes=3)

"""Shared L2 model machinery: params, layers, losses, optimizers, and the
generic train-chunk builder every model is exported through.

Design contract with the Rust coordinator (see DESIGN.md §2):

* Parameters and optimizer state travel as **single flat f32 vectors** —
  the PJRT C shim returns outputs as one tuple literal, so fewer/larger
  leaves minimize the host↔device roundtrip the coordinator must perform.
* A **train chunk** advances K optimizer steps per executable call via
  `lax.scan`. The coordinator supplies per-step vectors: q_fwd[K] (the CPT
  schedule values — evaluated in Rust), lr[K], seeds[K], plus K stacked
  minibatches. This amortizes the roundtrip K× (EXPERIMENTS.md §Perf).
* Bit-widths are runtime scalars; one artifact serves all of [q_min, q_max].

Every GEMM in every model routes through `ops.qdot` (the Pallas fused
quantize→matmul kernel) so the whole suite exercises the L1 hot path.
"""

import threading
from functools import partial

import jax
import jax.numpy as jnp

from .. import ops

# --------------------------------------------------------------------------
# GEMM FLOP accounting (paper §4.1 BitOps needs per-model GEMM FLOPs).
# A thread-local counter is armed while abstractly tracing a model's forward
# pass; `qdot`/`fdot` below record 2*m*k*n per call. The totals land in the
# artifact manifest and drive rust/src/quant/bitops.rs.
# --------------------------------------------------------------------------

_COUNTER = threading.local()


def _record(kind, flops):
    acc = getattr(_COUNTER, "acc", None)
    if acc is not None:
        acc[kind] = acc.get(kind, 0) + flops


def qdot(a, w, q_fwd, q_bwd):
    """Counted wrapper over ops.qdot (quantized GEMM)."""
    m, k = a.shape
    _, n = w.shape
    _record("q_gemm", 2 * m * k * n)
    return ops.qdot(a, w, q_fwd, q_bwd)


def fdot(a, b):
    """Full-precision GEMM (counted separately — e.g. FP-Agg aggregation)."""
    m, k = a.shape
    _, n = b.shape
    _record("fp_gemm", 2 * m * k * n)
    return a @ b


def count_gemm_flops(fn, *args):
    """Abstractly evaluate `fn(*args)` and return {'q_gemm': .., 'fp_gemm': ..}."""
    _COUNTER.acc = {}
    try:
        jax.eval_shape(fn, *args)
        return dict(_COUNTER.acc)
    finally:
        _COUNTER.acc = None


# --------------------------------------------------------------------------
# Parameter specs and flat <-> pytree conversion
# --------------------------------------------------------------------------

class ParamSpec:
    """Ordered list of named tensors with deterministic initialization."""

    def __init__(self):
        self.entries = []  # (name, shape, init_kind)

    def add(self, name, shape, init="he"):
        self.entries.append((name, tuple(int(s) for s in shape), init))
        return self

    def count(self):
        total = 0
        for _, shape, _ in self.entries:
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    def init_flat(self, key):
        """Initialize all tensors and return them as one flat f32 vector."""
        parts = []
        for i, (_, shape, kind) in enumerate(self.entries):
            k = jax.random.fold_in(key, i)
            n = 1
            for s in shape:
                n *= s
            if kind == "zeros":
                t = jnp.zeros(shape, jnp.float32)
            elif kind == "ones":
                t = jnp.ones(shape, jnp.float32)
            elif kind == "he":
                fan_in = shape[0] if len(shape) >= 2 else max(n, 1)
                t = jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)
            elif kind == "xavier":
                fan_in = shape[0] if len(shape) >= 2 else n
                fan_out = shape[-1]
                t = jax.random.normal(k, shape) * jnp.sqrt(2.0 / (fan_in + fan_out))
            elif kind == "embed":
                t = jax.random.normal(k, shape) * 0.02
            elif kind == "uniform":
                lim = 1.0 / jnp.sqrt(shape[0])
                t = jax.random.uniform(k, shape, minval=-lim, maxval=lim)
            else:
                raise ValueError(f"unknown init {kind}")
            parts.append(t.reshape(-1).astype(jnp.float32))
        return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)

    def unflatten(self, flat):
        """Flat f32[P] -> dict name -> tensor."""
        out = {}
        off = 0
        for name, shape, _ in self.entries:
            n = 1
            for s in shape:
                n *= s
            out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
            off += n
        return out

    def manifest(self):
        return [{"name": n, "shape": list(s)} for n, s, _ in self.entries]


# --------------------------------------------------------------------------
# Layers (all GEMMs through qdot)
# --------------------------------------------------------------------------

def qlinear(p, prefix, x, q_fwd, q_bwd, bias=True):
    """Quantized dense layer. x: [B, D_in] -> [B, D_out]."""
    y = qdot(x, p[f"{prefix}.w"], q_fwd, q_bwd)
    if bias:
        y = y + p[f"{prefix}.b"]
    return y


def conv2d_q(p, prefix, x, q_fwd, q_bwd, stride=1):
    """Quantized 3x3 same-conv as im2col + qdot.

    x: [B, H, W, C_in]; weight stored as [9*C_in, C_out]. im2col keeps the
    GEMM on the Pallas path (the paper quantizes convs the same way).
    """
    b, h, w, cin = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(3, 3),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H', W', 9*C_in] (feature dim = C_in * 9 per lax docs ordering)
    ho, wo = patches.shape[1], patches.shape[2]
    flat = patches.reshape(b * ho * wo, patches.shape[3])
    y = qdot(flat, p[f"{prefix}.w"], q_fwd, q_bwd)
    cout = y.shape[-1]
    return y.reshape(b, ho, wo, cout) + p[f"{prefix}.b"]


def groupnorm(p, prefix, x, groups=4, eps=1e-5):
    """GroupNorm over channels (stateless BN stand-in; DESIGN.md §4 notes
    the substitution — BN running stats would add mutable non-param state,
    and the paper keeps norm layers in full precision anyway)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(b, h, w, c)
    return xn * p[f"{prefix}.g"] + p[f"{prefix}.b"]


def layernorm(p, prefix, x, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mean) / jnp.sqrt(var + eps)
    return xn * p[f"{prefix}.g"] + p[f"{prefix}.b"]


def dropout(x, rate, key, train):
    if not train or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


# --------------------------------------------------------------------------
# Losses / metrics
# --------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """Mean cross-entropy. labels: int[B]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def masked_xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_accuracy(logits, labels, mask):
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def focal_bce(logits, targets, gamma=2.0, alpha=0.25):
    """Focal loss on sigmoid logits (RetinaNet-style, paper Fig 4 workload)."""
    p = jax.nn.sigmoid(logits)
    ce = -(targets * jnp.log(p + 1e-8) + (1 - targets) * jnp.log(1 - p + 1e-8))
    pt = targets * p + (1 - targets) * (1 - p)
    w = targets * alpha + (1 - targets) * (1 - alpha)
    return jnp.mean(w * (1 - pt) ** gamma * ce)


# --------------------------------------------------------------------------
# Optimizers over flat vectors
# --------------------------------------------------------------------------

class SGDM:
    """SGD + momentum (paper: momentum 0.9 for image classification)."""

    name = "sgdm"

    def __init__(self, momentum=0.9, weight_decay=0.0, clip_norm=0.0):
        self.momentum = momentum
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm

    def state_count(self, p):
        return p

    def init_state(self, p):
        return jnp.zeros((p,), jnp.float32)

    def update(self, params, state, grads, lr):
        grads = _maybe_clip(grads, self.clip_norm)
        if self.weight_decay:
            grads = grads + self.weight_decay * params
        buf = self.momentum * state + grads
        return params - lr * buf, buf


class Adam:
    """Adam with bias correction; step count carried in the state tail."""

    name = "adam"

    def __init__(self, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                 clip_norm=0.0):
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm

    def state_count(self, p):
        return 2 * p + 1

    def init_state(self, p):
        return jnp.zeros((2 * p + 1,), jnp.float32)

    def update(self, params, state, grads, lr):
        grads = _maybe_clip(grads, self.clip_norm)
        if self.weight_decay:
            grads = grads + self.weight_decay * params
        p = params.shape[0]
        m, v, t = state[:p], state[p:2 * p], state[2 * p]
        t = t + 1.0
        m = self.b1 * m + (1 - self.b1) * grads
        v = self.b2 * v + (1 - self.b2) * grads * grads
        mhat = m / (1 - self.b1 ** t)
        vhat = v / (1 - self.b2 ** t)
        new = params - lr * mhat / (jnp.sqrt(vhat) + self.eps)
        return new, jnp.concatenate([m, v, t[None]])


def _maybe_clip(g, max_norm):
    if not max_norm:
        return g
    norm = jnp.sqrt(jnp.sum(g * g))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-8))
    return g * scale


OPTIMIZERS = {"sgdm": SGDM, "adam": Adam}


# --------------------------------------------------------------------------
# Generic train-chunk / eval builders
# --------------------------------------------------------------------------

def make_step_fns(model, opt, chunk):
    """Build (init, train_chunk, train_step, eval) python callables for a
    model object exposing:

      spec:        ParamSpec
      loss(params_dict, data_dict, q_fwd, q_bwd, rng, train) -> (loss, metric)
      data_inputs: [(name, shape_per_step, dtype, stacked)] — see DESIGN.md
    """
    spec = model.spec
    p_count = spec.count()

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = spec.init_flat(key)
        return params, opt.init_state(p_count)

    stacked = [d for d in model.data_inputs if d[3]]
    shared = [d for d in model.data_inputs if not d[3]]

    def loss_flat(params_flat, data, q_fwd, q_bwd, rng, train):
        p = spec.unflatten(params_flat)
        return model.loss(p, data, q_fwd, q_bwd, rng, train)

    def one_step(params, state, data, q_fwd, q_bwd, lr, seed):
        rng = jax.random.PRNGKey(seed)
        grad_fn = jax.value_and_grad(
            lambda pf: loss_flat(pf, data, q_fwd, q_bwd, rng, True),
            has_aux=True,
        )
        (loss, metric), grads = grad_fn(params)
        params, state = opt.update(params, state, grads, lr)
        return params, state, loss, metric

    def train_chunk(params, state, *rest):
        # rest = stacked data (k-leading), shared data, q_fwd[k], lr[k],
        #        seeds[k] (i32), q_bwd scalar
        n_stacked = len(stacked)
        n_shared = len(shared)
        stacked_vals = rest[:n_stacked]
        shared_vals = rest[n_stacked:n_stacked + n_shared]
        q_fwd_v, lr_v, seeds_v, q_bwd = rest[n_stacked + n_shared:]

        shared_data = {d[0]: v for d, v in zip(shared, shared_vals)}

        def body(carry, xs):
            params, state = carry
            step_stacked, q, lr, seed = xs
            data = dict(shared_data)
            data.update({d[0]: v for d, v in zip(stacked, step_stacked)})
            params, state, loss, metric = one_step(
                params, state, data, q, q_bwd, lr, seed)
            return (params, state), (loss, metric)

        (params, state), (losses, metrics) = jax.lax.scan(
            body, (params, state), (tuple(stacked_vals), q_fwd_v, lr_v, seeds_v))
        return params, state, losses, metrics

    def eval_step(params, *data_vals):
        data = {d[0]: v for d, v in zip(model.data_inputs, data_vals)}
        rng = jax.random.PRNGKey(0)
        # Evaluation runs at full effective precision (q=32 ≈ identity);
        # matches the paper: precision scheduling is a *training* mechanism.
        loss, metric = loss_flat(params, data, 32.0, 32.0, rng, False)
        return loss, metric

    return init, train_chunk, eval_step


def chunk_arg_specs(model, chunk, batch):
    """Abstract input specs for lowering train_chunk (order must match)."""
    spec = model.spec
    p = spec.count()
    args = [
        jax.ShapeDtypeStruct((p,), jnp.float32),                    # params
        jax.ShapeDtypeStruct((model.opt.state_count(p),), jnp.float32),
    ]
    for name, shape, dtype, is_stacked in model.data_inputs:
        if is_stacked:
            args.append(jax.ShapeDtypeStruct((chunk, *shape), dtype))
    for name, shape, dtype, is_stacked in model.data_inputs:
        if not is_stacked:
            args.append(jax.ShapeDtypeStruct(shape, dtype))
    args += [
        jax.ShapeDtypeStruct((chunk,), jnp.float32),   # q_fwd per step
        jax.ShapeDtypeStruct((chunk,), jnp.float32),   # lr per step
        jax.ShapeDtypeStruct((chunk,), jnp.int32),     # seeds per step
        jax.ShapeDtypeStruct((), jnp.float32),         # q_bwd
    ]
    return args


def eval_arg_specs(model):
    spec = model.spec
    p = spec.count()
    args = [jax.ShapeDtypeStruct((p,), jnp.float32)]
    for name, shape, dtype, _ in model.data_inputs:
        args.append(jax.ShapeDtypeStruct(shape, dtype))
    return args

"""Residual CNNs for the image-classification experiments (paper Fig 3,
Table 1).

`resnet_tiny` stands in for ResNet-74-on-CIFAR (2 residual stages), and
`resnet_deep` for the ImageNet-scale panel (3 stages, more classes) — see
DESIGN.md §4 for the substitution argument. Every conv is an im2col GEMM
through the Pallas qdot path; norms are GroupNorm (stateless BN stand-in,
kept in full precision exactly as the paper keeps BN in full precision).
"""

import jax.numpy as jnp

from . import common
from .common import ParamSpec, conv2d_q, groupnorm, qdot


def _add_conv(spec, name, cin, cout):
    spec.add(f"{name}.w", (9 * cin, cout), "he")
    spec.add(f"{name}.b", (cout,), "zeros")


def _add_norm(spec, name, c):
    spec.add(f"{name}.g", (c,), "ones")
    spec.add(f"{name}.b", (c,), "zeros")


class ResNet:
    metric = "accuracy"

    def __init__(self, name, img=16, channels=(16, 32), blocks_per_stage=1,
                 classes=10, batch=32, weight_decay=1e-4):
        self.name = name
        self.img = img
        self.channels = channels
        self.blocks_per_stage = blocks_per_stage
        self.classes = classes
        self.batch = batch
        self.opt = common.SGDM(momentum=0.9, weight_decay=weight_decay)

        spec = ParamSpec()
        _add_conv(spec, "stem", 3, channels[0])
        _add_norm(spec, "stem.n", channels[0])
        cin = channels[0]
        for s, cout in enumerate(channels):
            for b in range(blocks_per_stage):
                pre = f"s{s}b{b}"
                _add_conv(spec, f"{pre}.c1", cin if b == 0 else cout, cout)
                _add_norm(spec, f"{pre}.n1", cout)
                _add_conv(spec, f"{pre}.c2", cout, cout)
                _add_norm(spec, f"{pre}.n2", cout)
                if b == 0 and cin != cout:
                    spec.add(f"{pre}.proj.w", (cin, cout), "he")
            cin = cout
        spec.add("head.w", (channels[-1], classes), "he")
        spec.add("head.b", (classes,), "zeros")
        self.spec = spec

        self.data_inputs = [
            ("x", (batch, img, img, 3), jnp.float32, True),
            ("y", (batch,), jnp.int32, True),
        ]

    def forward(self, p, x, q_fwd, q_bwd):
        h = conv2d_q(p, "stem", x, q_fwd, q_bwd)
        h = jnp.maximum(groupnorm(p, "stem.n", h), 0.0)
        for s, cout in enumerate(self.channels):
            for b in range(self.blocks_per_stage):
                pre = f"s{s}b{b}"
                stride = 2 if (s > 0 and b == 0) else 1
                y = conv2d_q(p, f"{pre}.c1", h, q_fwd, q_bwd, stride=stride)
                y = jnp.maximum(groupnorm(p, f"{pre}.n1", y), 0.0)
                y = conv2d_q(p, f"{pre}.c2", y, q_fwd, q_bwd)
                y = groupnorm(p, f"{pre}.n2", y)
                sc = h
                if stride != 1:
                    sc = sc[:, ::2, ::2, :]
                if f"{pre}.proj.w" in p:
                    bsz, hh, ww, cc = sc.shape
                    sc = qdot(sc.reshape(-1, cc), p[f"{pre}.proj.w"],
                              q_fwd, q_bwd).reshape(bsz, hh, ww, cout)
                h = jnp.maximum(y + sc, 0.0)
        pooled = jnp.mean(h, axis=(1, 2))
        return qdot(pooled, p["head.w"], q_fwd, q_bwd) + p["head.b"]

    def loss(self, p, data, q_fwd, q_bwd, rng, train):
        logits = self.forward(p, data["x"], q_fwd, q_bwd)
        return (common.softmax_xent(logits, data["y"]),
                common.accuracy(logits, data["y"]))


def resnet_tiny(batch=32):
    """CIFAR-panel stand-in: 16x16 imgs, 2 stages, 10 classes (~25k params)."""
    return ResNet("cnn_tiny", img=16, channels=(16, 32),
                  blocks_per_stage=1, classes=10, batch=batch)


def resnet_deep(batch=32):
    """ImageNet-panel stand-in: deeper/wider, 20 classes."""
    return ResNet("cnn_deep", img=16, channels=(16, 32, 64),
                  blocks_per_stage=1, classes=20, batch=batch,
                  weight_decay=1e-5)

"""Build-time compile path: Pallas kernels (L1) + JAX models (L2) + AOT.

Nothing in this package is imported at runtime — `aot.py` lowers everything
to HLO text under artifacts/, which the Rust coordinator loads via PJRT.
"""

"""Fused quantize→matmul Pallas kernel — the hot-spot of quantized training.

The paper simulates low-precision GEMMs on GPU by clipping operands before
each matmul. The TPU re-think (DESIGN.md §Hardware-Adaptation): instead of
materializing quantized copies in HBM, fuse fake-quantization into the
HBM→VMEM tile load of a blocked matmul. Each grid step loads an (bm, bk)
A-tile and a (bk, bn) B-tile, quantizes both *in VMEM*, and feeds the MXU;
partial products accumulate into the (bm, bn) output block across the k
axis of the grid.

Bit-widths arrive as (1, 1) scalar blocks, so a single compiled kernel
serves every precision in [q_min, q_max] — exactly what cyclic precision
training needs (a new q_t every iteration, no recompilation).

VMEM budget at the default 128-blocks (f32): A-tile + B-tile + their
quantized values + out block = 4 * 128*128*4 B = 256 KiB « 16 MiB, leaving
room for double-buffering on a real TPU. The contraction feeds the MXU with
(128, 128) operands, its native systolic shape.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls; interpret mode
lowers to plain HLO. Structure (BlockSpec schedule) is what we optimize —
real-TPU performance is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import _divisor_block

# VMEM budget for one grid step's working set (A-tile + B-tile + out block,
# f32). Real TPUs have ~16 MiB of VMEM; 4 MiB leaves headroom for double
# buffering and the quantized temporaries. Within the budget we make blocks
# as LARGE as possible: every extra grid step costs a loop iteration of
# dynamic-slice traffic (HBM re-reads of the A/B panels on TPU; while-loop
# overhead under interpret=True) — see EXPERIMENTS.md §Perf iteration 1.
_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def _block_shapes(m, n, k, budget=None):
    """Choose (bm, bn, bk) dividing (m, n, k), maximizing block volume
    within the VMEM budget. Shrinks the largest axis first."""
    budget = budget or _VMEM_BUDGET_BYTES
    bm, bn, bk = m, n, k

    def footprint(bm, bn, bk):
        return 4 * (bm * bk + bk * bn + bm * bn)

    while footprint(bm, bn, bk) > budget:
        # halve the largest axis (to a divisor of the dim)
        if bm >= bn and bm >= bk and bm > 8:
            bm = _divisor_block(m, max(bm // 2, 8))
        elif bn >= bk and bn > 8:
            bn = _divisor_block(n, max(bn // 2, 8))
        elif bk > 8:
            bk = _divisor_block(k, max(bk // 2, 8))
        else:
            break  # minimum tile reached
    return bm, bn, bk


def _qmm_kernel(a_ref, b_ref, qa_ref, qb_ref, sa_ref, sb_ref, o_ref):
    # Zero the output block on the first visit along the contraction axis.
    @pl.when(pl.program_id(2) == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    qa = qa_ref[0, 0]
    qb = qb_ref[0, 0]
    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]
    la = jnp.round(2.0 ** (qa - 1.0)) - 1.0
    lb = jnp.round(2.0 ** (qb - 1.0)) - 1.0
    # Quantize the tiles in VMEM, then contract on the MXU.
    aq = jnp.round(jnp.clip(a_ref[...] / sa, -1.0, 1.0) * la) / la * sa
    bq = jnp.round(jnp.clip(b_ref[...] / sb, -1.0, 1.0) * lb) / lb * sb
    o_ref[...] += jnp.dot(aq, bq, preferred_element_type=jnp.float32)


@functools.partial(jax.named_call, name="qmatmul_pallas")
def qmatmul(a, b, qa, qb, sa=None, sb=None):
    """Quantized matmul: fake_quant(a, qa) @ fake_quant(b, qb).

    Args:
      a:  f32[m, k]
      b:  f32[k, n]
      qa, qb: scalar bit-widths (traced f32 — runtime values).
      sa, sb: optional per-tensor scales; computed (max-abs) if omitted.

    Returns f32[m, n].
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    if sa is None:
        sa = jnp.maximum(jnp.max(jnp.abs(a)), 1e-8)
    if sb is None:
        sb = jnp.maximum(jnp.max(jnp.abs(b)), 1e-8)

    bm, bn, bk = _block_shapes(m, k=k, n=n)
    grid = (m // bm, n // bn, k // bk)

    qa2 = jnp.asarray(qa, jnp.float32).reshape(1, 1)
    qb2 = jnp.asarray(qb, jnp.float32).reshape(1, 1)
    sa2 = jnp.asarray(sa, jnp.float32).reshape(1, 1)
    sb2 = jnp.asarray(sb, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, qa2, qb2, sa2, sb2)

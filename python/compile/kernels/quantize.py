"""Pallas fake-quantization kernel with a *runtime* bit-width.

The kernel implements the same math as `ref.fake_quant`, but as an explicit
blocked HBM→VMEM schedule. The bit-width `q` and the per-tensor scale `s`
arrive as (1, 1) scalar blocks (SMEM-style operands on a real TPU), so one
compiled kernel serves the entire precision range [q_min, q_max] — the CPT
coordinator just feeds a different scalar each iteration.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO (a fori over the
grid with dynamic-slices) which runs on any backend, and is the numerics
ground-truth path for this repo. See DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred VMEM block: one (8, 128)-lane-aligned tile times a few sublanes.
# 256x256 f32 = 256 KiB — comfortably inside a 16 MiB VMEM budget together
# with the output block and scalars.
_PREF_BLOCK = 256


def _divisor_block(dim, pref):
    """Largest block size <= pref that divides dim.

    Pallas pads out-of-bounds blocks, which corrupts accumulation-style
    kernels; picking an exact divisor keeps every block fully in-bounds.
    Falls back to the full dimension (grid=1 on that axis).
    """
    if dim <= pref:
        return dim
    for cand in range(pref, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _quantize_kernel(x_ref, q_ref, s_ref, o_ref):
    q = q_ref[0, 0]
    s = s_ref[0, 0]
    lv = jnp.round(2.0 ** (q - 1.0)) - 1.0
    x = x_ref[...]
    o_ref[...] = jnp.round(jnp.clip(x / s, -1.0, 1.0) * lv) / lv * s


def quantize_2d(x, q, scale):
    """Fake-quantize a 2-D tensor to `q` bits via the Pallas kernel.

    Args:
      x:     f32[m, n]
      q:     scalar bit-width (traced; f32)
      scale: scalar per-tensor scale (traced; f32). Computed by the caller —
             the max-abs reduction is a separate (XLA-fused) pass so the
             kernel itself stays embarrassingly parallel.
    """
    m, n = x.shape
    bm = _divisor_block(m, _PREF_BLOCK)
    bn = _divisor_block(n, _PREF_BLOCK)
    grid = (m // bm, n // bn)
    qb = jnp.asarray(q, jnp.float32).reshape(1, 1)
    sb = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, qb, sb)


def quantize(x, q, scale=None):
    """Fake-quantize a tensor of any rank (reshapes through 2-D)."""
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    orig_shape = x.shape
    flat = x.reshape(1, -1) if x.ndim != 2 else x
    out = quantize_2d(flat, q, scale)
    return out.reshape(orig_shape)

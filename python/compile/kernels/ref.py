"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth used by pytest (and hypothesis sweeps) to verify
the Pallas kernels in `quantize.py` / `qmatmul.py`. They implement the
paper's quantizer exactly once, in the simplest possible form, so any
discrepancy in the kernels is attributable to the kernel code.

Quantizer (SBM/DoReFa-style symmetric uniform fake-quantization, paper §3.1):

    levels(q) = 2^(q-1) - 1            # signed, symmetric around 0
    s         = max(|x|)  (per tensor) # dynamic scale
    Q(x; q)   = round(clip(x/s, -1, 1) * levels) / levels * s

`q` is a *runtime* value (f32 scalar) — CPT changes it every iteration, and
recompiling per bit-width would defeat the point. `round(2^(q-1))` keeps the
level count exact for integer q while remaining a traced computation.
"""

import jax.numpy as jnp

# Smallest representable scale. Guards against all-zero tensors.
EPS = 1e-8


def levels(q):
    """Number of positive quantization levels for a signed q-bit format."""
    return jnp.round(2.0 ** (jnp.asarray(q, jnp.float32) - 1.0)) - 1.0


def dynamic_scale(x):
    """Per-tensor dynamic range (max-abs) with an epsilon floor."""
    return jnp.maximum(jnp.max(jnp.abs(x)), EPS)


def fake_quant(x, q, scale=None):
    """Fake-quantize `x` to `q` bits (symmetric uniform, per-tensor scale).

    Returns a float tensor holding the dequantized values — this is how the
    paper (and CPT / FracTrain before it) simulates low-precision arithmetic
    on hardware without native sub-byte support.
    """
    s = dynamic_scale(x) if scale is None else scale
    lv = levels(q)
    return jnp.round(jnp.clip(x / s, -1.0, 1.0) * lv) / lv * s


def quant_error_bound(q, scale):
    """Worst-case absolute rounding error of `fake_quant`: s / (2*levels)."""
    return scale / (2.0 * levels(q))


def qmatmul(a, b, qa, qb):
    """Reference quantized matmul: quantize both operands, then matmul."""
    return fake_quant(a, qa) @ fake_quant(b, qb)


def ste_mask(x, scale=None):
    """Straight-through-estimator clip mask: 1 where |x| <= s, else 0."""
    s = dynamic_scale(x) if scale is None else scale
    return (jnp.abs(x) <= s).astype(x.dtype)

"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import ref
from .quantize import quantize, quantize_2d
from .qmatmul import qmatmul

__all__ = ["ref", "quantize", "quantize_2d", "qmatmul"]

"""Quantized-training ops: the differentiable layer over the L1 kernels.

`qdot` is the single primitive every model routes its GEMMs through. It
implements the paper's Figure 1 dataflow:

  forward:  out = Q(a; q_fwd) @ Q(w; q_fwd)          (fused Pallas kernel)
  backward: g_q = Q(g; q_bwd)                        (gradient quantization)
            da  = (g_q @ Q(w)ᵀ) · STE-mask(a)
            dw  = (Q(a)ᵀ @ g_q) · STE-mask(w)

Per paper §3.1, cyclic precision applies only to the forward pass; the
backward pass quantizes gradients at the *fixed* q_max. Both bit-widths are
runtime scalars so one compiled train-step serves the whole precision range.

The straight-through estimator passes gradients unchanged inside the clip
range [-s, s] and zeroes them outside (DoReFa-style), implemented via a
custom_vjp so `jax.grad` of any model composes correctly.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.qmatmul import qmatmul as qmatmul_pallas


@jax.custom_vjp
def qdot(a, w, q_fwd, q_bwd):
    """Quantized matmul with STE backward and q_bwd gradient quantization.

    Args:
      a: f32[m, k] activations.
      w: f32[k, n] weights.
      q_fwd: scalar forward bit-width (cycled by the CPT schedule).
      q_bwd: scalar backward (gradient) bit-width (pinned to q_max).
    """
    return qmatmul_pallas(a, w, q_fwd, q_fwd)


def _qdot_fwd(a, w, q_fwd, q_bwd):
    sa = ref.dynamic_scale(a)
    sw = ref.dynamic_scale(w)
    out = qmatmul_pallas(a, w, q_fwd, q_fwd, sa, sw)
    # Residuals: the *quantized* operands (what the hardware would have
    # seen) plus the STE clip scales.
    aq = ref.fake_quant(a, q_fwd, sa)
    wq = ref.fake_quant(w, q_fwd, sw)
    mask_a = ref.ste_mask(a, sa)
    mask_w = ref.ste_mask(w, sw)
    return out, (aq, wq, mask_a, mask_w, q_bwd)


def _qdot_bwd(res, g):
    aq, wq, mask_a, mask_w, q_bwd = res
    # Gradient quantization (paper Figure 1: g_q). Fixed q_bwd = q_max.
    gq = ref.fake_quant(g, q_bwd)
    da = (gq @ wq.T) * mask_a
    dw = (aq.T @ gq) * mask_w
    return da, dw, None, None


qdot.defvjp(_qdot_fwd, _qdot_bwd)


@jax.custom_vjp
def quant_ste(x, q):
    """Fake-quantize with straight-through gradients (identity in-range).

    Used where a tensor (not a matmul operand) must be quantized — e.g. the
    Q-Agg aggregation messages in the GNN models.
    """
    return ref.fake_quant(x, q)


def _quant_ste_fwd(x, q):
    s = ref.dynamic_scale(x)
    return ref.fake_quant(x, q, s), ref.ste_mask(x, s)


def _quant_ste_bwd(mask, g):
    return g * mask, None


quant_ste.defvjp(_quant_ste_fwd, _quant_ste_bwd)


@jax.custom_vjp
def bwd_quant(x, q_bwd):
    """Identity forward; quantizes the cotangent to q_bwd bits on the way
    back. Inserted after non-GEMM blocks so gradient quantization covers the
    whole backward pass, mirroring the paper's Figure 1."""
    return x


def _bwd_quant_fwd(x, q_bwd):
    return x, q_bwd


def _bwd_quant_bwd(q_bwd, g):
    return ref.fake_quant(g, q_bwd), None


bwd_quant.defvjp(_bwd_quant_fwd, _bwd_quant_bwd)

//! Adaptive precision policies through the whole orchestration stack,
//! exercised entirely with fabricated outcomes (no PJRT / AOT artifacts —
//! the CI `test-unit` tier). The fabricated runner drives the *real*
//! policy implementations through the real chunked feedback loop
//! (`common::sim_policy_outcome`), so what these tests pin down is the
//! property production depends on: adaptive cells are deterministic,
//! which makes them shard, kill/resume, and merge byte-identically
//! across the sequential and global schedulers — and their realized
//! mean-q / relative-cost figures survive the store, `cpt status`, gc,
//! and the stable CSVs unchanged.

mod common;

use std::collections::HashMap;
use std::path::Path;

use common::{
    fab_outcome, sim_legacy_outcome, sim_policy_outcome, sim_static_outcome,
    tmp_dir,
};
use cpt::coordinator::campaign::{
    self, run_campaign_global, CampaignMember, CampaignRunOpts,
    SchedulerKind, Status,
};
use cpt::coordinator::exec::{CellError, CellRunner, ExecMember};
use cpt::coordinator::read_manifest;
use cpt::prelude::*;
use cpt::util::json::Json;

/// Fabricated worker backend that honors the member's policy: adaptive
/// members run the real policy against the synthetic loss curve, static
/// members replay their schedule through a chunked StaticPolicy.
struct PolicyFabRunner;

fn sim_member_outcome(
    member: &ExecMember,
    cell: &SweepCell,
    index: usize,
) -> RunOutcome {
    let q_min = recipe(&member.model).unwrap().q_min;
    if member.policy.is_adaptive() {
        sim_policy_outcome(
            &member.model,
            &member.policy,
            q_min,
            cell,
            index,
            member.steps,
        )
    } else {
        sim_static_outcome(
            &member.model,
            q_min,
            cell,
            index,
            member.steps,
            member.cycles,
        )
    }
}

impl CellRunner for PolicyFabRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        Ok(sim_member_outcome(member, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (0, 0.0)
    }
}

fn adaptive_member(
    name: &str,
    policy: &str,
    trials: usize,
    steps: usize,
) -> CampaignMember {
    let mut s = SweepSpec::new("mlp");
    campaign::set_policy(&mut s, PolicySpec::parse(policy).unwrap(), false)
        .unwrap();
    s.q_maxes = vec![8.0];
    s.trials = trials;
    s.steps = Some(steps);
    CampaignMember { name: name.into(), spec: s, jobs: None }
}

fn static_member(name: &str, schedules: &[&str], steps: usize) -> CampaignMember {
    let mut s = SweepSpec::new("mlp");
    s.schedules = schedules.iter().map(|x| x.to_string()).collect();
    s.q_maxes = vec![8.0];
    s.trials = 1;
    s.steps = Some(steps);
    CampaignMember { name: name.into(), spec: s, jobs: None }
}

/// A mixed campaign: plateau-policy member, governor member, and a
/// schedule-suite member, all over one model.
fn mixed_campaign() -> CampaignSpec {
    CampaignSpec {
        name: "policy-mix".into(),
        run_dir: None,
        members: vec![
            adaptive_member("plat", "loss_plateau:patience=1,ema=1", 2, 24),
            adaptive_member("gov", "cost_governor:target=0.6", 2, 24),
            static_member("sched", &["CR", "RR"], 16),
        ],
    }
}

fn fingerprints() -> HashMap<String, String> {
    HashMap::from([("mlp".to_string(), "fp-mlp".to_string())])
}

fn opts(root: &Path, jobs: usize, resume: bool) -> CampaignRunOpts {
    CampaignRunOpts {
        root: root.to_path_buf(),
        shard: ShardId::single(),
        jobs,
        resume,
        verbose: false,
        scheduler: SchedulerKind::Global,
    }
}

/// Ground truth for one member: the simulator applied to its canonical
/// cell list (what a serial, unsharded run computes).
fn ground_truth(m: &CampaignMember) -> Vec<RunOutcome> {
    let plan = SweepPlan::build(&m.spec).unwrap();
    let exec_member = ExecMember {
        name: m.name.clone(),
        model: m.spec.model.clone(),
        fingerprint: "fp-mlp".into(),
        policy: m.spec.policy.clone(),
        steps: plan.steps,
        cycles: plan.cycles,
        eval_every: m.spec.eval_every,
        cap: 1,
    };
    plan.cells
        .iter()
        .enumerate()
        .map(|(i, c)| sim_member_outcome(&exec_member, c, i))
        .collect()
}

fn write_csvs(dir: &Path, members: &[(String, Vec<RunOutcome>)]) {
    let mut keyed = Vec::new();
    for (name, outs) in members {
        let rows = aggregate(outs);
        SweepReport::new(name, "metric", true)
            .write_csv_stable(&rows, dir.join(format!("{name}.csv")))
            .unwrap();
        keyed.push((name.clone(), rows));
    }
    SweepReport::write_campaign_csv(&keyed, dir.join("campaign.csv")).unwrap();
}

fn keyed(
    r: &cpt::coordinator::campaign::CampaignRunResult,
) -> Vec<(String, Vec<RunOutcome>)> {
    r.members
        .iter()
        .map(|m| (m.name.clone(), m.outcomes.clone()))
        .collect()
}

#[test]
fn adaptive_cells_are_byte_identical_across_schedulers() {
    let tmp = tmp_dir("policy_equiv");
    let cspec = mixed_campaign();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints();

    // one-worker pool == sequential execution of the same store path
    let seq_root = tmp.join("seq");
    let seq =
        run_campaign_global(&plan, &opts(&seq_root, 1, false), &fps, None, |_| {
            Ok(PolicyFabRunner)
        })
        .unwrap();
    // global scheduler, overlapping workers
    let glob_root = tmp.join("glob");
    let glob =
        run_campaign_global(&plan, &opts(&glob_root, 3, false), &fps, None, |_| {
            Ok(PolicyFabRunner)
        })
        .unwrap();

    // members arrive in canonical (name-sorted) order: gov, plat, sched
    let by_name: HashMap<&str, &CampaignMember> =
        cspec.members.iter().map(|m| (m.name.as_str(), m)).collect();
    for result in [&seq, &glob] {
        assert_eq!(result.members.len(), 3);
        for m in &result.members {
            common::assert_outcomes_identical(
                &ground_truth(by_name[m.name.as_str()]),
                &m.outcomes,
            );
        }
    }

    let dir_seq = tmp.join("csv_seq");
    let dir_glob = tmp.join("csv_glob");
    write_csvs(&dir_seq, &keyed(&seq));
    write_csvs(&dir_glob, &keyed(&glob));
    for f in ["plat.csv", "gov.csv", "sched.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(dir_seq.join(f)).unwrap(),
            std::fs::read(dir_glob.join(f)).unwrap(),
            "{f} differs between schedulers"
        );
    }

    // the adaptive members' realized figures are meaningful: the plateau
    // member moved precision (mean_q strictly between q_min/q_max and
    // 1.0), and the governor landed on its cost target
    let plat = seq.members.iter().find(|m| m.name == "plat").unwrap();
    for o in &plat.outcomes {
        assert!(
            o.mean_q > 3.0 / 8.0 && o.mean_q < 1.0,
            "plateau member never switched: mean_q {}",
            o.mean_q
        );
    }
    let gov = seq.members.iter().find(|m| m.name == "gov").unwrap();
    for o in &gov.outcomes {
        assert!(
            (o.realized_cost - 0.6).abs() < 0.08,
            "governor missed its target: realized {}",
            o.realized_cost
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn adaptive_campaign_kill_resume_completes_identically() {
    let tmp = tmp_dir("policy_kill");
    let cspec = mixed_campaign();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints();
    let root = tmp.join("root");

    let err = run_campaign_global(
        &plan,
        &opts(&root, 2, false),
        &fps,
        Some(2),
        |_| Ok(PolicyFabRunner),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("halted after"), "{err:#}");
    match campaign::status(&root).unwrap() {
        Status::Campaign(c) => assert_eq!(c.done(), 2),
        _ => panic!("expected campaign status"),
    }

    let resumed = run_campaign_global(
        &plan,
        &opts(&root, 2, true),
        &fps,
        None,
        |_| Ok(PolicyFabRunner),
    )
    .unwrap();
    assert_eq!(resumed.total_resumed(), 2);
    let by_name: HashMap<&str, &CampaignMember> =
        cspec.members.iter().map(|m| (m.name.as_str(), m)).collect();
    for m in &resumed.members {
        common::assert_outcomes_identical(
            &ground_truth(by_name[m.name.as_str()]),
            &m.outcomes,
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn adaptive_shards_merge_identically_and_survive_gc() {
    let tmp = tmp_dir("policy_shard");
    let cspec = mixed_campaign();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints();

    // unsharded reference CSVs
    let ref_root = tmp.join("ref");
    let reference =
        run_campaign_global(&plan, &opts(&ref_root, 2, false), &fps, None, |_| {
            Ok(PolicyFabRunner)
        })
        .unwrap();
    let ref_csv = tmp.join("csv_ref");
    write_csvs(&ref_csv, &keyed(&reference));

    // 2 shards, then cross-merge the roots
    let mut roots = Vec::new();
    for index in 1..=2usize {
        let root = tmp.join(format!("shard{index}"));
        let mut o = opts(&root, 2, false);
        o.shard = ShardId { index, count: 2 };
        run_campaign_global(&plan, &o, &fps, None, |_| Ok(PolicyFabRunner))
            .unwrap();
        roots.push(root);
    }
    let merged = merge_campaign_roots(&roots).unwrap();
    let merged_members: Vec<(String, Vec<RunOutcome>)> = merged
        .members
        .iter()
        .map(|m| (m.name.clone(), m.outcomes.clone()))
        .collect();
    let merged_csv = tmp.join("csv_merged");
    write_csvs(&merged_csv, &merged_members);
    for f in ["plat.csv", "gov.csv", "sched.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(ref_csv.join(f)).unwrap(),
            std::fs::read(merged_csv.join(f)).unwrap(),
            "{f}: sharded merge differs from the unsharded run"
        );
    }

    // gc both roots: per-step histories (including the precision trace)
    // are stripped, but the realized columns come from the kept scalars,
    // so the re-merged CSVs must not change by a byte
    for root in &roots {
        let stats = campaign::gc(root).unwrap();
        assert!(stats.iter().any(|(_, s)| s.compacted > 0));
    }
    let remerged = merge_campaign_roots(&roots).unwrap();
    let remerged_members: Vec<(String, Vec<RunOutcome>)> = remerged
        .members
        .iter()
        .map(|m| (m.name.clone(), m.outcomes.clone()))
        .collect();
    let gc_csv = tmp.join("csv_gc");
    write_csvs(&gc_csv, &remerged_members);
    for f in ["plat.csv", "gov.csv", "sched.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(merged_csv.join(f)).unwrap(),
            std::fs::read(gc_csv.join(f)).unwrap(),
            "{f} changed across gc"
        );
    }
    // and the precision histories really are gone
    let one = remerged
        .members
        .iter()
        .flat_map(|m| &m.outcomes)
        .next()
        .unwrap();
    assert!(one.history.precisions.is_empty(), "gc kept the trace");
    assert!(one.mean_q > 0.0, "trace summary must survive gc");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn static_policy_csv_is_byte_identical_to_the_legacy_schedule_path() {
    // the pre-policy rendition (Schedule::q_vec directly) vs the policy
    // machinery (chunked StaticPolicy emission) over a full sweep: same
    // outcomes, same CSV bytes
    let tmp = tmp_dir("policy_static_equiv");
    let mut spec = SweepSpec::new("mlp");
    spec.schedules =
        vec!["CR".into(), "RR".into(), "ETH".into(), "STATIC".into()];
    spec.q_maxes = vec![6.0, 8.0];
    spec.trials = 2;
    spec.steps = Some(24);
    let plan = SweepPlan::build(&spec).unwrap();
    let q_min = recipe("mlp").unwrap().q_min;
    let legacy: Vec<RunOutcome> = plan
        .cells
        .iter()
        .enumerate()
        .map(|(i, c)| {
            sim_legacy_outcome("mlp", q_min, c, i, plan.steps, plan.cycles)
        })
        .collect();
    let via_policy: Vec<RunOutcome> = plan
        .cells
        .iter()
        .enumerate()
        .map(|(i, c)| {
            sim_static_outcome("mlp", q_min, c, i, plan.steps, plan.cycles)
        })
        .collect();
    common::assert_outcomes_identical(&legacy, &via_policy);
    let rep = SweepReport::new("equiv", "metric", true);
    let pa = tmp.join("legacy.csv");
    let pb = tmp.join("policy.csv");
    rep.write_csv_stable(&aggregate(&legacy), &pa).unwrap();
    rep.write_csv_stable(&aggregate(&via_policy), &pb).unwrap();
    assert_eq!(
        std::fs::read(&pa).unwrap(),
        std::fs::read(&pb).unwrap(),
        "StaticSuite-through-policy CSV differs from the legacy path"
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn status_surfaces_realized_trace_and_falls_back_on_old_manifests() {
    let tmp = tmp_dir("policy_status");
    let mut spec = SweepSpec::new("mlp");
    campaign::set_policy(
        &mut spec,
        PolicySpec::parse("cost_governor:target=0.6").unwrap(),
        false,
    )
    .unwrap();
    spec.q_maxes = vec![8.0];
    spec.trials = 2;
    spec.steps = Some(24);
    spec.shard = Some(ShardId::single());
    let plan = SweepPlan::build(&spec).unwrap();
    let dir = tmp.join("run");
    let mut st = RunStore::open(&dir, &plan, "fp-mlp", false).unwrap();
    let q_min = recipe("mlp").unwrap().q_min;
    for pc in plan.owned() {
        let out = sim_policy_outcome(
            "mlp", &spec.policy, q_min, &pc.cell, pc.index, plan.steps,
        );
        st.record(pc.index, &out).unwrap();
    }
    // status reads the realized figures straight from the manifest
    match campaign::status(&dir).unwrap() {
        Status::Sweep(m) => {
            let mq = m.mean_q().expect("mean_q on a policy-era manifest");
            let rc = m.realized_cost().expect("realized_cost");
            assert!(mq > 0.0 && mq <= 1.0, "{mq}");
            assert!((rc - 0.6).abs() < 0.08, "{rc}");
        }
        _ => panic!("expected sweep status"),
    }
    // strip the summary keys (a pre-policy manifest): status must fall
    // back silently, not error
    let mp = dir.join("run-manifest.json");
    let mut doc = Json::parse(&std::fs::read_to_string(&mp).unwrap()).unwrap();
    if let Json::Obj(top) = &mut doc {
        if let Some(Json::Obj(cells)) = top.get_mut("cells") {
            for cell in cells.values_mut() {
                if let Json::Obj(e) = cell {
                    e.remove("mean_q");
                    e.remove("realized_cost");
                }
            }
        }
    }
    std::fs::write(&mp, doc.to_string_pretty()).unwrap();
    match campaign::status(&dir).unwrap() {
        Status::Sweep(m) => {
            assert_eq!(m.mean_q(), None);
            assert_eq!(m.realized_cost(), None);
            assert_eq!(m.done(), 2, "progress reporting is unaffected");
        }
        _ => panic!("expected sweep status"),
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn adaptive_artifacts_round_trip_the_realized_figures_bit_exactly() {
    let tmp = tmp_dir("policy_roundtrip");
    let mut spec = SweepSpec::new("mlp");
    campaign::set_policy(
        &mut spec,
        PolicySpec::parse("loss_plateau:patience=1,ema=1").unwrap(),
        false,
    )
    .unwrap();
    spec.q_maxes = vec![8.0];
    spec.trials = 1;
    spec.steps = Some(24);
    let plan = SweepPlan::build(&spec).unwrap();
    let dir = tmp.join("run");
    let mut st = RunStore::open(&dir, &plan, "fp-mlp", false).unwrap();
    let q_min = recipe("mlp").unwrap().q_min;
    let out = sim_policy_outcome(
        "mlp", &spec.policy, q_min, &plan.cells[0], 0, plan.steps,
    );
    st.record(0, &out).unwrap();
    let back = st.load_outcome(0).unwrap();
    common::assert_outcomes_identical(
        std::slice::from_ref(&out),
        std::slice::from_ref(&back),
    );
    // the manifest entry's compact summary matches the artifact exactly
    let m = read_manifest(&dir).unwrap();
    let e = m.cells.get(&0).unwrap();
    assert_eq!(e.mean_q.unwrap().to_bits(), out.mean_q.to_bits());
    assert_eq!(
        e.realized_cost.unwrap().to_bits(),
        out.realized_cost.to_bits()
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn fab_outcome_still_round_trips() {
    // guard the shared fixture: the store round-trips the extended
    // outcome (other fabricated tiers lean on this helper)
    let tmp = tmp_dir("policy_fab");
    let mut spec = SweepSpec::new("mlp");
    spec.schedules = vec!["CR".into()];
    spec.q_maxes = vec![8.0];
    spec.trials = 1;
    spec.steps = Some(8);
    let plan = SweepPlan::build(&spec).unwrap();
    let dir = tmp.join("run");
    let mut st = RunStore::open(&dir, &plan, "fp", false).unwrap();
    let out = fab_outcome("mlp", &plan.cells[0], 0);
    st.record(0, &out).unwrap();
    let back = st.load_outcome(0).unwrap();
    common::assert_outcomes_identical(
        std::slice::from_ref(&out),
        std::slice::from_ref(&back),
    );
    std::fs::remove_dir_all(&tmp).ok();
}

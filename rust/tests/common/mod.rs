//! Shared fixtures for the integration test crates.
//!
//! Two tiers live here:
//! * the PJRT fixture ([`fixture`]) for tests that really train — it
//!   needs `make artifacts` to have run;
//! * fabricated-outcome builders ([`fab_outcome`], [`tiny_mlp_spec`],
//!   [`tmp_dir`]) for store/campaign tests that exercise planning,
//!   persistence, and merging without touching the runtime — these run
//!   on any machine (the CI `test-unit` tier).
//!
//! Each test crate compiles this module independently, so not every
//! helper is used everywhere.
#![allow(dead_code)]

use std::path::PathBuf;

use cpt::metrics::History;
use cpt::prelude::*;
use cpt::schedule::{
    group_of, mean_relative_q_of_trace, relative_cost_of_trace,
};

/// Per-test PJRT fixture (PJRT handles are not Sync, so no shared state).
pub struct Fixture {
    pub rt: Runtime,
    pub manifest: Manifest,
}

pub fn fixture() -> Fixture {
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load(cpt::artifacts_dir()).expect(
        "artifacts/manifest.json missing — run `make artifacts` first",
    );
    Fixture { rt, manifest }
}

/// A fresh temp directory for one test (removed up-front so a crashed
/// previous run cannot leak state in).
pub fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cpt_it_{name}"));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// The tiny MLP sweep every coordinator test runs: small enough to train
/// in well under a second per cell, rich enough (3 schedules × 2 trials)
/// to exercise sharding and aggregation.
pub fn tiny_mlp_spec() -> SweepSpec {
    let mut s = SweepSpec::new("mlp");
    s.schedules = vec!["CR".into(), "RR".into(), "STATIC".into()];
    s.q_maxes = vec![8.0];
    s.trials = 2;
    s.steps = Some(12);
    s.eval_every = 6;
    s
}

/// Fabricate a deterministic `RunOutcome` for a planned cell — the
/// store/campaign tests persist and merge these without training. Values
/// are index-dependent so misplaced cells cannot pass by coincidence,
/// and histories are non-empty so compaction has something to strip.
pub fn fab_outcome(model: &str, cell: &SweepCell, index: usize) -> RunOutcome {
    RunOutcome {
        model: model.to_string(),
        schedule: cell.schedule.clone(),
        group: group_of(&cell.schedule).label().into(),
        q_max: cell.q_max,
        trial: cell.trial,
        gbitops: 1.5 + index as f64 * 0.1,
        metric: 0.5 + index as f64 * 0.0625,
        eval_loss: 0.125,
        steps: 8,
        mean_q: 0.6875 + index as f64 * 0.015625,
        realized_cost: 0.5 + index as f64 * 0.03125,
        exec_seconds: 0.25,
        history: History {
            losses: vec![(0, 1.25), (1, 0.5 + index as f32 * 0.125)],
            metrics: vec![(0, 0.1)],
            evals: vec![(1, 0.75, 0.875)],
            precisions: vec![(0, 3), (1, 8)],
            gbitops: 1.5 + index as f64 * 0.1,
            mean_q: 0.6875 + index as f64 * 0.015625,
            realized_cost: 0.5 + index as f64 * 0.03125,
            exec_seconds: 0.25,
            total_seconds: 0.5,
        },
    }
}

/// Chunk size the policy simulators use (mirrors a model's trainer chunk
/// without needing a compiled model).
pub const SIM_CHUNK: usize = 4;

/// The synthetic per-step training loss the policy simulators feed back:
/// decays for the first half of the run, then plateaus — so plateau
/// policies demonstrably switch — with a small cell-identity offset so a
/// misrouted artifact cannot reproduce another cell's trace by accident.
pub fn sim_loss(cell: &SweepCell, index: usize, t: usize, steps: usize) -> f32 {
    let knee = (steps / 2).max(1);
    let tt = t.min(knee) as f32;
    2.0 / (1.0 + 0.5 * tt)
        + 0.001 * ((index * 13 + cell.trial * 7) % 5) as f32
}

/// Build a deterministic `RunOutcome` from a realized precision trace +
/// loss curve. All trace-derived figures (mean_q, realized_cost, the
/// precisions history) come from the trace itself, so two execution
/// paths agree iff their traces agree.
pub fn outcome_from_trace(
    model: &str,
    cell: &SweepCell,
    index: usize,
    qs: &[u32],
    losses: &[(usize, f32)],
) -> RunOutcome {
    let mean_q = mean_relative_q_of_trace(qs, cell.q_max);
    let realized_cost = relative_cost_of_trace(qs, cell.q_max);
    let gbitops = realized_cost * qs.len() as f64 * 0.01;
    let metric = 0.25 + 0.5 * mean_q + 0.001 * index as f64;
    RunOutcome {
        model: model.to_string(),
        schedule: cell.schedule.clone(),
        group: group_of(&cell.schedule).label().into(),
        q_max: cell.q_max,
        trial: cell.trial,
        gbitops,
        metric,
        eval_loss: losses.last().map(|&(_, l)| l).unwrap_or(0.5) as f64,
        steps: qs.len(),
        mean_q,
        realized_cost,
        exec_seconds: 0.125,
        history: History {
            losses: losses.to_vec(),
            metrics: Vec::new(),
            evals: vec![(qs.len(), 0.5, 0.75)],
            precisions: qs.iter().enumerate().map(|(t, &q)| (t, q)).collect(),
            gbitops,
            mean_q,
            realized_cost,
            exec_seconds: 0.125,
            total_seconds: 0.25,
        },
    }
}

/// Fabricate an *adaptive* cell outcome without PJRT: drive the real
/// policy implementation through the real chunked feedback loop against
/// the synthetic loss curve, then derive the outcome from the emitted
/// trace. Pure function of (policy, cell, index, steps) — exactly the
/// determinism contract production relies on — so any two schedulers,
/// shards, or resume passes must reproduce it bit-for-bit.
pub fn sim_policy_outcome(
    model: &str,
    policy: &PolicySpec,
    q_min: f64,
    cell: &SweepCell,
    index: usize,
    steps: usize,
) -> RunOutcome {
    let mut pol = policy
        .build_adaptive(q_min, cell.q_max, steps)
        .expect("adaptive policy");
    let mut qs: Vec<u32> = Vec::with_capacity(steps);
    let mut losses: Vec<(usize, f32)> = Vec::with_capacity(steps);
    let mut step = 0usize;
    while step < steps {
        let k = SIM_CHUNK.min(steps - step);
        let qv = pol.q_chunk(step, k);
        assert_eq!(qv.len(), k);
        let chunk_losses: Vec<f32> = (0..k)
            .map(|i| sim_loss(cell, index, step + i, steps))
            .collect();
        for (i, &q) in qv.iter().enumerate() {
            qs.push(q as u32);
            losses.push((step + i, chunk_losses[i]));
        }
        // the shared fold guarantees the sim feeds back exactly what the
        // production trainer would for the same losses
        pol.observe(ChunkFeedback::from_losses(step, &chunk_losses));
        step += k;
    }
    outcome_from_trace(model, cell, index, &qs, &losses)
}

/// Fabricate a *schedule-driven* cell outcome the same way, emitting the
/// trace through a chunked StaticPolicy — the policy-machinery rendition
/// of the legacy path (sim_legacy_outcome is the schedule-direct one).
pub fn sim_static_outcome(
    model: &str,
    q_min: f64,
    cell: &SweepCell,
    index: usize,
    steps: usize,
    cycles: usize,
) -> RunOutcome {
    let sched = cpt::coordinator::make_schedule(
        &cell.schedule,
        q_min,
        cell.q_max,
        steps,
        cycles,
    )
    .expect("suite schedule");
    let mut pol = StaticPolicy::new(sched);
    let mut qs: Vec<u32> = Vec::with_capacity(steps);
    let mut losses: Vec<(usize, f32)> = Vec::with_capacity(steps);
    let mut step = 0usize;
    while step < steps {
        let k = SIM_CHUNK.min(steps - step);
        for (i, q) in pol.q_chunk(step, k).into_iter().enumerate() {
            qs.push(q as u32);
            losses.push((step + i, sim_loss(cell, index, step + i, steps)));
        }
        step += k;
    }
    outcome_from_trace(model, cell, index, &qs, &losses)
}

/// The pre-policy rendition of a schedule-driven cell: materialize the
/// schedule directly (`Schedule::q_vec`, no policy machinery). The
/// StaticSuite equivalence test diffs its CSV bytes against
/// [`sim_static_outcome`]'s.
pub fn sim_legacy_outcome(
    model: &str,
    q_min: f64,
    cell: &SweepCell,
    index: usize,
    steps: usize,
    cycles: usize,
) -> RunOutcome {
    let sched = cpt::coordinator::make_schedule(
        &cell.schedule,
        q_min,
        cell.q_max,
        steps,
        cycles,
    )
    .expect("suite schedule");
    let qs: Vec<u32> = sched.q_vec(0, steps).iter().map(|&q| q as u32).collect();
    let losses: Vec<(usize, f32)> = (0..steps)
        .map(|t| (t, sim_loss(cell, index, t, steps)))
        .collect();
    outcome_from_trace(model, cell, index, &qs, &losses)
}

/// Strict outcome equality: every reported number bitwise, including the
/// full training history.
pub fn assert_outcomes_identical(a: &[RunOutcome], b: &[RunOutcome]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.model, y.model);
        assert_eq!(x.schedule, y.schedule);
        assert_eq!(x.q_max.to_bits(), y.q_max.to_bits());
        assert_eq!(x.trial, y.trial);
        assert_eq!(
            x.metric.to_bits(),
            y.metric.to_bits(),
            "{} t{}",
            x.schedule,
            x.trial
        );
        assert_eq!(x.eval_loss.to_bits(), y.eval_loss.to_bits());
        assert_eq!(x.gbitops.to_bits(), y.gbitops.to_bits());
        assert_eq!(x.mean_q.to_bits(), y.mean_q.to_bits());
        assert_eq!(x.realized_cost.to_bits(), y.realized_cost.to_bits());
        assert_eq!(x.group, y.group);
        assert_eq!(x.steps, y.steps);
        assert_eq!(x.history.losses, y.history.losses);
        assert_eq!(x.history.metrics, y.history.metrics);
        assert_eq!(x.history.precisions, y.history.precisions);
        assert_eq!(x.history.evals, y.history.evals);
    }
}

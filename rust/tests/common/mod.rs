//! Shared fixtures for the integration test crates.
//!
//! Two tiers live here:
//! * the PJRT fixture ([`fixture`]) for tests that really train — it
//!   needs `make artifacts` to have run;
//! * fabricated-outcome builders ([`fab_outcome`], [`tiny_mlp_spec`],
//!   [`tmp_dir`]) for store/campaign tests that exercise planning,
//!   persistence, and merging without touching the runtime — these run
//!   on any machine (the CI `test-unit` tier).
//!
//! Each test crate compiles this module independently, so not every
//! helper is used everywhere.
#![allow(dead_code)]

use std::path::PathBuf;

use cpt::metrics::History;
use cpt::prelude::*;
use cpt::schedule::group_of;

/// Per-test PJRT fixture (PJRT handles are not Sync, so no shared state).
pub struct Fixture {
    pub rt: Runtime,
    pub manifest: Manifest,
}

pub fn fixture() -> Fixture {
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load(cpt::artifacts_dir()).expect(
        "artifacts/manifest.json missing — run `make artifacts` first",
    );
    Fixture { rt, manifest }
}

/// A fresh temp directory for one test (removed up-front so a crashed
/// previous run cannot leak state in).
pub fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cpt_it_{name}"));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// The tiny MLP sweep every coordinator test runs: small enough to train
/// in well under a second per cell, rich enough (3 schedules × 2 trials)
/// to exercise sharding and aggregation.
pub fn tiny_mlp_spec() -> SweepSpec {
    let mut s = SweepSpec::new("mlp");
    s.schedules = vec!["CR".into(), "RR".into(), "STATIC".into()];
    s.q_maxes = vec![8.0];
    s.trials = 2;
    s.steps = Some(12);
    s.eval_every = 6;
    s
}

/// Fabricate a deterministic `RunOutcome` for a planned cell — the
/// store/campaign tests persist and merge these without training. Values
/// are index-dependent so misplaced cells cannot pass by coincidence,
/// and histories are non-empty so compaction has something to strip.
pub fn fab_outcome(model: &str, cell: &SweepCell, index: usize) -> RunOutcome {
    RunOutcome {
        model: model.to_string(),
        schedule: cell.schedule.clone(),
        group: group_of(&cell.schedule).label().into(),
        q_max: cell.q_max,
        trial: cell.trial,
        gbitops: 1.5 + index as f64 * 0.1,
        metric: 0.5 + index as f64 * 0.0625,
        eval_loss: 0.125,
        steps: 8,
        exec_seconds: 0.25,
        history: History {
            losses: vec![(0, 1.25), (1, 0.5 + index as f32 * 0.125)],
            metrics: vec![(0, 0.1)],
            evals: vec![(1, 0.75, 0.875)],
            precisions: vec![(0, 3), (1, 8)],
            gbitops: 1.5 + index as f64 * 0.1,
            exec_seconds: 0.25,
            total_seconds: 0.5,
        },
    }
}

/// Strict outcome equality: every reported number bitwise, including the
/// full training history.
pub fn assert_outcomes_identical(a: &[RunOutcome], b: &[RunOutcome]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.model, y.model);
        assert_eq!(x.schedule, y.schedule);
        assert_eq!(x.q_max.to_bits(), y.q_max.to_bits());
        assert_eq!(x.trial, y.trial);
        assert_eq!(
            x.metric.to_bits(),
            y.metric.to_bits(),
            "{} t{}",
            x.schedule,
            x.trial
        );
        assert_eq!(x.eval_loss.to_bits(), y.eval_loss.to_bits());
        assert_eq!(x.gbitops.to_bits(), y.gbitops.to_bits());
        assert_eq!(x.group, y.group);
        assert_eq!(x.steps, y.steps);
        assert_eq!(x.history.losses, y.history.losses);
        assert_eq!(x.history.metrics, y.history.metrics);
        assert_eq!(x.history.precisions, y.history.precisions);
        assert_eq!(x.history.evals, y.history.evals);
    }
}

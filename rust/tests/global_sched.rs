//! Global campaign scheduler, exercised entirely through fabricated
//! outcomes (no PJRT / AOT artifacts — the CI `test-unit` tier): the
//! shared worker pool over all members must persist, resume, and report
//! byte-for-byte what sequential execution produces, respect per-member
//! concurrency caps, and survive per-worker compile failures.

mod common;

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::anyhow;
use common::{fab_outcome, tmp_dir};
use cpt::coordinator::campaign::{
    self, read_campaign_manifest, run_campaign_global, CampaignMember,
    CampaignRunOpts, SchedulerKind, Status,
};
use cpt::coordinator::exec::{CellError, CellRunner, ExecMember};
use cpt::coordinator::read_manifest;
use cpt::prelude::*;
use cpt::util::propcheck::propcheck;

/// Fabricated worker backend: deterministic outcomes (shared with the
/// other fabricated tests via `common::fab_outcome`), a simulated
/// compile cache, optional injected compile failures, and an optional
/// per-member concurrency gauge.
struct FabRunner {
    /// Fingerprints this worker "fails to compile".
    fail: HashSet<String>,
    compiled: Vec<String>,
    compiles: usize,
    sleep_ms: u64,
    gauge: Option<Arc<Gauge>>,
}

impl FabRunner {
    fn plain() -> FabRunner {
        FabRunner {
            fail: HashSet::new(),
            compiled: Vec::new(),
            compiles: 0,
            sleep_ms: 0,
            gauge: None,
        }
    }
}

/// Concurrency high-water mark per member name.
struct Gauge {
    inner: Mutex<HashMap<String, (usize, usize)>>,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { inner: Mutex::new(HashMap::new()) }
    }

    fn enter(&self, name: &str) {
        let mut g = self.inner.lock().unwrap();
        let e = g.entry(name.to_string()).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.max(e.0);
    }

    fn exit(&self, name: &str) {
        self.inner.lock().unwrap().get_mut(name).unwrap().0 -= 1;
    }

    fn high_water(&self, name: &str) -> usize {
        self.inner.lock().unwrap().get(name).map_or(0, |e| e.1)
    }
}

impl CellRunner for FabRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        if self.fail.contains(&member.fingerprint) {
            return Err(CellError::Setup(anyhow!(
                "injected compile failure for '{}'",
                member.model
            )));
        }
        if !self.compiled.contains(&member.fingerprint) {
            self.compiled.push(member.fingerprint.clone());
            self.compiles += 1;
        }
        if let Some(g) = &self.gauge {
            g.enter(&member.name);
        }
        if self.sleep_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.sleep_ms,
            ));
        }
        if let Some(g) = &self.gauge {
            g.exit(&member.name);
        }
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiles, 0.0)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.compiled.iter().any(|f| f == fingerprint)
    }
}

fn member(
    name: &str,
    model: &str,
    schedules: &[&str],
    steps: usize,
) -> CampaignMember {
    let mut s = SweepSpec::new(model);
    s.schedules = schedules.iter().map(|x| x.to_string()).collect();
    s.q_maxes = vec![8.0];
    s.trials = 1;
    s.steps = Some(steps);
    CampaignMember { name: name.into(), spec: s, jobs: None }
}

/// Two members sharing one model — the executable-cache headline case.
fn shared_model_campaign() -> CampaignSpec {
    CampaignSpec {
        name: "gsched".into(),
        run_dir: None,
        members: vec![
            member("a", "mlp", &["CR", "RR"], 8),
            member("b", "mlp", &["CR", "STATIC"], 10),
        ],
    }
}

fn fingerprints_for(cspec: &CampaignSpec) -> HashMap<String, String> {
    cspec
        .members
        .iter()
        .map(|m| (m.spec.model.clone(), format!("fp-{}", m.spec.model)))
        .collect()
}

fn opts(root: &Path, jobs: usize, resume: bool) -> CampaignRunOpts {
    CampaignRunOpts {
        root: root.to_path_buf(),
        shard: ShardId::single(),
        jobs,
        resume,
        verbose: false,
        scheduler: SchedulerKind::Global,
    }
}

/// The full fabricated outcome list a sequential run of the member
/// produces (fabrication is deterministic, so this is the sequential
/// ground truth).
fn fab_member_outcomes(m: &CampaignMember) -> Vec<RunOutcome> {
    let plan = SweepPlan::build(&m.spec).unwrap();
    plan.cells
        .iter()
        .enumerate()
        .map(|(i, c)| fab_outcome(&m.spec.model, c, i))
        .collect()
}

/// Write the campaign's per-member stable CSVs + campaign.csv for a list
/// of (name, outcomes) into `dir`.
fn write_csvs(dir: &Path, members: &[(String, Vec<RunOutcome>)]) {
    let mut keyed = Vec::new();
    for (name, outs) in members {
        let rows = aggregate(outs);
        SweepReport::new(name, "metric", true)
            .write_csv_stable(&rows, dir.join(format!("{name}.csv")))
            .unwrap();
        keyed.push((name.clone(), rows));
    }
    SweepReport::write_campaign_csv(&keyed, dir.join("campaign.csv")).unwrap();
}

#[test]
fn global_scheduler_is_byte_identical_to_sequential_execution() {
    let tmp = tmp_dir("gsched_equiv");
    let cspec = shared_model_campaign();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints_for(&cspec);

    // sequential-equivalent execution: the same store/manifest path with
    // a one-worker pool (fabrication is deterministic, so this equals a
    // member-by-member sequential run)
    let seq_root = tmp.join("seq");
    let seq = run_campaign_global(&plan, &opts(&seq_root, 1, false), &fps, None, |_| {
        Ok(FabRunner::plain())
    })
    .unwrap();
    // global scheduler: one pool over both members
    let glob_root = tmp.join("glob");
    let glob =
        run_campaign_global(&plan, &opts(&glob_root, 3, false), &fps, None, |_| {
            let mut r = FabRunner::plain();
            r.sleep_ms = 1; // force overlap so claims interleave
            Ok(r)
        })
        .unwrap();

    // outcome-level: both match the fabricated sequential ground truth
    for result in [&seq, &glob] {
        assert_eq!(result.members.len(), 2);
        for (m, cm) in result.members.iter().zip(&cspec.members) {
            assert_eq!(m.name, cm.name);
            common::assert_outcomes_identical(
                &fab_member_outcomes(cm),
                &m.outcomes,
            );
        }
    }

    // CSV-level: per-member CSVs and campaign.csv byte-identical
    let dir_seq = tmp.join("csv_seq");
    let dir_glob = tmp.join("csv_glob");
    let keyed = |r: &cpt::coordinator::campaign::CampaignRunResult| {
        r.members
            .iter()
            .map(|m| (m.name.clone(), m.outcomes.clone()))
            .collect::<Vec<_>>()
    };
    write_csvs(&dir_seq, &keyed(&seq));
    write_csvs(&dir_glob, &keyed(&glob));
    for f in ["a.csv", "b.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(dir_seq.join(f)).unwrap(),
            std::fs::read(dir_glob.join(f)).unwrap(),
            "{f} differs between sequential and global execution"
        );
    }

    // the shared model was compiled at most once per worker, and the
    // stats were recorded into the campaign manifest for `cpt status`
    let sc = glob.scheduler.as_ref().expect("global scheduler stats");
    assert!(sc.jobs <= 3);
    for w in &sc.workers {
        assert!(w.compiles <= 1, "worker recompiled a cached model: {w:?}");
    }
    let cm = read_campaign_manifest(&glob_root).unwrap();
    let recorded = cm.scheduler.expect("scheduler stats in manifest");
    assert_eq!(&recorded, sc);
    match campaign::status(&glob_root).unwrap() {
        Status::Campaign(c) => {
            assert_eq!(c.done(), 4);
            assert!(c.scheduler.is_some());
        }
        _ => panic!("expected campaign status"),
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn global_scheduler_kill_and_resume_is_byte_identical() {
    let tmp = tmp_dir("gsched_kill");
    let cspec = shared_model_campaign();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints_for(&cspec);
    let root = tmp.join("root");

    // kill after 2 freshly recorded cells (injected, not via env — other
    // tests in this process must not see a global halt counter)
    let err = run_campaign_global(
        &plan,
        &opts(&root, 2, false),
        &fps,
        Some(2),
        |_| Ok(FabRunner::plain()),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("halted after"), "{err:#}");

    // exactly the recorded cells are durable; status sees them
    match campaign::status(&root).unwrap() {
        Status::Campaign(c) => assert_eq!(c.done(), 2),
        _ => panic!("expected campaign status"),
    }

    // resume completes the remainder, reusing both recorded cells
    let resumed = run_campaign_global(
        &plan,
        &opts(&root, 2, true),
        &fps,
        None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    assert_eq!(resumed.total_resumed(), 2);
    assert_eq!(resumed.total_cells(), 4);
    for (m, cm) in resumed.members.iter().zip(&cspec.members) {
        common::assert_outcomes_identical(
            &fab_member_outcomes(cm),
            &m.outcomes,
        );
    }

    // a no-op resume (everything already recorded) must not overwrite
    // the manifest's scheduler stats with an empty record
    let recorded = read_campaign_manifest(&root)
        .unwrap()
        .scheduler
        .expect("stats after completing run");
    let noop = run_campaign_global(
        &plan,
        &opts(&root, 2, true),
        &fps,
        None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    assert_eq!(noop.total_resumed(), 4);
    assert_eq!(noop.scheduler.as_ref(), Some(&recorded));
    assert_eq!(
        read_campaign_manifest(&root).unwrap().scheduler,
        Some(recorded),
        "no-op resume must preserve the recorded pool accounting"
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn compile_failure_keeps_worker_alive_for_other_members() {
    // two models: worker 0 cannot compile cnn_tiny, worker 1 can compile
    // everything — the campaign still completes
    let tmp = tmp_dir("gsched_compile_fail");
    let cspec = CampaignSpec {
        name: "gs-fail".into(),
        run_dir: None,
        members: vec![
            member("a", "mlp", &["CR", "RR"], 8),
            member("b", "cnn_tiny", &["CR", "STATIC"], 8),
        ],
    };
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints_for(&cspec);
    let root = tmp.join("root");
    let result =
        run_campaign_global(&plan, &opts(&root, 2, false), &fps, None, |w| {
            let mut r = FabRunner::plain();
            if w == 0 {
                r.fail.insert("fp-cnn_tiny".into());
            }
            r.sleep_ms = 1;
            Ok(r)
        })
        .unwrap();
    for (m, cm) in result.members.iter().zip(&cspec.members) {
        common::assert_outcomes_identical(
            &fab_member_outcomes(cm),
            &m.outcomes,
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn unclaimed_member_fails_the_campaign_then_resume_completes_it() {
    // no worker can compile cnn_tiny: the campaign fails with the
    // compile error, but the compilable member's cells are durable —
    // a later resume (with working workers) picks them up
    let tmp = tmp_dir("gsched_unclaimed");
    let cspec = CampaignSpec {
        name: "gs-unclaimed".into(),
        run_dir: None,
        members: vec![
            member("a", "mlp", &["CR", "RR"], 8),
            member("b", "cnn_tiny", &["CR", "STATIC"], 8),
        ],
    };
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints_for(&cspec);
    let root = tmp.join("root");
    let err =
        run_campaign_global(&plan, &opts(&root, 2, false), &fps, None, |_| {
            let mut r = FabRunner::plain();
            r.fail.insert("fp-cnn_tiny".into());
            Ok(r)
        })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unclaimed"), "{msg}");
    assert!(msg.contains("injected compile failure"), "{msg}");

    // member a completed and was recorded despite the overall failure
    let ma = read_manifest(&root.join("a")).unwrap();
    assert_eq!(ma.done(), 2, "compilable member must have been recorded");

    let resumed =
        run_campaign_global(&plan, &opts(&root, 2, true), &fps, None, |_| {
            Ok(FabRunner::plain())
        })
        .unwrap();
    assert_eq!(resumed.total_resumed(), 2);
    for (m, cm) in resumed.members.iter().zip(&cspec.members) {
        common::assert_outcomes_identical(
            &fab_member_outcomes(cm),
            &m.outcomes,
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn per_member_jobs_cap_is_never_exceeded() {
    // Over random campaign shapes, pool sizes, and member caps: the
    // number of a member's cells in flight at once never exceeds
    // min(member jobs, pool jobs).
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    propcheck(8, |rng| {
        let case = CASE.fetch_add(1, Ordering::SeqCst);
        let root = tmp_dir(&format!("gsched_cap_{case}"));
        let n_members = 1 + rng.below(3) as usize;
        let jobs = 2 + rng.below(3) as usize;
        let mut members = Vec::new();
        for i in 0..n_members {
            let scheds: Vec<String> = (0..2 + rng.below(3))
                .map(|k| format!("M{i}S{k}"))
                .collect();
            let sched_refs: Vec<&str> =
                scheds.iter().map(|s| s.as_str()).collect();
            let mut m = member(&format!("m{i}"), "mlp", &sched_refs, 8);
            if rng.below(2) == 0 {
                m.jobs = Some(1 + rng.below(2) as usize);
            }
            members.push(m);
        }
        let cspec = CampaignSpec {
            name: "gs-cap".into(),
            run_dir: None,
            members,
        };
        let plan = CampaignPlan::build(&cspec).unwrap();
        let fps = fingerprints_for(&cspec);
        let gauge = Arc::new(Gauge::new());
        let result = run_campaign_global(
            &plan,
            &opts(&root, jobs, false),
            &fps,
            None,
            |_| {
                let mut r = FabRunner::plain();
                r.gauge = Some(gauge.clone());
                r.sleep_ms = 1;
                Ok(r)
            },
        )
        .unwrap();
        for cm in &cspec.members {
            let cap = cm.jobs.unwrap_or(jobs).min(jobs);
            let seen = gauge.high_water(&cm.name);
            cpt::prop_assert!(
                seen <= cap,
                "member '{}' ran {seen} cells at once (cap {cap})",
                cm.name
            );
        }
        cpt::prop_assert!(
            result.total_cells()
                == result
                    .members
                    .iter()
                    .map(|m| m.outcomes.len())
                    .sum::<usize>(),
            "incomplete member outcomes"
        );
        std::fs::remove_dir_all(&root).ok();
        Ok(())
    });
}

#[test]
fn store_routing_never_crosses_member_boundaries() {
    // Over random shapes and shards: each member's run dir records
    // exactly its own owned cells, and every artifact decodes to the
    // member's own fabricated outcome (cross-routing cannot pass because
    // fabricated values depend on the member's schedules and indices).
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    propcheck(10, |rng| {
        let case = CASE.fetch_add(1, Ordering::SeqCst);
        let root = tmp_dir(&format!("gsched_route_{case}"));
        let n_members = 1 + rng.below(3) as usize;
        let count = 1 + rng.below(3) as usize;
        let index = 1 + rng.below(count as u32) as usize;
        let shard = ShardId { index, count };
        let mut members = Vec::new();
        for i in 0..n_members {
            let scheds: Vec<String> = (0..1 + rng.below(4))
                .map(|k| format!("R{i}S{k}"))
                .collect();
            let sched_refs: Vec<&str> =
                scheds.iter().map(|s| s.as_str()).collect();
            let mut m =
                member(&format!("m{i}"), "mlp", &sched_refs, 8 + i);
            m.spec.trials = 1 + rng.below(2) as usize;
            members.push(m);
        }
        let cspec = CampaignSpec {
            name: "gs-route".into(),
            run_dir: None,
            members,
        };
        let plan = CampaignPlan::build(&cspec).unwrap();
        let fps = fingerprints_for(&cspec);
        let mut o = opts(&root, 3, false);
        o.shard = shard;
        run_campaign_global(&plan, &o, &fps, None, |_| Ok(FabRunner::plain()))
            .unwrap();
        for m in &plan.members {
            let mut s = m.spec.clone();
            s.shard = Some(shard);
            let mplan = SweepPlan::build(&s).unwrap();
            let ms = read_manifest(&root.join(&m.name)).unwrap();
            let want: Vec<usize> =
                mplan.owned().iter().map(|pc| pc.index).collect();
            let got: Vec<usize> = ms.cells.keys().copied().collect();
            cpt::prop_assert!(
                got == want,
                "member '{}' recorded cells {got:?}, owns {want:?}",
                m.name
            );
            // artifacts decode to this member's own fabricated outcomes
            let mut st = RunStore::open(
                &root.join(&m.name),
                &mplan,
                fps.get(&m.spec.model).unwrap(),
                true,
            )
            .unwrap();
            for pc in mplan.owned() {
                let out = st.take_valid_outcome(pc.index);
                let out = match out {
                    Some(o) => o,
                    None => return Err(format!(
                        "member '{}' cell {} artifact invalid",
                        m.name, pc.index
                    )),
                };
                let want = fab_outcome(&m.spec.model, &pc.cell, pc.index);
                cpt::prop_assert!(
                    out.metric.to_bits() == want.metric.to_bits()
                        && out.schedule == want.schedule
                        && out.trial == want.trial,
                    "member '{}' cell {} holds a foreign outcome",
                    m.name,
                    pc.index
                );
            }
        }
        std::fs::remove_dir_all(&root).ok();
        Ok(())
    });
}

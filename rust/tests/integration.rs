//! Integration tests: artifacts -> PJRT -> training loop, end to end.
//!
//! These need `make artifacts` to have run (the Makefile test target
//! guarantees it). Coordinator-level tests live in their own files:
//! tests/sweep_merge.rs (execution equivalence), tests/store_resume.rs
//! (crash/preempt resume), tests/campaign.rs (campaign planning/merge,
//! no PJRT needed). Shared fixtures are in tests/common/mod.rs.

mod common;

use common::fixture;
use cpt::coordinator::recipes;
use cpt::prelude::*;
use cpt::schedule::Schedule;

#[test]
fn manifest_lists_all_models() {
    let f = fixture();
    for &m in recipes::model_names() {
        let spec = f.manifest.model(m).unwrap();
        spec.validate().unwrap();
        assert!(spec.param_count > 0);
        assert_eq!(spec.chunk, f.manifest.chunk);
    }
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let a = model.init_state(1).unwrap();
    let b = model.init_state(1).unwrap();
    let c = model.init_state(2).unwrap();
    let va = &a.params.data;
    let vb = &b.params.data;
    let vc = &c.params.data;
    assert_eq!(va, vb, "same seed must give identical params");
    assert_ne!(va, vc, "different seeds must differ");
    assert_eq!(va.len(), model.spec.param_count);
    assert!(va.iter().all(|x| x.is_finite()));
}

#[test]
fn mlp_trains_to_high_accuracy() {
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let out = cpt::coordinator::run_one(
        &model, "mlp", "CR", 8.0, 0, 96, 8, 0, false,
    )
    .unwrap();
    assert!(
        out.metric > 0.9,
        "mlp should reach >90% accuracy, got {}",
        out.metric
    );
    // loss must broadly decrease
    let first = out.history.losses.first().unwrap().1;
    let last = out.history.tail_train_loss(8);
    assert!(last < first * 0.7, "loss {first} -> {last}");
}

#[test]
fn chunk_and_single_step_paths_agree() {
    // Running K steps via the chunk artifact must equal K single-step
    // calls (same data/schedule/seeds) — validates the lax.scan export.
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let k = model.spec.chunk;

    let mut data = dataset_for("mlp", 7).unwrap();
    let mut stacked_per_step = Vec::new();
    for i in 0..k {
        stacked_per_step.push(data.train_batch(i).unwrap());
    }

    let q: Vec<f32> = (0..k).map(|i| 3.0 + (i % 6) as f32).collect();
    let lr: Vec<f32> = vec![0.05; k];
    let seeds: Vec<i32> = (0..k as i32).collect();

    // chunk path
    let mut st_chunk = model.init_state(3).unwrap();
    let stacked: Vec<xla::Literal> = {
        let mut per_input: Vec<Vec<HostTensor>> = vec![Vec::new(); 2];
        for b in &stacked_per_step {
            for (slot, t) in per_input.iter_mut().zip(b.iter()) {
                slot.push(t.clone());
            }
        }
        per_input
            .iter()
            .map(|ts| HostTensor::stack(ts).unwrap().to_literal().unwrap())
            .collect()
    };
    let res_chunk = model
        .advance(&mut st_chunk, k, &stacked, &[], &q, &lr, &seeds, 8.0)
        .unwrap();

    // single-step path
    let mut st_step = model.init_state(3).unwrap();
    let mut losses = Vec::new();
    for i in 0..k {
        let stacked: Vec<xla::Literal> = stacked_per_step[i]
            .iter()
            .map(|t| {
                HostTensor::stack(std::slice::from_ref(t))
                    .unwrap()
                    .to_literal()
                    .unwrap()
            })
            .collect();
        let r = model
            .advance(
                &mut st_step,
                1,
                &stacked,
                &[],
                &q[i..i + 1],
                &lr[i..i + 1],
                &seeds[i..i + 1],
                8.0,
            )
            .unwrap();
        losses.push(r.losses[0]);
    }

    for (a, b) in res_chunk.losses.iter().zip(&losses) {
        assert!(
            (a - b).abs() < 1e-5,
            "chunk vs step loss mismatch: {a} vs {b}"
        );
    }
    let pc = &st_chunk.params.data;
    let ps = &st_step.params.data;
    let max_diff = pc
        .iter()
        .zip(ps)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "params diverge: {max_diff}");
}

#[test]
fn runtime_precision_changes_behavior() {
    // Same model, same data: training at q=3 vs q=8 must produce
    // different losses (proves q_t is live at runtime).
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();

    let run = |q: f32| -> Vec<f32> {
        let mut st = model.init_state(5).unwrap();
        let mut data = dataset_for("mlp", 9).unwrap();
        let mut all = Vec::new();
        for step in 0..2 {
            let k = model.spec.chunk;
            let mut per_input: Vec<Vec<HostTensor>> = vec![Vec::new(); 2];
            for i in 0..k {
                let b = data.train_batch(step * k + i).unwrap();
                for (slot, t) in per_input.iter_mut().zip(b) {
                    slot.push(t);
                }
            }
            let stacked: Vec<xla::Literal> = per_input
                .iter()
                .map(|ts| HostTensor::stack(ts).unwrap().to_literal().unwrap())
                .collect();
            let r = model
                .advance(
                    &mut st,
                    k,
                    &stacked,
                    &[],
                    &vec![q; k],
                    &vec![0.05; k],
                    &(0..k as i32).collect::<Vec<_>>(),
                    8.0,
                )
                .unwrap();
            all.extend(r.losses);
        }
        all
    };

    let l3 = run(3.0);
    let l8 = run(8.0);
    assert_ne!(l3, l8, "q=3 and q=8 training identical — q_t is dead");
}

#[test]
fn gcn_qagg_vs_fpagg_same_init_different_dynamics() {
    let f = fixture();
    let qagg = f.rt.load_model(f.manifest.model("gcn_qagg").unwrap()).unwrap();
    let fpagg =
        f.rt.load_model(f.manifest.model("gcn_fpagg").unwrap()).unwrap();
    // identical param spec
    assert_eq!(qagg.spec.param_count, fpagg.spec.param_count);

    let run = |model: &LoadedModel, name: &str| {
        cpt::coordinator::run_one(model, name, "STATIC", 4.0, 0, 24, 8, 0, false)
            .unwrap()
    };
    let a = run(&qagg, "gcn_qagg");
    let b = run(&fpagg, "gcn_fpagg");
    // at q=4 the aggregation strategy must matter
    let diff = a
        .history
        .losses
        .iter()
        .zip(&b.history.losses)
        .map(|((_, x), (_, y))| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(diff > 1e-5, "Q-Agg and FP-Agg identical at q=4");
}

#[test]
fn deficit_schedule_pins_low_precision_in_window() {
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let schedule = Schedule::deficit(3.0, 8.0, 8, 24);
    let mut data = dataset_for("mlp", 3).unwrap();
    let cfg = TrainConfig {
        total_steps: 32,
        q_bwd: 8.0,
        eval_every: 0,
        seed: 1,
        log_every: 1,
        verbose: false,
    };
    let mut t = Trainer::new(
        &model,
        data.as_mut(),
        schedule,
        LrSchedule::Constant { lr: 0.05 },
        cfg,
    );
    let hist = t.run().unwrap();
    for &(step, q) in &hist.precisions {
        let want = if (8..24).contains(&step) { 3 } else { 8 };
        assert_eq!(q, want, "step {step}");
    }
}

#[test]
fn eval_is_deterministic() {
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let st = model.init_state(0).unwrap();
    let mut data = dataset_for("mlp", 5).unwrap();
    let batch: Vec<xla::Literal> = data
        .eval_batch(0)
        .unwrap()
        .iter()
        .map(|t| t.to_literal().unwrap())
        .collect();
    let batch2: Vec<xla::Literal> = data
        .eval_batch(0)
        .unwrap()
        .iter()
        .map(|t| t.to_literal().unwrap())
        .collect();
    let (l1, m1) = model.evaluate(&st, &batch).unwrap();
    let (l2, m2) = model.evaluate(&st, &batch2).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(m1, m2);
}

#[test]
fn bitops_scale_with_schedule() {
    // A Large-group schedule must consume fewer GBitOps than STATIC on
    // the same run length (the paper's x-axis).
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let steps = 32;
    let rr = cpt::coordinator::run_one(
        &model, "mlp", "RR", 8.0, 0, steps, 8, 0, false,
    )
    .unwrap();
    let st = cpt::coordinator::run_one(
        &model, "mlp", "STATIC", 8.0, 0, steps, 8, 0, false,
    )
    .unwrap();
    assert!(
        rr.gbitops < st.gbitops * 0.85,
        "RR {} !< STATIC {}",
        rr.gbitops,
        st.gbitops
    );
}

#[test]
fn trainer_remainder_path_matches_all_single_steps() {
    // total_steps % chunk != 0 makes Trainer::run fall back to k=1 calls
    // for the tail. The whole run must match a manual all-single-step
    // replay with the same seed stream, data, and schedule — same
    // per-step losses, precisions, and BitOps.
    use cpt::util::prng::Pcg32;

    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let k = model.spec.chunk;
    assert!(k > 1, "remainder test needs chunk > 1");
    let total = k + 2;

    let cfg = TrainConfig {
        total_steps: total,
        q_bwd: 8.0,
        eval_every: 0,
        seed: 4,
        log_every: 1,
        verbose: false,
    };
    let mut data = dataset_for("mlp", 11).unwrap();
    let mut t = Trainer::new(
        &model,
        data.as_mut(),
        Schedule::static_q(8.0),
        LrSchedule::Constant { lr: 0.05 },
        cfg,
    );
    let hist = t.run().unwrap();
    assert_eq!(hist.losses.len(), total, "remainder steps must be logged");
    assert!(hist.precisions.iter().all(|&(_, q)| q == 8));

    // manual replay: all k=1 advances, same seed stream as the trainer
    // (it draws per-step seeds sequentially regardless of chunking)
    let mut st = model.init_state(4).unwrap();
    let mut seed_rng = Pcg32::new(4, 0x5EED);
    let mut data2 = dataset_for("mlp", 11).unwrap();
    let mut losses = Vec::new();
    for step in 0..total {
        let seeds = vec![seed_rng.next_u32() as i32];
        let batch = data2.train_batch(step).unwrap();
        let stacked: Vec<xla::Literal> = batch
            .iter()
            .map(|t| {
                HostTensor::stack(std::slice::from_ref(t))
                    .unwrap()
                    .to_literal()
                    .unwrap()
            })
            .collect();
        let r = model
            .advance(&mut st, 1, &stacked, &[], &[8.0], &[0.05], &seeds, 8.0)
            .unwrap();
        losses.push(r.losses[0]);
    }

    for (i, (&(step, l), &lm)) in
        hist.losses.iter().zip(&losses).enumerate()
    {
        assert_eq!(step, i);
        assert!(
            (l - lm).abs() < 1e-4,
            "step {i}: trainer {l} vs manual {lm}"
        );
    }

    // BitOps must account all `total` steps at q=8
    let mut acc = BitOpsAccountant::new(&model.spec, 8.0, 1.0);
    acc.record_steps(&vec![8.0f32; total]);
    let want = acc.total().gbitops;
    assert!(
        (hist.gbitops - want).abs() < 1e-9,
        "gbitops {} vs {}",
        hist.gbitops,
        want
    );
}

#[test]
fn static_dataset_literal_caching_preserves_results() {
    // shared_static() lets the trainer convert eval batches to literals
    // once; the cached path must not change any reported number vs a
    // fresh trainer run (eval batches are deterministic per index).
    let f = fixture();
    let model = f.rt.load_model(f.manifest.model("mlp").unwrap()).unwrap();
    let run = || {
        let mut data = dataset_for("mlp", 13).unwrap();
        assert!(data.shared_static(), "mlp dataset should be static");
        let cfg = TrainConfig {
            total_steps: 16,
            q_bwd: 8.0,
            eval_every: 4, // several evals -> cache is exercised
            seed: 2,
            log_every: 1,
            verbose: false,
        };
        let mut t = Trainer::new(
            &model,
            data.as_mut(),
            Schedule::static_q(8.0),
            LrSchedule::Constant { lr: 0.05 },
            cfg,
        );
        t.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.losses, b.losses);
}

//! Campaign planning, persistence, status, gc, and merge — exercised
//! entirely through fabricated outcomes, so this file runs without the
//! PJRT runtime or AOT artifacts (the CI `test-unit` tier).

mod common;

use std::path::Path;

use common::{fab_outcome, tmp_dir};
use cpt::coordinator::campaign::{self, CampaignMember, Status};
use cpt::coordinator::store::MANIFEST_FILE;
use cpt::prelude::*;
use cpt::util::propcheck::propcheck;

fn member(name: &str, schedules: &[&str], steps: usize) -> CampaignMember {
    let mut s = SweepSpec::new("mlp");
    s.schedules = schedules.iter().map(|x| x.to_string()).collect();
    s.q_maxes = vec![8.0];
    s.trials = 1;
    s.steps = Some(steps);
    CampaignMember { name: name.into(), spec: s, jobs: None }
}

fn two_member_campaign() -> CampaignSpec {
    CampaignSpec {
        name: "fab".into(),
        run_dir: None,
        members: vec![
            member("a", &["CR", "RR"], 8),
            member("b", &["CR", "STATIC"], 10),
        ],
    }
}

/// Fabricate a complete campaign shard root: campaign manifest plus one
/// run dir per member holding fabricated outcomes for every owned cell.
fn build_root(root: &Path, cspec: &CampaignSpec, shard: ShardId) -> CampaignPlan {
    let plan = CampaignPlan::build(cspec).unwrap();
    campaign::open_campaign_root(root, &plan, shard, false).unwrap();
    for m in &plan.members {
        let mut s = m.spec.clone();
        s.shard = Some(shard);
        let mplan = SweepPlan::build(&s).unwrap();
        let mut st =
            RunStore::open(&root.join(&m.name), &mplan, "fp-test", false)
                .unwrap();
        for pc in mplan.owned() {
            st.record(pc.index, &fab_outcome("mlp", &pc.cell, pc.index))
                .unwrap();
        }
    }
    plan
}

/// The full fabricated outcome list a member sweep would produce.
fn fab_member_outcomes(m: &CampaignMember) -> Vec<RunOutcome> {
    let plan = SweepPlan::build(&m.spec).unwrap();
    plan.cells
        .iter()
        .enumerate()
        .map(|(i, c)| fab_outcome("mlp", c, i))
        .collect()
}

fn edit_file(path: &Path, from: &str, to: &str) {
    let src = std::fs::read_to_string(path).unwrap();
    assert!(src.contains(from), "'{from}' not found in {}", path.display());
    std::fs::write(path, src.replace(from, to)).unwrap();
}

#[test]
fn fabricated_shards_merge_to_independent_sweep_results() {
    let tmp = tmp_dir("campaign_fab_merge");
    let cspec = two_member_campaign();
    let mut roots = Vec::new();
    for i in 1..=2usize {
        let root = tmp.join(format!("root{i}"));
        build_root(&root, &cspec, ShardId { index: i, count: 2 });
        roots.push(root);
    }
    let merged = merge_campaign_roots(&roots).unwrap();
    assert_eq!(merged.name, "fab");
    assert_eq!(merged.members.len(), 2);
    for cm in &cspec.members {
        let mm = merged.members.iter().find(|m| m.name == cm.name).unwrap();
        let want = fab_member_outcomes(cm);
        common::assert_outcomes_identical(&want, &mm.outcomes);

        // stable CSV byte-identity vs the independently fabricated sweep
        let rep = SweepReport::new(&cm.name, "metric", true);
        let pa = tmp.join(format!("{}_independent.csv", cm.name));
        let pb = tmp.join(format!("{}_campaign.csv", cm.name));
        rep.write_csv_stable(&aggregate(&want), &pa).unwrap();
        rep.write_csv_stable(&aggregate(&mm.outcomes), &pb).unwrap();
        assert_eq!(
            std::fs::read(&pa).unwrap(),
            std::fs::read(&pb).unwrap(),
            "member '{}' CSV differs",
            cm.name
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn merge_refuses_roots_from_different_campaigns() {
    let tmp = tmp_dir("campaign_fab_hash");
    let r1 = tmp.join("r1");
    build_root(&r1, &two_member_campaign(), ShardId { index: 1, count: 2 });
    let mut other = two_member_campaign();
    other.members[0].spec.trials = 3; // a result-determining change
    let r2 = tmp.join("r2");
    build_root(&r2, &other, ShardId { index: 2, count: 2 });
    let err = merge_campaign_roots(&[r1, r2]).unwrap_err();
    assert!(err.to_string().contains("campaign hash"), "{err:#}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn merge_refuses_member_dir_holding_a_different_sweep() {
    // Campaign manifests record each member's spec hash; a member dir
    // swapped for a self-consistent but different sweep must be refused.
    let tmp = tmp_dir("campaign_fab_member_swap");
    let root = tmp.join("root");
    build_root(&root, &two_member_campaign(), ShardId::single());
    // rebuild member 'a' from a different spec in place
    std::fs::remove_dir_all(root.join("a")).unwrap();
    let foreign = member("a", &["CR", "RR"], 99).spec;
    let fplan = SweepPlan::build(&foreign).unwrap();
    let mut st =
        RunStore::open(&root.join("a"), &fplan, "fp-test", false).unwrap();
    for pc in fplan.owned() {
        st.record(pc.index, &fab_outcome("mlp", &pc.cell, pc.index)).unwrap();
    }
    let err = merge_campaign_roots(&[root.clone()]).unwrap_err();
    assert!(err.to_string().contains("holds spec hash"), "{err:#}");
    // status refuses the same inconsistency
    let err = campaign::status(&root).unwrap_err();
    assert!(err.to_string().contains("disagrees"), "{err:#}");
    std::fs::remove_dir_all(&tmp).ok();
}

/// Reopen one member store (resume) and report which of its first two
/// cells still load.
fn member_cell_validity(root: &Path, m: &CampaignMember) -> (bool, bool) {
    let mut s = m.spec.clone();
    s.shard = Some(ShardId::single());
    let plan = SweepPlan::build(&s).unwrap();
    let mut st =
        RunStore::open(&root.join(&m.name), &plan, "fp-test", true).unwrap();
    (
        st.take_valid_outcome(0).is_some(),
        st.take_valid_outcome(1).is_some(),
    )
}

#[test]
fn truncated_artifact_in_campaign_tree_recomputes_and_refuses_merge() {
    let tmp = tmp_dir("campaign_corrupt_truncate");
    let root = tmp.join("root");
    let cspec = two_member_campaign();
    build_root(&root, &cspec, ShardId::single());
    // truncate member a's cell 0 artifact (torn write without the
    // atomic-rename protection)
    let manifest = cpt::coordinator::read_manifest(&root.join("a")).unwrap();
    let victim = root.join("a").join(&manifest.cells[&0].file);
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();

    // resume: the damaged cell is dropped for recompute, the intact one loads
    let (c0, c1) = member_cell_validity(&root, &cspec.members[0]);
    assert!(!c0, "truncated artifact must not load");
    assert!(c1, "intact artifact must load");
    // merge: refuses (a merge cannot recompute)
    let err = merge_campaign_roots(&[root.clone()]).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err:#}");
    // status is manifest-only, so it still reports (recorded) progress
    match campaign::status(&root).unwrap() {
        Status::Campaign(c) => assert_eq!(c.done(), 4),
        _ => panic!("expected campaign status"),
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn flipped_checksum_byte_in_campaign_tree_recomputes_and_refuses_merge() {
    let tmp = tmp_dir("campaign_corrupt_checksum");
    let root = tmp.join("root");
    let cspec = two_member_campaign();
    build_root(&root, &cspec, ShardId::single());
    // flip one hex digit of cell 0's recorded checksum in member a's
    // run manifest
    let mp = root.join("a").join(MANIFEST_FILE);
    let manifest = cpt::coordinator::read_manifest(&root.join("a")).unwrap();
    let sum = &manifest.cells[&0].checksum;
    let flipped: String = {
        let mut chars: Vec<char> = sum.chars().collect();
        chars[0] = if chars[0] == '0' { '1' } else { '0' };
        chars.into_iter().collect()
    };
    edit_file(&mp, sum, &flipped);

    let (c0, c1) = member_cell_validity(&root, &cspec.members[0]);
    assert!(!c0, "cell with flipped checksum must not load");
    assert!(c1);
    let err = merge_campaign_roots(&[root.clone()]).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err:#}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn wrong_schema_version_in_campaign_tree_refuses_everything() {
    let tmp = tmp_dir("campaign_corrupt_schema");
    let root = tmp.join("root");
    let cspec = two_member_campaign();
    build_root(&root, &cspec, ShardId::single());
    edit_file(
        &root.join("a").join(MANIFEST_FILE),
        "\"version\": 1",
        "\"version\": 2",
    );
    // an unknown schema is never guessed at: resume, merge, and status
    // all refuse
    let mut s = cspec.members[0].spec.clone();
    s.shard = Some(ShardId::single());
    let plan = SweepPlan::build(&s).unwrap();
    let err = RunStore::open(&root.join("a"), &plan, "fp-test", true)
        .unwrap_err();
    assert!(err.to_string().contains("schema version"), "{err:#}");
    let err = merge_campaign_roots(&[root.clone()]).unwrap_err();
    assert!(err.to_string().contains("schema version"), "{err:#}");
    let err = campaign::status(&root).unwrap_err();
    assert!(err.to_string().contains("schema version"), "{err:#}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn wrong_code_version_in_campaign_tree_refuses_resume_merge_status() {
    let tmp = tmp_dir("campaign_corrupt_codever");
    let root = tmp.join("root");
    let cspec = two_member_campaign();
    build_root(&root, &cspec, ShardId::single());
    edit_file(
        &root.join("a").join(MANIFEST_FILE),
        RunStore::code_version(),
        "0.0.0-other-build",
    );
    let mut s = cspec.members[0].spec.clone();
    s.shard = Some(ShardId::single());
    let plan = SweepPlan::build(&s).unwrap();
    let err = RunStore::open(&root.join("a"), &plan, "fp-test", true)
        .unwrap_err();
    assert!(err.to_string().contains("this binary"), "{err:#}");
    let err = merge_campaign_roots(&[root.clone()]).unwrap_err();
    assert!(err.to_string().contains("written by cpt"), "{err:#}");
    let err = campaign::status(&root).unwrap_err();
    assert!(err.to_string().contains("disagrees"), "{err:#}");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn gc_preserves_merged_campaign_csvs_byte_identically() {
    let tmp = tmp_dir("campaign_gc");
    let cspec = two_member_campaign();
    let mut roots = Vec::new();
    for i in 1..=2usize {
        let root = tmp.join(format!("root{i}"));
        build_root(&root, &cspec, ShardId { index: i, count: 2 });
        roots.push(root);
    }
    let write_csvs = |dir: &Path| {
        let merged = merge_campaign_roots(&roots).unwrap();
        let mut keyed = Vec::new();
        for m in &merged.members {
            let rows = aggregate(&m.outcomes);
            SweepReport::new(&m.name, "metric", true)
                .write_csv_stable(&rows, dir.join(format!("{}.csv", m.name)))
                .unwrap();
            keyed.push((m.name.clone(), rows));
        }
        SweepReport::write_campaign_csv(&keyed, dir.join("campaign.csv"))
            .unwrap();
    };
    let before = tmp.join("before");
    write_csvs(&before);

    let status_before = match campaign::status(&roots[0]).unwrap() {
        Status::Campaign(c) => (c.planned(), c.done()),
        _ => panic!("expected campaign status"),
    };
    for root in &roots {
        let stats = campaign::gc(root).unwrap();
        assert_eq!(stats.len(), 2, "both members compacted");
        for (label, st) in &stats {
            assert!(st.compacted > 0, "{label}: nothing compacted");
            assert_eq!(st.skipped, 0);
            assert!(
                st.bytes_after < st.bytes_before,
                "{label}: {st:?} did not shrink"
            );
        }
    }
    // a second gc is a no-op
    for (_, st) in campaign::gc(&roots[0]).unwrap() {
        assert_eq!(st.compacted, 0);
    }
    // status is unchanged by compaction
    let status_after = match campaign::status(&roots[0]).unwrap() {
        Status::Campaign(c) => (c.planned(), c.done()),
        _ => panic!("expected campaign status"),
    };
    assert_eq!(status_before, status_after);

    let after = tmp.join("after");
    write_csvs(&after);
    for name in ["a.csv", "b.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(before.join(name)).unwrap(),
            std::fs::read(after.join(name)).unwrap(),
            "{name} changed across gc"
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn sweep_dir_status_reports_from_manifest() {
    let tmp = tmp_dir("sweep_status");
    let mut s = member("x", &["CR", "RR"], 8).spec;
    s.shard = Some(ShardId { index: 1, count: 2 });
    let plan = SweepPlan::build(&s).unwrap();
    let mut st = RunStore::open(&tmp, &plan, "fp-test", false).unwrap();
    let owned = plan.owned();
    st.record(owned[0].index, &fab_outcome("mlp", &owned[0].cell, owned[0].index))
        .unwrap();
    match campaign::status(&tmp).unwrap() {
        Status::Sweep(m) => {
            assert_eq!(m.model, "mlp");
            assert_eq!((m.done(), m.remaining(), m.planned()), (1, 0, 1));
            assert!((m.exec_seconds() - 0.25).abs() < 1e-12);
        }
        _ => panic!("expected sweep status"),
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn status_counts_always_satisfy_done_plus_remaining_equals_planned() {
    // The cpt status invariant, over random campaign shapes, shards, and
    // partial completion states: done + remaining == planned, per member
    // and in total, with done equal to the cells actually recorded.
    propcheck(25, |rng| {
        let root = tmp_dir("campaign_status_prop");
        let count = 1 + rng.below(3) as usize;
        let index = 1 + rng.below(count as u32) as usize;
        let shard = ShardId { index, count };
        let n_members = 1 + rng.below(3) as usize;
        let members: Vec<CampaignMember> = (0..n_members)
            .map(|i| {
                let mut m = member("m", &[], 8);
                m.name = format!("m{i}");
                m.spec.schedules = (0..1 + rng.below(3))
                    .map(|k| format!("S{k}"))
                    .collect();
                m.spec.trials = 1 + rng.below(3) as usize;
                m
            })
            .collect();
        let cspec =
            CampaignSpec { name: "p".into(), run_dir: None, members };
        let plan = CampaignPlan::build(&cspec).unwrap();
        campaign::open_campaign_root(&root, &plan, shard, false).unwrap();
        let mut recorded = 0usize;
        for m in &plan.members {
            if rng.below(4) == 0 {
                continue; // member not started at all
            }
            let mut s = m.spec.clone();
            s.shard = Some(shard);
            let mplan = SweepPlan::build(&s).unwrap();
            let mut st =
                RunStore::open(&root.join(&m.name), &mplan, "fp-test", false)
                    .unwrap();
            for pc in mplan.owned() {
                if rng.below(2) == 0 {
                    st.record(
                        pc.index,
                        &fab_outcome("mlp", &pc.cell, pc.index),
                    )
                    .unwrap();
                    recorded += 1;
                }
            }
        }
        let c = match campaign::status(&root).unwrap() {
            Status::Campaign(c) => c,
            _ => return Err("expected campaign status".into()),
        };
        for m in &c.members {
            cpt::prop_assert!(
                m.done + m.remaining() == m.planned,
                "member {}: {} + {} != {}",
                m.name,
                m.done,
                m.remaining(),
                m.planned
            );
        }
        cpt::prop_assert!(
            c.done() + c.remaining() == c.planned(),
            "total: {} + {} != {}",
            c.done(),
            c.remaining(),
            c.planned()
        );
        cpt::prop_assert!(
            c.done() == recorded,
            "done {} != recorded {recorded}",
            c.done()
        );
        std::fs::remove_dir_all(&root).ok();
        Ok(())
    });
}

#[test]
fn campaign_csvs_from_toml_round_trip() {
    // End-to-end through the TOML layer (no training): parse a campaign
    // file, fabricate its tree, merge, and check the campaign CSV keys.
    let tmp = tmp_dir("campaign_toml_fab");
    let doc = cpt::config::toml::TomlDoc::parse(
        r#"
[campaign]
name = "panels"

[[campaign.sweep]]
name = "left"
model = "mlp"
schedules = ["CR"]
q_maxes = [8]
steps = 8

[[campaign.sweep]]
name = "right"
model = "mlp"
schedules = ["RR"]
q_maxes = [8]
steps = 8
"#,
    )
    .unwrap();
    let cspec = CampaignSpec::from_toml(&doc).unwrap();
    let root = tmp.join("root");
    build_root(&root, &cspec, ShardId::single());
    let merged = merge_campaign_roots(&[root]).unwrap();
    let keyed: Vec<(String, Vec<cpt::coordinator::AggRow>)> = merged
        .members
        .iter()
        .map(|m| (m.name.clone(), aggregate(&m.outcomes)))
        .collect();
    let p = tmp.join("campaign.csv");
    SweepReport::write_campaign_csv(&keyed, &p).unwrap();
    let csv = std::fs::read_to_string(&p).unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), 3, "header + one row per member: {csv}");
    assert!(lines[0].starts_with("sweep,model,"));
    assert!(lines[1].starts_with("left,mlp,CR,"));
    assert!(lines[2].starts_with("right,mlp,RR,"));
    std::fs::remove_dir_all(&tmp).ok();
}

//! Persistent AOT executable cache, exercised entirely through
//! fabricated runners over a REAL `AotStore` (no PJRT — the CI
//! `test-unit` tier): a second "process" (fresh worker pool, empty
//! in-memory caches) over a populated cache dir must warm-start with
//! zero compiles, a corrupted cache must recompile and never change
//! results, and the hit/disk-hit/miss accounting must land in the
//! campaign manifest.

mod common;

use std::collections::HashMap;
use std::path::Path;

use common::{fab_outcome, tmp_dir};
use cpt::coordinator::aot::{AotKey, AotStore};
use cpt::coordinator::campaign::{
    read_campaign_manifest, run_campaign_global, CampaignMember,
    CampaignRunOpts, SchedulerKind,
};
use cpt::coordinator::exec::{CacheStats, CellError, CellRunner, ExecMember};
use cpt::prelude::*;

/// Deterministic stand-in for serialized executable bytes: derived from
/// the fingerprint, so a cross-wired cache entry cannot pass by
/// coincidence (the stale-bytes fence below compares against these).
fn fab_payloads(fingerprint: &str) -> Vec<(String, Vec<u8>)> {
    vec![
        ("init".into(), format!("init<{fingerprint}>").into_bytes()),
        ("train".into(), format!("train<{fingerprint}>").into_bytes()),
    ]
}

/// Fabricated worker backend mirroring `PjrtCellRunner`'s two-level
/// lookup at the bytes level: in-memory list, then the real disk store,
/// then a "compile" that publishes its payloads for future processes.
struct FabAotRunner {
    store: AotStore,
    mem: Vec<String>,
    compiles: usize,
    cache: CacheStats,
}

impl FabAotRunner {
    fn new(cache_dir: &Path) -> Result<FabAotRunner> {
        Ok(FabAotRunner {
            store: AotStore::open(cache_dir)?,
            mem: Vec::new(),
            compiles: 0,
            cache: CacheStats::default(),
        })
    }

    fn key(fingerprint: &str) -> AotKey {
        AotKey::new(fingerprint, "fab", "fab-exe-v1")
    }
}

impl CellRunner for FabAotRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        let fp = &member.fingerprint;
        if self.mem.contains(fp) {
            self.cache.hits += 1;
        } else {
            self.cache.misses += 1;
            let key = Self::key(fp);
            match self.store.load(&key) {
                Some(payloads) => {
                    // stale-bytes fence: whatever the store serves must
                    // be exactly what a compile of this model produces
                    assert_eq!(
                        payloads,
                        fab_payloads(fp),
                        "cache served foreign bytes for '{fp}'"
                    );
                    self.cache.disk_hits += 1;
                }
                None => {
                    self.compiles += 1;
                    // racing workers may lose the publish — that's fine,
                    // the entry is whole either way
                    self.store
                        .publish(&key, &member.model, &fab_payloads(fp))
                        .map_err(CellError::Setup)?;
                }
            }
            self.mem.push(fp.clone());
        }
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiles, 0.0)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.mem.iter().any(|f| f == fingerprint)
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache
    }
}

fn member(
    name: &str,
    model: &str,
    schedules: &[&str],
    steps: usize,
) -> CampaignMember {
    let mut s = SweepSpec::new(model);
    s.schedules = schedules.iter().map(|x| x.to_string()).collect();
    s.q_maxes = vec![8.0];
    s.trials = 1;
    s.steps = Some(steps);
    CampaignMember { name: name.into(), spec: s, jobs: None }
}

/// Two members sharing one model plus one on its own model — both the
/// shared-executable case and the multi-entry cache case.
fn campaign_spec() -> CampaignSpec {
    CampaignSpec {
        name: "aotwarm".into(),
        run_dir: None,
        members: vec![
            member("a", "mlp", &["CR", "RR"], 8),
            member("b", "mlp", &["CR", "STATIC"], 10),
            member("c", "cnn_tiny", &["CR"], 8),
        ],
    }
}

fn fingerprints_for(cspec: &CampaignSpec) -> HashMap<String, String> {
    cspec
        .members
        .iter()
        .map(|m| (m.spec.model.clone(), format!("fp-{}", m.spec.model)))
        .collect()
}

fn opts(root: &Path, jobs: usize) -> CampaignRunOpts {
    CampaignRunOpts {
        root: root.to_path_buf(),
        shard: ShardId::single(),
        jobs,
        resume: false,
        verbose: false,
        scheduler: SchedulerKind::Global,
    }
}

fn fab_member_outcomes(m: &CampaignMember) -> Vec<RunOutcome> {
    let plan = SweepPlan::build(&m.spec).unwrap();
    plan.cells
        .iter()
        .enumerate()
        .map(|(i, c)| fab_outcome(&m.spec.model, c, i))
        .collect()
}

fn write_csvs(dir: &Path, members: &[(String, Vec<RunOutcome>)]) {
    let mut keyed = Vec::new();
    for (name, outs) in members {
        let rows = aggregate(outs);
        SweepReport::new(name, "metric", true)
            .write_csv_stable(&rows, dir.join(format!("{name}.csv")))
            .unwrap();
        keyed.push((name.clone(), rows));
    }
    SweepReport::write_campaign_csv(&keyed, dir.join("campaign.csv")).unwrap();
}

/// Run the fabricated campaign as one "process" against `cache`.
fn run_process(
    root: &Path,
    cache: &Path,
    jobs: usize,
) -> cpt::coordinator::campaign::CampaignRunResult {
    let cspec = campaign_spec();
    let plan = CampaignPlan::build(&cspec).unwrap();
    let fps = fingerprints_for(&cspec);
    run_campaign_global(&plan, &opts(root, jobs), &fps, None, |_| {
        FabAotRunner::new(cache)
    })
    .unwrap()
}

fn assert_ground_truth(result: &cpt::coordinator::campaign::CampaignRunResult) {
    let cspec = campaign_spec();
    assert_eq!(result.members.len(), cspec.members.len());
    for (m, cm) in result.members.iter().zip(&cspec.members) {
        assert_eq!(m.name, cm.name);
        common::assert_outcomes_identical(&fab_member_outcomes(cm), &m.outcomes);
    }
}

fn keyed(
    r: &cpt::coordinator::campaign::CampaignRunResult,
) -> Vec<(String, Vec<RunOutcome>)> {
    r.members
        .iter()
        .map(|m| (m.name.clone(), m.outcomes.clone()))
        .collect()
}

/// Append garbage to every payload file under the cache dir.
fn corrupt_all_payloads(cache: &Path) -> usize {
    let mut hit = 0;
    let mut stack = vec![cache.to_path_buf()];
    while let Some(d) = stack.pop() {
        for e in std::fs::read_dir(&d).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "bin") {
                let mut bytes = std::fs::read(&p).unwrap();
                bytes.extend_from_slice(b"CORRUPT");
                std::fs::write(&p, &bytes).unwrap();
                hit += 1;
            }
        }
    }
    hit
}

#[test]
fn second_process_warm_starts_with_zero_compiles() {
    let tmp = tmp_dir("aot_warm");
    let cache = tmp.join("cache");

    // cold process: every model compiles once (per worker at most), and
    // the compiles are published
    let cold = run_process(&tmp.join("cold"), &cache, 2);
    assert_ground_truth(&cold);
    let sc_cold = cold.scheduler.as_ref().expect("scheduler stats");
    assert!(sc_cold.total_compiles() >= 2, "two models must compile");

    // warm process: fresh root, fresh workers with empty in-memory
    // caches — every first-touch of a model is a disk hit, zero compiles
    let warm = run_process(&tmp.join("warm"), &cache, 2);
    assert_ground_truth(&warm);
    let sc_warm = warm.scheduler.as_ref().expect("scheduler stats");
    assert_eq!(sc_warm.total_compiles(), 0, "warm start must not compile");
    assert!(sc_warm.total_disk_hits() >= 2, "disk must serve both models");

    // accounting invariant and manifest round-trip of the new fields
    for sc in [sc_cold, sc_warm] {
        for w in &sc.workers {
            assert_eq!(
                w.misses,
                w.disk_hits + w.compiles,
                "each LRU miss is a disk hit or a compile: {w:?}"
            );
        }
    }
    let recorded = read_campaign_manifest(&tmp.join("warm"))
        .unwrap()
        .scheduler
        .expect("scheduler stats in manifest");
    assert_eq!(&recorded, sc_warm);

    // results are byte-identical between cold and warm execution
    let (d_cold, d_warm) = (tmp.join("csv_cold"), tmp.join("csv_warm"));
    write_csvs(&d_cold, &keyed(&cold));
    write_csvs(&d_warm, &keyed(&warm));
    for f in ["a.csv", "b.csv", "c.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(d_cold.join(f)).unwrap(),
            std::fs::read(d_warm.join(f)).unwrap(),
            "{f} differs between cold and warm runs"
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn corrupted_cache_recompiles_and_results_are_identical() {
    let tmp = tmp_dir("aot_corrupt");
    let cache = tmp.join("cache");

    let cold = run_process(&tmp.join("cold"), &cache, 2);
    assert_ground_truth(&cold);
    assert!(corrupt_all_payloads(&cache) >= 2, "cache must hold payloads");

    // a process over the damaged cache falls back to compiling — no
    // crash, no stale bytes (the runner's fence would panic), and
    // byte-identical results
    let after = run_process(&tmp.join("after"), &cache, 2);
    assert_ground_truth(&after);
    let sc = after.scheduler.as_ref().expect("scheduler stats");
    assert_eq!(sc.total_disk_hits(), 0, "damaged entries must not serve");
    assert!(sc.total_compiles() >= 2, "fallback must recompile");

    let (d_cold, d_after) = (tmp.join("csv_cold"), tmp.join("csv_after"));
    write_csvs(&d_cold, &keyed(&cold));
    write_csvs(&d_after, &keyed(&after));
    for f in ["a.csv", "b.csv", "c.csv", "campaign.csv"] {
        assert_eq!(
            std::fs::read(d_cold.join(f)).unwrap(),
            std::fs::read(d_after.join(f)).unwrap(),
            "{f} differs after cache corruption"
        );
    }

    // damaged entries poison their keys (publish_exclusive cannot
    // replace a manifest) — gc heals, and the next process repopulates
    // and warm-starts again
    let store = AotStore::open(&cache).unwrap();
    let gc = store.gc(None).unwrap();
    assert!(gc.evicted >= 2, "gc must remove the damaged entries: {gc:?}");
    let repop = run_process(&tmp.join("repop"), &cache, 2);
    assert!(repop.scheduler.unwrap().total_compiles() >= 2);
    let rewarm = run_process(&tmp.join("rewarm"), &cache, 2);
    assert_ground_truth(&rewarm);
    assert_eq!(rewarm.scheduler.unwrap().total_compiles(), 0);
    std::fs::remove_dir_all(&tmp).ok();
}

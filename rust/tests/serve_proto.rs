//! The `cpt serve` wire protocol, attacked from both sides (the CI
//! `test-unit` tier — no PJRT): a propcheck round trip over random
//! request/response frames, the malformed-input matrix against the pure
//! decoder, and the same matrix against a live daemon socket — every
//! bad input gets a typed error reply, never a panic or a wedged
//! connection.

mod common;

use std::io::Write;
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

use anyhow::bail;
use common::tmp_dir;
use cpt::coordinator::lease::TestClock;
use cpt::server::proto::{
    self, decode_request, decode_response, encode_request, encode_response,
    ErrorCode, Request, Response, ServeStats, MAX_FRAME_BYTES,
};
use cpt::server::{Client, JobState, JobStats, JobView, ServeOpts, Server};
use cpt::util::prng::Pcg32;
use cpt::util::propcheck::propcheck;
use cpt::util::{read_frame, write_frame};

/// Strings over an alphabet chosen to stress JSON escaping and framing:
/// quotes, backslashes, braces, newlines (which compact JSON must keep
/// escaped — a raw one would split the frame), control chars, unicode.
fn rand_string(rng: &mut Pcg32) -> String {
    const ALPHABET: [char; 16] = [
        'a', 'Z', '0', '"', '\\', '\n', '\t', '{', '}', ':', ',', ' ',
        'λ', '→', '\u{1}', '/',
    ];
    let len = rng.below(24) as usize;
    (0..len)
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u32) as usize])
        .collect()
}

fn rand_state(rng: &mut Pcg32) -> JobState {
    match rng.below(4) {
        0 => JobState::Queued,
        1 => JobState::Running,
        2 => JobState::Done,
        _ => JobState::Failed,
    }
}

fn rand_view(rng: &mut Pcg32) -> JobView {
    JobView {
        ticket: format!("{:016x}", rng.next_u32()),
        name: rand_string(rng),
        state: rand_state(rng),
        planned: rng.below(100) as usize,
        done: match rng.below(3) {
            0 => None,
            _ => Some(rng.below(100) as usize),
        },
        // awkward but finite float (bit-exact JSON round trip is part
        // of the contract under test)
        submitted: rng.next_u32() as f64 / 7.0,
        error: match rng.below(3) {
            0 => Some(rand_string(rng)),
            _ => None,
        },
        stats: match rng.below(3) {
            0 => Some(JobStats {
                compiles: rng.below(10) as usize,
                compile_seconds: rng.next_u32() as f64 / 7.0,
                hits: rng.below(100) as usize,
                disk_hits: rng.below(100) as usize,
                misses: rng.below(100) as usize,
            }),
            _ => None,
        },
    }
}

fn rand_request(rng: &mut Pcg32) -> Request {
    match rng.below(8) {
        0 => Request::Ping,
        1 => Request::Submit { spec_toml: rand_string(rng) },
        2 => Request::Status { ticket: rand_string(rng) },
        3 => Request::Result { ticket: rand_string(rng) },
        4 => Request::Jobs,
        5 => Request::Gc {
            max_age: match rng.below(3) {
                0 => None,
                _ => Some(rng.next_u32() as f64 / 7.0),
            },
            max_bytes: match rng.below(3) {
                0 => None,
                _ => Some(rng.next_u32() as u64),
            },
        },
        6 => Request::Stats,
        _ => Request::Shutdown,
    }
}

fn rand_serve_stats(rng: &mut Pcg32) -> ServeStats {
    ServeStats {
        uptime_seconds: rng.next_u32() as f64 / 7.0,
        jobs_by_state: (0..rng.below(4))
            .map(|_| (rand_string(rng), rng.below(50) as usize))
            .collect(),
        requests: rng.next_u32() as u64,
        errors_by_code: (0..rng.below(4))
            .map(|_| (rand_string(rng), rng.next_u32() as u64))
            .collect(),
        pool: JobStats {
            compiles: rng.below(10) as usize,
            compile_seconds: rng.next_u32() as f64 / 7.0,
            hits: rng.below(100) as usize,
            disk_hits: rng.below(100) as usize,
            misses: rng.below(100) as usize,
        },
    }
}

fn rand_response(rng: &mut Pcg32) -> Response {
    match rng.below(9) {
        0 => Response::Pong,
        1 => Response::Submitted {
            ticket: format!("{:016x}", rng.next_u32()),
            state: rand_state(rng),
            attached: rng.below(2) == 0,
            planned: rng.below(50) as usize,
        },
        2 => Response::Status { job: rand_view(rng) },
        3 => Response::ResultFiles {
            ticket: format!("{:016x}", rng.next_u32()),
            files: (0..rng.below(4))
                .map(|i| (format!("f{i}.csv"), rand_string(rng)))
                .collect(),
        },
        4 => Response::Jobs {
            jobs: (0..rng.below(4)).map(|_| rand_view(rng)).collect(),
        },
        5 => Response::ShuttingDown,
        6 => Response::GcDone {
            removed: rng.below(20) as usize,
            bytes_freed: rng.next_u32() as u64,
        },
        7 => Response::Stats { stats: rand_serve_stats(rng) },
        _ => Response::Error {
            code: ErrorCode::BadSpec,
            message: rand_string(rng),
        },
    }
}

/// encode → frame → unframe → decode must reproduce the value exactly,
/// for every request and response shape over hostile payload strings.
#[test]
fn frames_round_trip_for_random_requests_and_responses() {
    propcheck(64, |rng| {
        let req = rand_request(rng);
        let mut wire = Vec::new();
        write_frame(&mut wire, encode_request(&req).as_bytes())
            .map_err(|e| format!("write_frame: {e}"))?;
        let mut r: &[u8] = &wire;
        let frame = read_frame(&mut r, MAX_FRAME_BYTES)
            .map_err(|e| format!("read_frame: {e}"))?
            .ok_or_else(|| "unexpected EOF".to_string())?;
        let back = decode_request(&frame)
            .map_err(|(c, m)| format!("decode [{}]: {m}", c.as_str()))?;
        cpt::prop_assert!(back == req, "request changed: {req:?} -> {back:?}");

        let resp = rand_response(rng);
        let mut wire = Vec::new();
        write_frame(&mut wire, encode_response(&resp).as_bytes())
            .map_err(|e| format!("write_frame: {e}"))?;
        let mut r: &[u8] = &wire;
        let frame = read_frame(&mut r, MAX_FRAME_BYTES)
            .map_err(|e| format!("read_frame: {e}"))?
            .ok_or_else(|| "unexpected EOF".to_string())?;
        let back =
            decode_response(&frame).map_err(|e| format!("decode: {e:#}"))?;
        cpt::prop_assert!(
            back == resp,
            "response changed: {resp:?} -> {back:?}"
        );
        Ok(())
    });
}

/// The pure decoder maps every malformed frame to its specific typed
/// error — decoding is total, the error class is part of the contract.
#[test]
fn malformed_request_frames_map_to_typed_errors() {
    let cases: &[(&[u8], ErrorCode)] = &[
        (b"\xff\xfe garbage", ErrorCode::BadJson),
        (b"{not json", ErrorCode::BadJson),
        (b"[1,2,3]", ErrorCode::BadSchemaVersion),
        (b"{\"verb\": \"ping\"}", ErrorCode::BadSchemaVersion),
        (b"{\"v\": 2, \"verb\": \"ping\"}", ErrorCode::BadSchemaVersion),
        (b"{\"v\": \"one\", \"verb\": \"ping\"}", ErrorCode::BadSchemaVersion),
        (b"{\"v\": 1.5, \"verb\": \"ping\"}", ErrorCode::BadSchemaVersion),
        (b"{\"v\": 1}", ErrorCode::BadRequest),
        (b"{\"v\": 1, \"verb\": 7}", ErrorCode::BadRequest),
        (b"{\"v\": 1, \"verb\": \"dance\"}", ErrorCode::UnknownVerb),
        (b"{\"v\": 1, \"verb\": \"submit\"}", ErrorCode::BadRequest),
        (
            b"{\"v\": 1, \"verb\": \"submit\", \"spec_toml\": 9}",
            ErrorCode::BadRequest,
        ),
        (b"{\"v\": 1, \"verb\": \"status\"}", ErrorCode::BadRequest),
        (
            b"{\"v\": 1, \"verb\": \"result\", \"ticket\": null}",
            ErrorCode::BadRequest,
        ),
        (
            b"{\"v\": 1, \"verb\": \"gc\", \"max_age\": \"old\"}",
            ErrorCode::BadRequest,
        ),
    ];
    for (frame, want) in cases {
        match decode_request(frame) {
            Err((code, msg)) => assert_eq!(
                code,
                *want,
                "frame {:?}: got [{}] {msg}",
                String::from_utf8_lossy(frame),
                code.as_str()
            ),
            Ok(req) => panic!(
                "frame {:?} decoded to {req:?}",
                String::from_utf8_lossy(frame)
            ),
        }
    }
}

/// A daemon whose executor can never run anything — pure protocol
/// surface. Jobs submitted here would fail if executed; these tests
/// never submit a valid spec.
fn proto_server(root: &Path) -> Server {
    let exec: cpt::server::CampaignExec =
        Arc::new(|_, _| bail!("no exec in proto tests"));
    Server::start(
        ServeOpts {
            root: root.to_path_buf(),
            listen: "127.0.0.1:0".to_string(),
            jobs: 1,
            concurrent: 1,
            allow_remote: false,
            verbose: false,
        },
        exec,
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap()
}

/// Send one raw frame, expect one typed error reply with `want`.
fn expect_error_reply(stream: &mut TcpStream, want: ErrorCode) {
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let frame = read_frame(&mut reader, MAX_FRAME_BYTES)
        .expect("reply frame")
        .expect("server closed without replying");
    match decode_response(&frame).unwrap() {
        Response::Error { code, message } => {
            assert_eq!(code, want, "unexpected error class: {message}")
        }
        other => panic!("expected {want:?} error, got {other:?}"),
    }
}

#[test]
fn live_daemon_answers_every_malformed_input_with_a_typed_error() {
    let root = tmp_dir("serve_proto_live");
    let srv = proto_server(&root);
    let addr = srv.addr().to_string();

    // in-frame errors: typed reply AND the connection stays usable
    let in_frame: &[(&[u8], ErrorCode)] = &[
        (b"{not json", ErrorCode::BadJson),
        (b"{\"v\": 3, \"verb\": \"ping\"}", ErrorCode::BadSchemaVersion),
        (b"{\"v\": 1, \"verb\": \"dance\"}", ErrorCode::UnknownVerb),
        (b"{\"v\": 1, \"verb\": \"status\"}", ErrorCode::BadRequest),
    ];
    for (frame, want) in in_frame {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(frame).unwrap();
        stream.write_all(b"\n").unwrap();
        expect_error_reply(&mut stream, *want);
        // same connection must still answer a well-formed request
        let mut reader =
            std::io::BufReader::new(stream.try_clone().unwrap());
        write_frame(&mut stream, encode_request(&Request::Ping).as_bytes())
            .unwrap();
        let frame = read_frame(&mut reader, MAX_FRAME_BYTES)
            .unwrap()
            .expect("connection wedged after typed error");
        assert_eq!(decode_response(&frame).unwrap(), Response::Pong);
    }

    // typed application errors through the real client
    let mut client = Client::connect(&addr).unwrap();
    let err = client
        .submit("this is [ not a campaign\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("bad_spec"), "{err}");
    // valid TOML, invalid campaign
    let err = client.submit("[campaign]\n").unwrap_err().to_string();
    assert!(err.contains("bad_spec"), "{err}");
    let err = client.status("aaaabbbbccccdddd").unwrap_err().to_string();
    assert!(err.contains("unknown_ticket"), "{err}");
    let err = client
        .result_files("aaaabbbbccccdddd")
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown_ticket"), "{err}");
    // the connection survived four application errors
    client.ping().unwrap();

    // an oversized frame compromises the stream: typed reply, then the
    // daemon closes — and fresh connections still work (exactly max+1
    // bytes, so the daemon consumes the whole payload before replying
    // and its close cannot RST the reply away)
    let mut stream = TcpStream::connect(&addr).unwrap();
    let chunk = vec![b'x'; 1 << 16];
    let mut left = MAX_FRAME_BYTES + 1;
    while left > 0 {
        let n = left.min(chunk.len());
        stream.write_all(&chunk[..n]).unwrap();
        left -= n;
    }
    expect_error_reply(&mut stream, ErrorCode::FrameTooLarge);
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    assert_eq!(
        read_frame(&mut reader, MAX_FRAME_BYTES).unwrap(),
        None,
        "daemon must close after an oversized frame"
    );

    // a truncated frame (peer hangs up mid-frame) likewise: typed
    // reply, then close
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(b"{\"v\": 1, \"verb\": \"pi").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    expect_error_reply(&mut stream, ErrorCode::BadFrame);
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    assert_eq!(read_frame(&mut reader, MAX_FRAME_BYTES).unwrap(), None);

    // a clean disconnect between frames is not an error at all
    drop(TcpStream::connect(&addr).unwrap());
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();

    // clean shutdown: acknowledged, then the daemon exits
    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&root).ok();
}

/// The `stats` verb against a live daemon: the counters must reflect
/// the traffic this very connection generated, and the reply must
/// round-trip through the real client.
#[test]
fn live_daemon_reports_stats() {
    let root = tmp_dir("serve_proto_stats");
    let srv = proto_server(&root);
    let addr = srv.addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    client.ping().unwrap();
    // one typed application error so the error table is non-empty
    let err = client.status("aaaabbbbccccdddd").unwrap_err().to_string();
    assert!(err.contains("unknown_ticket"), "{err}");
    let s = client.stats().unwrap();
    assert!(s.uptime_seconds >= 0.0);
    assert!(s.jobs_by_state.is_empty(), "{:?}", s.jobs_by_state);
    // at least ping + status + this stats call
    assert!(s.requests >= 3, "requests={}", s.requests);
    assert_eq!(s.errors_by_code, vec![("unknown_ticket".to_string(), 1)]);
    assert_eq!(s.pool, JobStats::default());
    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&root).ok();
}

/// The version field is checked before the verb: a future-versioned
/// frame with an unknown verb must be answered as a version problem, so
/// old daemons never misreport what newer clients say.
#[test]
fn schema_version_is_checked_before_the_verb() {
    match decode_request(b"{\"v\": 9, \"verb\": \"brand_new_verb\"}") {
        Err((code, _)) => assert_eq!(code, ErrorCode::BadSchemaVersion),
        Ok(r) => panic!("decoded {r:?}"),
    }
    // error codes on the wire round trip through their stable strings
    for code in [
        ErrorCode::BadFrame,
        ErrorCode::FrameTooLarge,
        ErrorCode::BadJson,
        ErrorCode::BadSchemaVersion,
        ErrorCode::UnknownVerb,
        ErrorCode::BadRequest,
        ErrorCode::BadSpec,
        ErrorCode::UnknownTicket,
        ErrorCode::NotDone,
        ErrorCode::JobFailed,
        ErrorCode::Internal,
    ] {
        assert_eq!(ErrorCode::parse(code.as_str()).unwrap(), code);
    }
    assert!(ErrorCode::parse("no_such_code").is_err());
    assert_eq!(proto::PROTO_VERSION, 1);
}

//! The obs subsystem end to end (the CI `test-unit` tier — no PJRT):
//! event JSONL round trips under adversarial strings, durable-sink
//! recovery from a crash-truncated tail, metrics snapshot determinism,
//! and the `cpt trace` analyzer on a fabricated two-worker run whose
//! span breakdown must account for each worker's wall clock.

mod common;

use std::io::Write;
use std::sync::Arc;

use common::tmp_dir;
use cpt::coordinator::lease::TestClock;
use cpt::obs::analyze::summarize;
use cpt::obs::log::Level;
use cpt::obs::metrics::Registry;
use cpt::obs::trace::{read_root, Event, Tracer};
use cpt::util::json::{self, Json};
use cpt::util::prng::Pcg32;
use cpt::util::propcheck::propcheck;

/// Strings over an alphabet chosen to stress the JSONL invariant:
/// quotes, backslashes, raw newlines/tabs (which the compact encoder
/// must escape — an unescaped one would split the line), braces,
/// control chars, unicode.
fn rand_string(rng: &mut Pcg32) -> String {
    const ALPHABET: [char; 16] = [
        'a', 'Z', '0', '"', '\\', '\n', '\t', '{', '}', ':', ',', ' ',
        'λ', '→', '\u{1}', '/',
    ];
    let len = rng.below(24) as usize;
    (0..len)
        .map(|_| ALPHABET[rng.below(ALPHABET.len() as u32) as usize])
        .collect()
}

fn rand_event(rng: &mut Pcg32) -> Event {
    // awkward but finite floats (bit-exact JSON round trip is part of
    // the contract under test)
    let t = rng.next_u32() as f64 / 7.0;
    let mut ev = Event::new(t, &rand_string(rng));
    if rng.below(2) == 0 {
        ev = ev.dur(rng.next_u32() as f64 / 7.0);
    }
    if rng.below(2) == 0 {
        ev = ev.worker(rng.below(8) as usize);
    }
    if rng.below(2) == 0 {
        ev = ev.member(rng.below(8) as usize);
    }
    if rng.below(2) == 0 {
        ev = ev.cell(rng.below(100) as usize);
    }
    for _ in 0..rng.below(4) {
        let key = rand_string(rng);
        ev = if rng.below(2) == 0 {
            ev.tag(&key, json::s(&rand_string(rng)))
        } else {
            ev.tag(&key, json::num(rng.next_u32() as f64 / 7.0))
        };
    }
    ev
}

#[test]
fn event_lines_round_trip_adversarial_strings() {
    propcheck(128, |rng| {
        let ev = rand_event(rng);
        let line = ev.to_line();
        cpt::prop_assert!(!line.contains('\n'), "raw newline: {line:?}");
        let back = Event::parse_line(&line)
            .map_err(|e| format!("parse {line:?}: {e:#}"))?;
        cpt::prop_assert!(back == ev, "{back:?} != {ev:?}");
        Ok(())
    });
}

#[test]
fn sink_survives_crash_truncated_tail() {
    let root = tmp_dir("obs_truncated");
    let clock = Arc::new(TestClock::new(50.0));
    let tracer = Tracer::create(&root, clock).unwrap();
    let good = vec![
        Event::new(51.0, "claim").dur(0.5).worker(0),
        Event::new(52.0, "exec").dur(1.0).worker(0),
    ];
    tracer.append(&good);
    // a crash mid-write leaves a partial last line (no newline), and a
    // foreign tool might leave plain garbage; neither may be fatal
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(tracer.path())
        .unwrap();
    f.write_all(b"not json at all\n").unwrap();
    f.write_all(b"{\"t\":53.0,\"kind\":\"tru").unwrap();
    drop(f);
    let events = read_root(&root).unwrap();
    assert_eq!(events, good);
    // pointing at the trace dir itself (not its parent) also works
    let direct = read_root(&root.join("trace")).unwrap();
    assert_eq!(direct, good);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn metrics_snapshots_are_order_independent_and_deterministic() {
    // the same multiset of updates, applied in two different orders,
    // must serialize byte-identically — what `cpt stats` leans on
    let a = Registry::new();
    a.inc("pool.claims", 3);
    a.observe("serve.request_seconds", 0.25);
    a.inc("serve.errors.bad_frame", 1);
    a.observe("serve.request_seconds", 1.5);
    a.set_gauge("queue.depth", 4.0);
    a.inc("pool.claims", 2);

    let b = Registry::new();
    b.set_gauge("queue.depth", 4.0);
    b.inc("serve.errors.bad_frame", 1);
    b.observe("serve.request_seconds", 1.5);
    b.inc("pool.claims", 5);
    b.observe("serve.request_seconds", 0.25);

    let sa = a.snapshot();
    let sb = b.snapshot();
    let ja = sa.to_json().to_string_compact();
    let jb = sb.to_json().to_string_compact();
    assert_eq!(ja, jb);
    assert_eq!(sa.counter("pool.claims"), 5);
    assert_eq!(
        sa.counters_with_prefix("serve.errors"),
        vec![("bad_frame".to_string(), 1)]
    );
    let (name, h) = &sa.hists[0];
    assert_eq!(name, "serve.request_seconds");
    assert_eq!(h.count, 2);
    assert_eq!(h.min, 0.25);
    assert_eq!(h.max, 1.5);
    assert!((h.sum - 1.75).abs() < 1e-12);
}

/// The four spans of one fabricated cell, tiling
/// `[t0, t0 + claim + compile + exec + record)` exactly the way the
/// executor emits them.
#[allow(clippy::too_many_arguments)]
fn cell_spans(
    t0: f64,
    w: usize,
    m: usize,
    c: usize,
    claim: f64,
    compile: f64,
    exec: f64,
    record: f64,
) -> Vec<Event> {
    let outcome = if compile > 0.0 { "miss" } else { "hit" };
    vec![
        Event::new(t0, "claim")
            .dur(claim)
            .worker(w)
            .member(m)
            .cell(c),
        Event::new(t0 + claim, "compile")
            .dur(compile)
            .worker(w)
            .member(m)
            .cell(c)
            .tag_str("outcome", outcome),
        Event::new(t0 + claim + compile, "exec")
            .dur(exec)
            .worker(w)
            .member(m)
            .cell(c)
            .tag_str("name", "mlp")
            .tag_str("model", "m8"),
        Event::new(t0 + claim + compile + exec, "record")
            .dur(record)
            .worker(w)
            .member(m)
            .cell(c),
    ]
}

#[test]
fn two_worker_trace_accounts_for_wall_clock() {
    let root = tmp_dir("obs_two_workers");
    let clock = Arc::new(TestClock::new(100.0));
    let tracer = Tracer::create(&root, clock).unwrap();
    // worker 0 runs member 0 cells 0 and 1 back to back: 8.6s of wall
    let mut w0 = cell_spans(100.0, 0, 0, 0, 0.5, 2.0, 3.0, 0.25);
    w0.extend(cell_spans(105.75, 0, 0, 1, 0.1, 0.0, 2.5, 0.25));
    tracer.append(&w0);
    // worker 1 runs member 1 cell 0: 6.75s of wall — written as a
    // second trace file, the multi-process layout read_root merges
    let w1 = cell_spans(100.0, 1, 1, 0, 0.75, 1.5, 4.0, 0.5);
    let w1_path = root.join("trace").join("trace-w1.jsonl");
    let mut f = std::fs::File::create(w1_path).unwrap();
    for ev in &w1 {
        writeln!(f, "{}", ev.to_line()).unwrap();
    }
    drop(f);

    let events = read_root(&root).unwrap();
    assert_eq!(events.len(), 12);
    // the merged stream is timestamp-sorted across files
    for pair in events.windows(2) {
        assert!(pair[0].t <= pair[1].t);
    }

    let s = summarize(&events, 2);
    assert_eq!(s.events, 12);
    assert_eq!(
        s.kinds,
        vec![
            ("claim".to_string(), 3),
            ("compile".to_string(), 3),
            ("exec".to_string(), 3),
            ("record".to_string(), 3),
        ]
    );
    assert_eq!(s.t_min, 100.0);
    assert!((s.t_max - 108.6).abs() < 1e-9, "t_max={}", s.t_max);

    // per-worker claim+compile+exec+record must account for the wall
    // clock each worker was busy, within float-sum tolerance
    assert_eq!(s.workers.len(), 2);
    let w0b = &s.workers[0];
    assert_eq!((w0b.worker, w0b.cells), (0, 2));
    assert!((w0b.queue_wait - 0.6).abs() < 1e-9);
    assert!((w0b.compile - 2.0).abs() < 1e-9);
    assert!((w0b.exec - 5.5).abs() < 1e-9);
    assert!((w0b.record - 0.5).abs() < 1e-9);
    assert!((w0b.total() - 8.6).abs() < 1e-9, "total={}", w0b.total());
    let w1b = &s.workers[1];
    assert_eq!((w1b.worker, w1b.cells), (1, 1));
    assert!((w1b.total() - 6.75).abs() < 1e-9, "total={}", w1b.total());

    // member table: labels from exec tags, compile/exec attribution
    assert_eq!(s.members.len(), 2);
    assert_eq!(s.members[0].label, "mlp:m8");
    assert!((s.members[0].compile - 2.0).abs() < 1e-9);
    assert!((s.members[0].exec - 5.5).abs() < 1e-9);
    assert_eq!(s.members[1].cells, 1);

    // slowest cells by compile+exec: (m1,c0)=5.5 then (m0,c0)=5.0
    assert_eq!(s.slowest.len(), 2);
    let top = &s.slowest[0];
    assert_eq!((top.member, top.cell, top.worker), (1, 0, Some(1)));
    assert!((top.seconds - 5.5).abs() < 1e-9);
    assert_eq!((s.slowest[1].member, s.slowest[1].cell), (0, 0));

    // the text report carries the rows check.sh greps for
    let text = s.render_text();
    assert!(text.contains("worker 0:"), "{text}");
    assert!(text.contains("worker 1:"), "{text}");
    assert!(text.contains("compile="), "{text}");
    assert!(text.contains("slowest cells:"), "{text}");
    assert!(text.ends_with('\n'), "{text:?}");

    // the JSON report mirrors the same totals
    let j = s.to_json();
    let workers = match j.get("workers").unwrap() {
        Json::Arr(v) => v.clone(),
        other => panic!("workers not an array: {other:?}"),
    };
    assert_eq!(workers.len(), 2);
    let w0j = &workers[0];
    let total0 = w0j.get("total_seconds").unwrap().as_f64().unwrap();
    assert!((total0 - 8.6).abs() < 1e-9, "total0={total0}");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn summarize_invariants_hold_on_random_traces() {
    propcheck(64, |rng| {
        let n = rng.below(40) as usize;
        let events: Vec<Event> = (0..n).map(|_| rand_event(rng)).collect();
        let top_k = rng.below(5) as usize;
        let s = summarize(&events, top_k);
        cpt::prop_assert!(s.events == n, "events={} n={n}", s.events);
        cpt::prop_assert!(
            s.slowest.len() <= top_k,
            "slowest {} > top_k {top_k}",
            s.slowest.len()
        );
        for pair in s.slowest.windows(2) {
            cpt::prop_assert!(
                pair[0].seconds >= pair[1].seconds,
                "slowest not sorted: {} < {}",
                pair[0].seconds,
                pair[1].seconds
            );
        }
        let kind_total: usize = s.kinds.iter().map(|(_, c)| c).sum();
        cpt::prop_assert!(kind_total == n, "kinds {kind_total} != {n}");
        for w in &s.workers {
            let sum = w.queue_wait + w.compile + w.exec + w.record;
            cpt::prop_assert!(
                (w.total() - sum).abs() < 1e-9,
                "total {} != parts {sum}",
                w.total()
            );
        }
        cpt::prop_assert!(
            s.t_max >= s.t_min || n == 0,
            "t range inverted: [{}, {}]",
            s.t_min,
            s.t_max
        );
        Ok(())
    });
}

#[test]
fn log_level_parsing_is_strict() {
    assert_eq!("warn".parse::<Level>().unwrap(), Level::Warn);
    assert_eq!("WARNING".parse::<Level>().unwrap(), Level::Warn);
    assert_eq!("err".parse::<Level>().unwrap(), Level::Error);
    assert_eq!("debug".parse::<Level>().unwrap(), Level::Debug);
    let e = "vrbose".parse::<Level>().unwrap_err();
    assert!(e.contains("unknown log level 'vrbose'"), "{e}");
}

//! Crash/preempt resume on the PJRT runtime, for single sweeps and for
//! campaign roots. Needs `make artifacts` to have run.

mod common;

use common::{assert_outcomes_identical, fixture, tmp_dir};
use cpt::coordinator::campaign::{
    CampaignMember, CampaignRunOpts, SchedulerKind,
};
use cpt::prelude::*;

#[test]
fn resume_skips_completed_cells_and_recomputes_damaged_ones() {
    let f = fixture();
    let tmp = tmp_dir("resume");
    let spec = || {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "RR".into()];
        s.q_maxes = vec![8.0];
        s.trials = 1;
        s.steps = Some(10);
        s.run_dir = Some(tmp.clone());
        s.resume = true; // fresh dir on first run, reopen afterwards
        s
    };
    let (first, t1) = run_sweep_timed(&f.manifest, &spec()).unwrap();
    assert_eq!(t1.resumed, 0);
    assert_eq!(first.len(), 2);

    // full resume: every cell loads from its artifact, none retrain
    let (second, t2) = run_sweep_timed(&f.manifest, &spec()).unwrap();
    assert_eq!(t2.resumed, 2, "all cells must come from the store");
    assert_outcomes_identical(&first, &second);

    // damage one artifact (simulated crash mid-write of cell 0): only
    // that cell is recomputed, and results still match
    let victim = std::fs::read_dir(&tmp)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| {
            p.file_name()
                .unwrap()
                .to_string_lossy()
                .starts_with("00000")
        })
        .expect("cell 0 artifact");
    std::fs::write(&victim, b"truncated garbage").unwrap();
    let (third, t3) = run_sweep_timed(&f.manifest, &spec()).unwrap();
    assert_eq!(t3.resumed, 1, "only the intact cell may be skipped");
    assert_outcomes_identical(&first, &third);

    // a spec change must refuse to reuse the directory
    let mut other = spec();
    other.trials = 2;
    let err = run_sweep_timed(&f.manifest, &other).unwrap_err();
    assert!(
        err.to_string().contains("different sweep spec"),
        "{err:#}"
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn campaign_resume_skips_recorded_cells_and_refuses_changed_plans() {
    // Campaign-level resume-after-kill: rerunning a completed (or
    // partially completed) root recomputes only what is missing, and a
    // changed member spec refuses the whole tree.
    let f = fixture();
    let root = tmp_dir("campaign_resume");
    let cspec = |steps_b: usize| CampaignSpec {
        name: "resume".into(),
        run_dir: None,
        members: vec![
            CampaignMember {
                name: "a".into(),
                spec: {
                    let mut s = SweepSpec::new("mlp");
                    s.schedules = vec!["CR".into()];
                    s.q_maxes = vec![8.0];
                    s.steps = Some(8);
                    s
                },
                jobs: None,
            },
            CampaignMember {
                name: "b".into(),
                spec: {
                    let mut s = SweepSpec::new("mlp");
                    s.schedules = vec!["RR".into(), "STATIC".into()];
                    s.q_maxes = vec![8.0];
                    s.steps = Some(steps_b);
                    s
                },
                jobs: None,
            },
        ],
    };
    let plan = CampaignPlan::build(&cspec(10)).unwrap();
    let opts = |resume: bool, scheduler: SchedulerKind| CampaignRunOpts {
        root: root.clone(),
        shard: ShardId::single(),
        jobs: 1,
        resume,
        verbose: false,
        scheduler,
    };
    let first =
        run_campaign(&f.manifest, &plan, &opts(false, SchedulerKind::Global))
            .unwrap();
    assert_eq!(first.total_cells(), 3);
    assert_eq!(first.total_resumed(), 0);

    // a second run without --resume refuses the existing root
    let err =
        run_campaign(&f.manifest, &plan, &opts(false, SchedulerKind::Global))
            .unwrap_err();
    assert!(err.to_string().contains("--resume"), "{err:#}");

    // full resume — on the *sequential* path: a global-scheduler root
    // resumes interchangeably, and every cell comes from the store
    let second = run_campaign(
        &f.manifest,
        &plan,
        &opts(true, SchedulerKind::Sequential),
    )
    .unwrap();
    for (a, b) in first.members.iter().zip(&second.members) {
        assert_eq!(a.name, b.name);
        assert_eq!(b.timing.resumed, b.timing.cells, "{} retrained", b.name);
        assert_outcomes_identical(&a.outcomes, &b.outcomes);
    }

    // kill-shaped damage: delete one of member b's artifacts; resume
    // recomputes exactly that cell and reproduces identical results
    let victim = std::fs::read_dir(root.join("b"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| {
            p.file_name()
                .unwrap()
                .to_string_lossy()
                .starts_with("00001")
        })
        .expect("member b cell 1 artifact");
    std::fs::remove_file(&victim).unwrap();
    let third =
        run_campaign(&f.manifest, &plan, &opts(true, SchedulerKind::Global))
            .unwrap();
    let b3 = third.members.iter().find(|r| r.name == "b").unwrap();
    assert_eq!(b3.timing.resumed, 1, "only the intact cell may be skipped");
    for (a, b) in first.members.iter().zip(&third.members) {
        assert_outcomes_identical(&a.outcomes, &b.outcomes);
    }

    // a result-determining change to any member refuses the root
    let changed = CampaignPlan::build(&cspec(11)).unwrap();
    let err =
        run_campaign(&f.manifest, &changed, &opts(true, SchedulerKind::Global))
            .unwrap_err();
    assert!(err.to_string().contains("different campaign"), "{err:#}");
    std::fs::remove_dir_all(&root).ok();
}

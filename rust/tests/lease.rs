//! Lease-based dynamic cell claiming, exercised entirely through
//! fabricated outcomes (no PJRT / AOT artifacts — the CI `test-unit`
//! tier): concurrent claimers must divide a run disjointly and each
//! report the complete result; dead, stalled, and clock-expired claimers
//! must be stolen from without a cell ever being recorded twice; and a
//! claim session over a pre-existing (static-mode) run dir must resume
//! its valid artifacts and recompute only the broken ones.

mod common;

use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use common::{fab_outcome, tmp_dir};
use cpt::coordinator::exec::{CellError, CellRunner, ExecMember};
use cpt::coordinator::lease::{
    self, claim_board_status, claim_workers, ClaimConfig, ClaimMember,
    TestClock,
};
use cpt::coordinator::{read_manifest, ClaimerId};
use cpt::prelude::*;
use cpt::util::propcheck::propcheck;

/// Fabricated worker backend (the `tests/global_sched.rs` pattern):
/// deterministic outcomes via `common::fab_outcome`, optional injected
/// compile failures, optional per-cell sleep to force interleaving.
struct FabRunner {
    fail: HashSet<String>,
    compiled: Vec<String>,
    compiles: usize,
    sleep_ms: u64,
}

impl FabRunner {
    fn plain() -> FabRunner {
        FabRunner {
            fail: HashSet::new(),
            compiled: Vec::new(),
            compiles: 0,
            sleep_ms: 0,
        }
    }

    fn slow(sleep_ms: u64) -> FabRunner {
        FabRunner { sleep_ms, ..FabRunner::plain() }
    }
}

impl CellRunner for FabRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        if self.fail.contains(&member.fingerprint) {
            return Err(CellError::Setup(anyhow::anyhow!(
                "injected compile failure for '{}'",
                member.model
            )));
        }
        if !self.compiled.contains(&member.fingerprint) {
            self.compiled.push(member.fingerprint.clone());
            self.compiles += 1;
        }
        if self.sleep_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.sleep_ms));
        }
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiles, 0.0)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.compiled.iter().any(|f| f == fingerprint)
    }
}

/// A claim member over a small fabricated sweep. Each claimer builds its
/// own copy (the plan is deterministic, so all copies agree).
fn claim_member(
    name: &str,
    model: &str,
    schedules: &[&str],
    trials: usize,
    dir: &Path,
    cap: usize,
) -> ClaimMember {
    let mut s = SweepSpec::new(model);
    s.schedules = schedules.iter().map(|x| x.to_string()).collect();
    s.q_maxes = vec![8.0];
    s.trials = trials;
    s.steps = Some(8);
    let plan = SweepPlan::build(&s).unwrap();
    ClaimMember {
        exec: ExecMember {
            name: name.into(),
            model: model.into(),
            fingerprint: format!("fp-{model}"),
            policy: s.policy.clone(),
            steps: plan.steps,
            cycles: plan.cycles,
            eval_every: s.eval_every,
            cap,
        },
        dir: dir.to_path_buf(),
        spec_hash: plan.spec_hash.clone(),
        cells: plan.cells.clone(),
    }
}

/// The deterministic ground truth a serial run of the member produces.
fn fab_truth(m: &ClaimMember) -> Vec<RunOutcome> {
    m.cells
        .iter()
        .enumerate()
        .map(|(i, c)| fab_outcome(&m.exec.model, c, i))
        .collect()
}

/// Test config: fast polls so waiting claimers spin in milliseconds, a
/// long lease so cooperating claimers never steal by accident.
fn cfg(name: &str) -> ClaimConfig {
    let mut c = ClaimConfig::new(ClaimerId::parse(name).unwrap());
    c.lease_secs = 60.0;
    c.poll_secs = 0.05;
    c
}

#[test]
fn two_claimers_divide_one_sweep_and_both_report_full_results() {
    let tmp = tmp_dir("lease_divide");
    let mdir = tmp.join("run");
    let wdir = tmp.join("run/claim/workers");
    let make = || claim_member("", "mlp", &["CR", "RR", "STATIC"], 2, &mdir, 2);
    let (cfg_a, cfg_b) = (cfg("alice"), cfg("bob"));

    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(|| {
            lease::run_claim("t", vec![make()], &wdir, 2, false, &cfg_a, None, |_| {
                Ok(FabRunner::slow(2))
            })
        });
        let hb = s.spawn(|| {
            lease::run_claim("t", vec![make()], &wdir, 2, false, &cfg_b, None, |_| {
                Ok(FabRunner::slow(2))
            })
        });
        (ha.join().unwrap(), hb.join().unwrap())
    });
    let (outs_a, stats_a) = ra.unwrap();
    let (outs_b, stats_b) = rb.unwrap();

    // both claimers report the COMPLETE canonical result, including the
    // cells their peer computed
    let truth = fab_truth(&make());
    common::assert_outcomes_identical(&truth, &outs_a[0]);
    common::assert_outcomes_identical(&truth, &outs_b[0]);

    // ownership is disjoint and covering: the commit entries are
    // create-exclusive, so committed_here counts partition the plan
    assert_eq!(stats_a.committed_here + stats_b.committed_here, 6);
    assert_eq!(stats_a.stolen + stats_b.stolen, 0, "nothing expired");

    // the rebuilt manifest is an ordinary, complete run manifest
    let ms = read_manifest(&mdir).unwrap();
    assert_eq!(ms.cells.len(), 6);
    assert_eq!(ms.total_cells, 6);

    // the status surfaces see the board and both liveness files
    let now = 1.0e12; // far future: everyone long silent, board complete
    let board = claim_board_status(&mdir, now).unwrap().expect("board");
    assert_eq!(board.committed, 6);
    assert!(board.active.is_empty() && board.expired.is_empty());
    let workers = claim_workers(&mdir, now).unwrap();
    let names: Vec<&str> =
        workers.iter().map(|w| w.claimer.as_str()).collect();
    assert_eq!(names, ["alice", "bob"]);
    assert!(workers.iter().all(|w| !w.looks_alive()));
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn claimers_cover_disjointly_over_random_shapes() {
    // Over random campaign shapes (member count, schedule count, trials,
    // pool sizes): two concurrent claimers always produce a disjoint
    // covering division, and both report every member's full result.
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    propcheck(6, |rng| {
        let case = CASE.fetch_add(1, Ordering::SeqCst);
        let root = tmp_dir(&format!("lease_prop_{case}"));
        let n_members = 1 + rng.below(2) as usize;
        let shapes: Vec<(String, Vec<String>, usize)> = (0..n_members)
            .map(|i| {
                let scheds: Vec<String> = (0..1 + rng.below(3))
                    .map(|k| format!("P{i}S{k}"))
                    .collect();
                (format!("m{i}"), scheds, 1 + rng.below(2) as usize)
            })
            .collect();
        let jobs_a = 1 + rng.below(3) as usize;
        let jobs_b = 1 + rng.below(3) as usize;
        let members = |cap: usize| -> Vec<ClaimMember> {
            shapes
                .iter()
                .map(|(name, scheds, trials)| {
                    let refs: Vec<&str> =
                        scheds.iter().map(|s| s.as_str()).collect();
                    claim_member(
                        name,
                        "mlp",
                        &refs,
                        *trials,
                        &root.join(name),
                        cap,
                    )
                })
                .collect()
        };
        let wdir = root.join("claim/workers");
        let (cfg_a, cfg_b) = (cfg("alice"), cfg("bob"));
        let (ra, rb) = std::thread::scope(|s| {
            let ha = s.spawn(|| {
                lease::run_claim(
                    "t",
                    members(jobs_a),
                    &wdir,
                    jobs_a,
                    false,
                    &cfg_a,
                    None,
                    |_| Ok(FabRunner::slow(1)),
                )
            });
            let hb = s.spawn(|| {
                lease::run_claim(
                    "t",
                    members(jobs_b),
                    &wdir,
                    jobs_b,
                    false,
                    &cfg_b,
                    None,
                    |_| Ok(FabRunner::slow(1)),
                )
            });
            (ha.join().unwrap(), hb.join().unwrap())
        });
        let (outs_a, stats_a) = ra.map_err(|e| format!("claimer a: {e:#}"))?;
        let (outs_b, stats_b) = rb.map_err(|e| format!("claimer b: {e:#}"))?;
        let ms = members(1);
        let total: usize = ms.iter().map(|m| m.cells.len()).sum();
        cpt::prop_assert!(
            stats_a.committed_here + stats_b.committed_here == total,
            "division not disjoint-covering: {} + {} != {total}",
            stats_a.committed_here,
            stats_b.committed_here
        );
        for (mi, m) in ms.iter().enumerate() {
            let truth = fab_truth(m);
            common::assert_outcomes_identical(&truth, &outs_a[mi]);
            common::assert_outcomes_identical(&truth, &outs_b[mi]);
            let manifest = read_manifest(&m.dir).unwrap();
            cpt::prop_assert!(
                manifest.cells.len() == m.cells.len(),
                "member '{}' manifest holds {}/{} cells",
                m.exec.name,
                manifest.cells.len(),
                m.cells.len()
            );
        }
        std::fs::remove_dir_all(&root).ok();
        Ok(())
    });
}

#[test]
fn stalled_claimer_is_stolen_from_and_its_late_commits_are_refused() {
    // Claimer A commits one cell, then goes dark (stall injection: no
    // heartbeats, no claims) while holding leases with work in flight.
    // B steals the expired leases and finishes everything. When A wakes,
    // its in-flight results hit the generation fence and are refused
    // without writing — no cell is recorded twice, and both claimers
    // still report the full, identical result.
    let tmp = tmp_dir("lease_stall");
    let mdir = tmp.join("run");
    let wdir = tmp.join("run/claim/workers");
    let make = || claim_member("", "mlp", &["CR", "RR", "STATIC"], 2, &mdir, 2);

    let mut cfg_a = cfg("staller");
    cfg_a.lease_secs = 0.4;
    cfg_a.stall_after_cells = Some(1);
    cfg_a.stall_secs = 3.0;
    let mut cfg_b = cfg("thief");
    cfg_b.lease_secs = 0.4;

    let (ra, rb) = std::thread::scope(|s| {
        let ha = s.spawn(|| {
            lease::run_claim("t", vec![make()], &wdir, 2, false, &cfg_a, None, |_| {
                Ok(FabRunner::slow(30))
            })
        });
        // let A claim its first batch and commit before B exists
        std::thread::sleep(Duration::from_millis(100));
        let hb = s.spawn(|| {
            lease::run_claim("t", vec![make()], &wdir, 2, false, &cfg_b, None, |_| {
                Ok(FabRunner::slow(1))
            })
        });
        (ha.join().unwrap(), hb.join().unwrap())
    });
    let (outs_a, stats_a) = ra.unwrap();
    let (outs_b, stats_b) = rb.unwrap();

    let truth = fab_truth(&make());
    common::assert_outcomes_identical(&truth, &outs_a[0]);
    common::assert_outcomes_identical(&truth, &outs_b[0]);
    assert!(stats_b.stolen >= 1, "B never stole: {}", stats_b.stolen);
    assert!(
        stats_a.exec.refused >= 1,
        "A's post-stall commits were not fenced: {}",
        stats_a.exec.refused
    );
    // exactly-once despite the theft: committed_here still partitions
    assert_eq!(stats_a.committed_here + stats_b.committed_here, 6);
    assert!(stats_a.committed_here >= 1, "A committed before stalling");
    let ms = read_manifest(&mdir).unwrap();
    assert_eq!(ms.cells.len(), 6);
    // every manifest artifact is present exactly as referenced — a torn
    // or duplicated write could not have produced validating checksums
    for e in ms.cells.values() {
        assert!(mdir.join(&e.file).exists(), "{} missing", e.file);
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn dead_claimer_is_stolen_from_and_the_survivor_completes() {
    // Claimer A dies (halt injection) right after its first commit,
    // holding live leases. A fresh claimer B steals them once they
    // expire and finishes the run without any intervention.
    let tmp = tmp_dir("lease_dead");
    let mdir = tmp.join("run");
    let wdir = tmp.join("run/claim/workers");
    let make = || claim_member("", "mlp", &["CR", "RR", "STATIC"], 2, &mdir, 2);

    let mut cfg_a = cfg("victim");
    cfg_a.lease_secs = 0.3;
    let err = lease::run_claim(
        "t",
        vec![make()],
        &wdir,
        2,
        false,
        &cfg_a,
        Some(1), // die after one freshly recorded cell
        |_| Ok(FabRunner::plain()),
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("halted after"), "{err:#}");

    let mut cfg_b = cfg("survivor");
    cfg_b.lease_secs = 0.3;
    let (outs, stats) = lease::run_claim(
        "t",
        vec![make()],
        &wdir,
        2,
        false,
        &cfg_b,
        None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    common::assert_outcomes_identical(&fab_truth(&make()), &outs[0]);
    assert_eq!(stats.resumed(), 1, "A's one committed cell survived");
    assert_eq!(stats.committed_here, 5);
    assert!(stats.stolen >= 1, "B reclaimed A's abandoned leases");
    assert_eq!(read_manifest(&mdir).unwrap().cells.len(), 6);
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn expired_lease_steal_is_gated_on_the_injected_clock() {
    // A ghost claimer holds a live lease on the only cell. With the
    // injected clock standing still, the claimer can only wait; once the
    // clock advances past the deadline, it steals (generation 2) and
    // completes. No real lease periods are slept through.
    let tmp = tmp_dir("lease_clock");
    let mdir = tmp.join("run");
    let leases = mdir.join("claim/leases");
    std::fs::create_dir_all(&leases).unwrap();
    std::fs::write(
        leases.join("00000.g1.json"),
        "{\n  \"claimer\": \"ghost\",\n  \"deadline\": 1050.0,\n  \
         \"generation\": 1,\n  \"kind\": \"cpt-lease\"\n}\n",
    )
    .unwrap();
    let clock = Arc::new(TestClock::new(1000.0));
    let mut c = cfg("timekeeper");
    c.clock = clock.clone();
    c.auto_heartbeat = false; // frozen clock: beats would be no-ops anyway
    let wdir = mdir.join("claim/workers");
    let make = || claim_member("", "mlp", &["CR"], 1, &mdir, 1);

    let (outs, stats) = std::thread::scope(|s| {
        let h = s.spawn(|| {
            lease::run_claim("t", vec![make()], &wdir, 1, false, &c, None, |_| {
                Ok(FabRunner::plain())
            })
        });
        // the ghost's lease is live at t=1000: the claimer can only poll
        std::thread::sleep(Duration::from_millis(150));
        clock.advance(100.0); // t=1100 > deadline 1050: steal-eligible
        h.join().unwrap()
    })
    .unwrap();
    common::assert_outcomes_identical(&fab_truth(&make()), &outs[0]);
    assert_eq!(stats.stolen, 1, "the expired ghost lease was stolen");
    assert_eq!(stats.committed_here, 1);
    // the steal superseded, never deleted: both generations are on file
    assert!(leases.join("00000.g1.json").exists());
    assert!(leases.join("00000.g2.json").exists());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn claim_resumes_a_static_manifest_and_recomputes_invalid_artifacts() {
    let tmp = tmp_dir("lease_seed");
    let mdir = tmp.join("run");
    let wdir = tmp.join("run/claim/workers");
    let make = || claim_member("", "mlp", &["CR", "RR"], 2, &mdir, 2);

    // first claim session completes and leaves an ordinary manifest
    let (_, stats) = lease::run_claim(
        "t", vec![make()], &wdir, 2, false, &cfg("seed-a"), None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    assert_eq!(stats.committed_here, 4);

    // strip the claim board: the dir now looks exactly like a static
    // (non-claim) run dir — manifest + artifacts, no coordination state
    std::fs::remove_dir_all(mdir.join(lease::CLAIM_DIR)).unwrap();
    let (outs, stats) = lease::run_claim(
        "t", vec![make()], &wdir, 2, false, &cfg("seed-b"), None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    common::assert_outcomes_identical(&fab_truth(&make()), &outs[0]);
    assert_eq!(stats.resumed(), 4, "every manifest cell was seeded");
    assert_eq!(stats.committed_here, 0);

    // a broken artifact must NOT be laundered into a commit entry: strip
    // the board again, delete cell 0's artifact, and re-claim
    std::fs::remove_dir_all(mdir.join(lease::CLAIM_DIR)).unwrap();
    let lost = read_manifest(&mdir).unwrap().cells[&0].file.clone();
    std::fs::remove_file(mdir.join(&lost)).unwrap();
    let (outs, stats) = lease::run_claim(
        "t", vec![make()], &wdir, 2, false, &cfg("seed-c"), None,
        |_| Ok(FabRunner::plain()),
    )
    .unwrap();
    common::assert_outcomes_identical(&fab_truth(&make()), &outs[0]);
    assert_eq!(stats.resumed(), 3);
    assert_eq!(stats.committed_here, 1, "only the broken cell recomputed");
    let healed = read_manifest(&mdir).unwrap();
    assert!(
        healed.cells[&0].file.ends_with(".seed-c.json"),
        "cell 0 should reference the recomputing claimer's artifact, got {}",
        healed.cells[&0].file
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn refill_fails_loudly_when_no_one_can_run_the_remaining_cells() {
    // Every worker of the only claimer fails to compile the model and no
    // peer holds a live lease: the run must error out, not spin forever.
    let tmp = tmp_dir("lease_nocompile");
    let mdir = tmp.join("run");
    let wdir = tmp.join("run/claim/workers");
    let make = || claim_member("", "mlp", &["CR"], 1, &mdir, 1);
    let err = lease::run_claim(
        "t",
        vec![make()],
        &wdir,
        1,
        false,
        &cfg("lonely"),
        None,
        |_| {
            let mut r = FabRunner::plain();
            r.fail.insert("fp-mlp".into());
            Ok(r)
        },
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("no worker in this process can compile")
            || msg.contains("no other claimer holds a live lease"),
        "{msg}"
    );
    std::fs::remove_dir_all(&tmp).ok();
}

//! Sweep execution equivalence, end to end on the PJRT runtime: parallel
//! vs serial, sharded + merged vs serial, and campaigns vs independent
//! member sweeps. Needs `make artifacts` to have run.

mod common;

use common::{assert_outcomes_identical, fixture, tiny_mlp_spec, tmp_dir};
use cpt::coordinator::campaign::{
    CampaignMember, CampaignRunOpts, SchedulerKind,
};
use cpt::prelude::*;

#[test]
fn parallel_sweep_outcomes_bit_identical_to_serial() {
    // The work-queue executor must produce the same RunOutcomes (metrics,
    // GBitOps, full history) in the same order as serial execution —
    // every cell is an independently seeded run, so only wall-clock may
    // differ.
    let f = fixture();
    let mut spec = tiny_mlp_spec();
    spec.steps = Some(16);
    spec.eval_every = 8;

    spec.jobs = 1;
    let serial = run_sweep(&f.manifest, &spec).unwrap();
    spec.jobs = 3;
    let parallel = run_sweep(&f.manifest, &spec).unwrap();

    assert_eq!(serial.len(), 6);
    assert_outcomes_identical(&serial, &parallel);
}

#[test]
fn sharded_sweep_plus_merge_is_bit_identical_to_serial() {
    // The headline acceptance path: shard 1/2 + shard 2/2 into run dirs,
    // merge, and compare against the unsharded serial run — outcome by
    // outcome (bitwise, including history) and as CSV bytes.
    let f = fixture();
    let tmp = tmp_dir("shard_merge");
    let serial = run_sweep(&f.manifest, &tiny_mlp_spec()).unwrap();
    assert_eq!(serial.len(), 6);

    let mut dirs = Vec::new();
    for i in 1..=2usize {
        let mut s = tiny_mlp_spec();
        s.shard = Some(ShardId::parse(&format!("{i}/2")).unwrap());
        let dir = tmp.join(format!("shard{i}"));
        s.run_dir = Some(dir.clone());
        let (outs, timing) = run_sweep_timed(&f.manifest, &s).unwrap();
        assert_eq!(outs.len(), 3, "round-robin halves of 6 cells");
        assert_eq!(timing.cells, 3);
        assert_eq!(timing.resumed, 0);
        dirs.push(dir);
    }
    let (model, merged) = merge_run_dirs(&dirs).unwrap();
    assert_eq!(model, "mlp");
    assert_outcomes_identical(&serial, &merged);

    // CSV byte-identity on the deterministic aggregate columns
    let rep = SweepReport::new("t", "metric", true);
    let pa = tmp.join("serial.csv");
    let pb = tmp.join("merged.csv");
    rep.write_csv_stable(&aggregate(&serial), &pa).unwrap();
    rep.write_csv_stable(&aggregate(&merged), &pb).unwrap();
    let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    assert_eq!(ba, bb, "merged CSV must be byte-identical to serial");
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn campaign_shards_merge_byte_identical_to_independent_sweeps() {
    // A 2-sweep campaign run as 2 shards, cross-merged, must reproduce
    // each member sweep bit-for-bit — outcomes and stable CSV bytes —
    // exactly as if the sweeps had been run independently and serially.
    let f = fixture();
    let tmp = tmp_dir("campaign_e2e");
    let spec_a = {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "RR".into()];
        s.q_maxes = vec![8.0];
        s.steps = Some(8);
        s
    };
    let spec_b = {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "STATIC".into()];
        s.q_maxes = vec![8.0];
        s.steps = Some(10);
        s
    };
    let cspec = CampaignSpec {
        name: "e2e".into(),
        run_dir: None,
        members: vec![
            CampaignMember { name: "a".into(), spec: spec_a.clone(), jobs: None },
            CampaignMember { name: "b".into(), spec: spec_b.clone(), jobs: None },
        ],
    };
    let plan = CampaignPlan::build(&cspec).unwrap();

    let mut roots = Vec::new();
    for i in 1..=2usize {
        let root = tmp.join(format!("root{i}"));
        // alternate schedulers across the shards: the merge below proves
        // the global pool and the sequential path are interchangeable
        let opts = CampaignRunOpts {
            root: root.clone(),
            shard: ShardId::parse(&format!("{i}/2")).unwrap(),
            jobs: if i == 1 { 2 } else { 1 },
            resume: false,
            verbose: false,
            scheduler: if i == 1 {
                SchedulerKind::Global
            } else {
                SchedulerKind::Sequential
            },
        };
        let result = run_campaign(&f.manifest, &plan, &opts).unwrap();
        assert_eq!(result.members.len(), 2);
        // each member has 2 cells; every shard owns 1 of each
        assert!(result.members.iter().all(|r| r.timing.cells == 1));
        if i == 1 {
            // 2 members share one model: with 2 workers the pool must
            // compile strictly fewer than members x workers times
            let sc = result.scheduler.as_ref().expect("global stats");
            assert!(
                sc.total_compiles() < 2 * 2,
                "shared-model campaign compiled {} times",
                sc.total_compiles()
            );
        } else {
            assert!(result.scheduler.is_none());
        }
        roots.push(root);
    }

    let merged = merge_campaign_roots(&roots).unwrap();
    assert_eq!(merged.name, "e2e");
    assert_eq!(merged.members.len(), 2);
    for (name, spec) in [("a", &spec_a), ("b", &spec_b)] {
        let serial = run_sweep(&f.manifest, spec).unwrap();
        let mm = merged
            .members
            .iter()
            .find(|m| m.name == name)
            .unwrap_or_else(|| panic!("member '{name}' missing from merge"));
        assert_eq!(mm.model, "mlp");
        assert_outcomes_identical(&serial, &mm.outcomes);

        let rep = SweepReport::new(name, "metric", true);
        let pa = tmp.join(format!("{name}_independent.csv"));
        let pb = tmp.join(format!("{name}_campaign.csv"));
        rep.write_csv_stable(&aggregate(&serial), &pa).unwrap();
        rep.write_csv_stable(&aggregate(&mm.outcomes), &pb).unwrap();
        assert_eq!(
            std::fs::read(&pa).unwrap(),
            std::fs::read(&pb).unwrap(),
            "campaign member '{name}' CSV must match the independent sweep"
        );
    }
    std::fs::remove_dir_all(&tmp).ok();
}

//! `cpt serve` end to end over fabricated cell runners (no PJRT — the
//! CI `test-unit` tier): submit → poll → fetch must be byte-identical
//! to the same spec through the direct campaign path, identical
//! resubmissions must dedupe to zero new executions, simultaneous
//! submissions must collapse to one job, and a daemon restarted over a
//! dead daemon's debris must recover its interrupted jobs.

mod common;

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use common::{fab_outcome, tmp_dir};
use cpt::config::toml::TomlDoc;
use cpt::coordinator::campaign::{
    run_campaign_global, CampaignRunOpts, SchedulerKind,
};
use cpt::coordinator::exec::{CellError, CellRunner, ExecMember};
use cpt::coordinator::lease::TestClock;
use cpt::coordinator::report;
use cpt::prelude::*;
use cpt::server::{jobs, Client, JobRecord, JobState, ServeOpts, Server};

/// The spec every test submits: two members sharing one model, 4 cells
/// total (mirrors the global-scheduler test campaign).
fn campaign_toml() -> String {
    "[campaign]\n\
     name = \"servecamp\"\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"a\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\", \"RR\"]\n\
     q_maxes = [8.0]\n\
     trials = 1\n\
     steps = 8\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"b\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\", \"STATIC\"]\n\
     q_maxes = [8.0]\n\
     trials = 1\n\
     steps = 10\n"
        .to_string()
}

fn plan_of(spec_toml: &str) -> CampaignPlan {
    let doc = TomlDoc::parse(spec_toml).unwrap();
    CampaignPlan::build(&CampaignSpec::from_toml(&doc).unwrap()).unwrap()
}

/// Fabricated worker: deterministic outcomes, global executed-cell
/// counter — the zero-new-cells dedupe assertions hang off it.
struct CountingRunner {
    cells: Arc<AtomicUsize>,
}

impl CellRunner for CountingRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        self.cells.fetch_add(1, Ordering::SeqCst);
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (0, 0.0)
    }

    fn has_cached(&self, _fingerprint: &str) -> bool {
        true
    }
}

fn fingerprints(plan: &CampaignPlan) -> HashMap<String, String> {
    plan.members
        .iter()
        .map(|m| (m.spec.model.clone(), format!("fp-{}", m.spec.model)))
        .collect()
}

/// A start gate for the executor, so a test can hold the job mid-flight
/// while clients race their submissions.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate { open: Mutex::new(false), cv: Condvar::new() }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut g = self.open.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// The production exec shape (`run_campaign` over the artifact
/// manifest), with fabricated workers: same scheduler, same stores,
/// same resume semantics — plus execution/cell counters.
fn counting_exec(
    execs: Arc<AtomicUsize>,
    cells: Arc<AtomicUsize>,
    gate: Option<Arc<Gate>>,
) -> cpt::server::CampaignExec {
    Arc::new(move |plan, opts| {
        if let Some(g) = &gate {
            g.wait_open();
        }
        execs.fetch_add(1, Ordering::SeqCst);
        let fps = fingerprints(plan);
        run_campaign_global(plan, opts, &fps, None, |_| {
            Ok(CountingRunner { cells: cells.clone() })
        })
    })
}

fn serve_opts(root: &Path) -> ServeOpts {
    ServeOpts {
        root: root.to_path_buf(),
        listen: "127.0.0.1:0".to_string(),
        jobs: 2,
        verbose: false,
    }
}

#[test]
fn submit_poll_fetch_is_byte_identical_to_direct_campaign_and_caches() {
    let tmp = tmp_dir("serve_e2e");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);

    // ground truth: the identical spec through the direct campaign path
    // (`cpt campaign` reports through the same write_campaign_csv_tree)
    let direct = run_campaign_global(
        &plan,
        &CampaignRunOpts {
            root: tmp.join("direct"),
            shard: ShardId::single(),
            jobs: 2,
            resume: false,
            verbose: false,
            scheduler: SchedulerKind::Global,
        },
        &fingerprints(&plan),
        None,
        |_| Ok(CountingRunner { cells: Arc::new(AtomicUsize::new(0)) }),
    )
    .unwrap();
    let truth_dir = tmp.join("truth");
    report::write_campaign_csv_tree(
        &truth_dir,
        direct
            .members
            .iter()
            .map(|m| (m.name.as_str(), m.outcomes.as_slice())),
    )
    .unwrap();

    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let serve_root = tmp.join("serve");
    let srv = Server::start(
        serve_opts(&serve_root),
        counting_exec(execs.clone(), cells.clone(), None),
        Arc::new(TestClock::new(100.0)),
    )
    .unwrap();
    // the bound address is published for `cpt submit --connect`
    assert_eq!(
        std::fs::read_to_string(serve_root.join(jobs::SERVE_ADDR_FILE))
            .unwrap(),
        srv.addr()
    );

    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, state, attached) = client.submit(&spec_toml).unwrap();
    assert_eq!(ticket, plan.campaign_hash, "ticket IS the campaign hash");
    assert_eq!(state, JobState::Queued);
    assert!(!attached);

    let v = client.wait_done(&ticket, 5).unwrap();
    assert_eq!(v.state, JobState::Done);
    assert_eq!(v.planned, plan.total_cells());
    assert_eq!(v.done, Some(plan.total_cells()));

    let files = client.result_files(&ticket).unwrap();
    let names: Vec<&str> = files.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, vec!["a.csv", "b.csv", "campaign.csv"]);
    for (name, data) in &files {
        let want = std::fs::read_to_string(truth_dir.join(name)).unwrap();
        assert_eq!(
            data, &want,
            "{name} differs between `cpt serve` and the direct campaign"
        );
    }
    assert_eq!(execs.load(Ordering::SeqCst), 1);
    assert_eq!(cells.load(Ordering::SeqCst), plan.total_cells());

    // resubmitting the identical spec is a pure cache hit: same ticket,
    // attached to the done job, identical bytes, zero new executions
    // and zero new cells
    let (t2, s2, attached2) = client.submit(&spec_toml).unwrap();
    assert_eq!(t2, ticket);
    assert_eq!(s2, JobState::Done);
    assert!(attached2, "identical spec must dedupe onto the done job");
    assert_eq!(client.result_files(&ticket).unwrap(), files);
    assert_eq!(execs.load(Ordering::SeqCst), 1, "cache hit re-executed");
    assert_eq!(
        cells.load(Ordering::SeqCst),
        plan.total_cells(),
        "cache hit ran new cells"
    );

    // `jobs` over the wire and `cpt status <serve root>` (serve_status)
    // agree on the one done job
    let listed = client.jobs().unwrap();
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].ticket, ticket);
    assert_eq!(listed[0].state, JobState::Done);
    assert!(jobs::is_serve_root(&serve_root));
    let views = jobs::serve_status(&serve_root).unwrap();
    assert_eq!(views.len(), 1);
    assert_eq!(views[0].ticket, ticket);
    assert_eq!(views[0].state, JobState::Done);
    assert_eq!(views[0].done, Some(plan.total_cells()));

    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn simultaneous_identical_submissions_execute_exactly_once() {
    let tmp = tmp_dir("serve_race");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);
    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let gate = Arc::new(Gate::new());
    let srv = Server::start(
        serve_opts(&tmp.join("serve")),
        counting_exec(execs.clone(), cells.clone(), Some(gate.clone())),
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let addr = srv.addr().to_string();

    // two clients submit the identical spec concurrently while the
    // gate holds the executor mid-job
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec_toml.clone();
            std::thread::spawn(move || {
                Client::connect(&addr).unwrap().submit(&spec).unwrap()
            })
        })
        .collect();
    let subs: Vec<(String, JobState, bool)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert_eq!(subs[0].0, plan.campaign_hash);
    assert_eq!(subs[1].0, subs[0].0, "both clients share one ticket");
    let fresh = subs.iter().filter(|(_, _, attached)| !attached).count();
    assert_eq!(fresh, 1, "exactly one submission created the job: {subs:?}");

    // the job is in flight: result is a typed not_done error
    let ticket = subs[0].0.clone();
    let mut a = Client::connect(&addr).unwrap();
    let err = a.result_files(&ticket).unwrap_err().to_string();
    assert!(err.contains("not_done"), "{err}");

    gate.open();
    a.wait_done(&ticket, 5).unwrap();
    let fa = a.result_files(&ticket).unwrap();
    let fb = Client::connect(&addr).unwrap().result_files(&ticket).unwrap();
    assert_eq!(fa, fb, "both clients read byte-identical results");
    assert_eq!(
        execs.load(Ordering::SeqCst),
        1,
        "two submissions, one execution"
    );
    assert_eq!(cells.load(Ordering::SeqCst), plan.total_cells());

    a.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn restart_recovers_interrupted_jobs_and_fences_tampered_specs() {
    let tmp = tmp_dir("serve_recover");
    let serve_root = tmp.join("serve");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);
    let ticket = plan.campaign_hash.clone();

    // fabricate the debris of a daemon that died mid-job: a `running`
    // record whose spec is intact, and a sibling whose recorded ticket
    // does not match its spec bytes (tampered / half-written)
    jobs::init_serve_root(&serve_root).unwrap();
    cpt::util::write_atomic(
        jobs::job_dir(&serve_root, &ticket).join(jobs::JOB_SPEC_FILE),
        spec_toml.as_bytes(),
    )
    .unwrap();
    JobRecord {
        ticket: ticket.clone(),
        name: plan.name.clone(),
        state: JobState::Running,
        planned: plan.total_cells(),
        submitted: 1.0,
        finished: None,
        error: None,
    }
    .store(&serve_root)
    .unwrap();
    let bad_ticket = "00000000deadbeef";
    cpt::util::write_atomic(
        jobs::job_dir(&serve_root, bad_ticket).join(jobs::JOB_SPEC_FILE),
        spec_toml.as_bytes(),
    )
    .unwrap();
    JobRecord {
        ticket: bad_ticket.to_string(),
        name: plan.name.clone(),
        state: JobState::Queued,
        planned: plan.total_cells(),
        submitted: 2.0,
        finished: None,
        error: None,
    }
    .store(&serve_root)
    .unwrap();

    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let srv = Server::start(
        serve_opts(&serve_root),
        counting_exec(execs.clone(), cells.clone(), None),
        Arc::new(TestClock::new(50.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();

    // the interrupted job was requeued and runs to completion
    let v = client.wait_done(&ticket, 5).unwrap();
    assert_eq!(v.state, JobState::Done);
    assert_eq!(execs.load(Ordering::SeqCst), 1);
    client.result_files(&ticket).unwrap();

    // the tampered job was fenced to `failed` at recovery, not executed
    let bad = client.status(bad_ticket).unwrap();
    assert_eq!(bad.state, JobState::Failed);
    assert!(
        bad.error.as_deref().unwrap_or("").contains("recovery"),
        "{:?}",
        bad.error
    );

    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn a_failed_job_reports_its_error_and_leaves_the_daemon_healthy() {
    let tmp = tmp_dir("serve_fail");
    let exec: cpt::server::CampaignExec =
        Arc::new(|_, _| anyhow::bail!("injected executor failure"));
    let srv = Server::start(
        serve_opts(&tmp.join("serve")),
        exec,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, _, _) = client.submit(&campaign_toml()).unwrap();

    let v = loop {
        let v = client.status(&ticket).unwrap();
        if v.state.is_terminal() {
            break v;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    };
    assert_eq!(v.state, JobState::Failed);
    assert!(
        v.error.as_deref().unwrap().contains("injected executor failure"),
        "{:?}",
        v.error
    );
    // `result` maps the failure to its typed code; `wait_done` to an Err
    let err = client.result_files(&ticket).unwrap_err().to_string();
    assert!(err.contains("job_failed"), "{err}");
    let err = client.wait_done(&ticket, 5).unwrap_err().to_string();
    assert!(err.contains("injected executor failure"), "{err}");
    // the executor survives a failed job
    client.ping().unwrap();
    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

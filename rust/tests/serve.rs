//! `cpt serve` end to end over fabricated cell runners (no PJRT — the
//! CI `test-unit` tier): submit → poll → fetch must be byte-identical
//! to the same spec through the direct campaign path, identical
//! resubmissions must dedupe to zero new executions, simultaneous
//! submissions must collapse to one job, and a daemon restarted over a
//! dead daemon's debris must recover its interrupted jobs. The pooled
//! half drives the shared persistent worker pool exactly as `cpt serve`
//! wires it: cross-job warm compiles, fair-share scheduling between
//! concurrent jobs, graceful drain on shutdown, gc over the wire, and
//! the non-loopback bind guard.

mod common;

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use common::{fab_outcome, tmp_dir};
use cpt::config::toml::TomlDoc;
use cpt::coordinator::campaign::{
    run_campaign_global, run_campaign_pooled, CampaignRunOpts, SchedulerKind,
};
use cpt::coordinator::exec::{CacheStats, CellError, CellRunner, ExecMember};
use cpt::coordinator::lease::TestClock;
use cpt::coordinator::{pool, report};
use cpt::prelude::*;
use cpt::server::{jobs, Client, JobRecord, JobState, ServeOpts, Server};

/// The spec every test submits: two members sharing one model, 4 cells
/// total (mirrors the global-scheduler test campaign).
fn campaign_toml() -> String {
    "[campaign]\n\
     name = \"servecamp\"\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"a\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\", \"RR\"]\n\
     q_maxes = [8.0]\n\
     trials = 1\n\
     steps = 8\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"b\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\", \"STATIC\"]\n\
     q_maxes = [8.0]\n\
     trials = 1\n\
     steps = 10\n"
        .to_string()
}

/// A second, distinct spec (its own ticket) sharing the same model —
/// the cross-job warm-compile assertions submit this after
/// [`campaign_toml`].
fn campaign_toml2() -> String {
    "[campaign]\n\
     name = \"servecamp2\"\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"c\"\n\
     model = \"mlp\"\n\
     schedules = [\"RR\", \"STATIC\"]\n\
     q_maxes = [8.0]\n\
     trials = 1\n\
     steps = 12\n"
        .to_string()
}

/// 18 cells — enough runway for a small job to overtake it, and for a
/// shutdown to land mid-flight.
fn big_campaign_toml() -> String {
    "[campaign]\n\
     name = \"bigcamp\"\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"big\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\", \"RR\", \"STATIC\"]\n\
     q_maxes = [4.0, 6.0, 8.0]\n\
     trials = 2\n\
     steps = 8\n"
        .to_string()
}

/// 2 cells — the latecomer fair-share must let finish first.
fn small_campaign_toml() -> String {
    "[campaign]\n\
     name = \"smallcamp\"\n\
     \n\
     [[campaign.sweep]]\n\
     name = \"small\"\n\
     model = \"mlp\"\n\
     schedules = [\"CR\"]\n\
     q_maxes = [8.0]\n\
     trials = 2\n\
     steps = 8\n"
        .to_string()
}

fn plan_of(spec_toml: &str) -> CampaignPlan {
    let doc = TomlDoc::parse(spec_toml).unwrap();
    CampaignPlan::build(&CampaignSpec::from_toml(&doc).unwrap()).unwrap()
}

/// Fabricated worker: deterministic outcomes, global executed-cell
/// counter — the zero-new-cells dedupe assertions hang off it.
struct CountingRunner {
    cells: Arc<AtomicUsize>,
}

impl CellRunner for CountingRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        self.cells.fetch_add(1, Ordering::SeqCst);
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (0, 0.0)
    }

    fn has_cached(&self, _fingerprint: &str) -> bool {
        true
    }
}

fn fingerprints(plan: &CampaignPlan) -> HashMap<String, String> {
    plan.members
        .iter()
        .map(|m| (m.spec.model.clone(), format!("fp-{}", m.spec.model)))
        .collect()
}

/// A start gate for the executor, so a test can hold the job mid-flight
/// while clients race their submissions.
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate { open: Mutex::new(false), cv: Condvar::new() }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut g = self.open.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// The production exec shape (`run_campaign` over the artifact
/// manifest), with fabricated workers: same scheduler, same stores,
/// same resume semantics — plus execution/cell counters.
fn counting_exec(
    execs: Arc<AtomicUsize>,
    cells: Arc<AtomicUsize>,
    gate: Option<Arc<Gate>>,
) -> cpt::server::CampaignExec {
    Arc::new(move |plan, opts| {
        if let Some(g) = &gate {
            g.wait_open();
        }
        execs.fetch_add(1, Ordering::SeqCst);
        let fps = fingerprints(plan);
        run_campaign_global(plan, opts, &fps, None, |_| {
            Ok(CountingRunner { cells: cells.clone() })
        })
    })
}

fn serve_opts(root: &Path) -> ServeOpts {
    ServeOpts {
        root: root.to_path_buf(),
        listen: "127.0.0.1:0".to_string(),
        jobs: 2,
        concurrent: 1,
        allow_remote: false,
        verbose: false,
    }
}

/// Pool worker with per-worker compile tracking: first sight of a
/// fingerprint is a compile (and a cache miss), every later cell is a
/// hit. The cross-job warm-start assertions hang off the compile
/// counter staying flat on the second job.
struct PoolRunner {
    compiled: Vec<String>,
    compiles: Arc<AtomicUsize>,
    cells: Arc<AtomicUsize>,
    stats: CacheStats,
    sleep_ms: u64,
}

impl CellRunner for PoolRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        _per_step_logs: bool,
    ) -> Result<RunOutcome, CellError> {
        if self.compiled.iter().any(|f| f == &member.fingerprint) {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
            self.compiled.push(member.fingerprint.clone());
            self.compiles.fetch_add(1, Ordering::SeqCst);
        }
        if self.sleep_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.sleep_ms,
            ));
        }
        self.cells.fetch_add(1, Ordering::SeqCst);
        Ok(fab_outcome(&member.model, cell, cell_index))
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiled.len(), 0.0)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.compiled.iter().any(|f| f == fingerprint)
    }

    fn cache_stats(&self) -> CacheStats {
        self.stats
    }
}

/// A shared persistent pool over [`PoolRunner`] workers — the daemon's
/// production wiring, minus PJRT.
fn test_pool(
    size: usize,
    compiles: &Arc<AtomicUsize>,
    cells: &Arc<AtomicUsize>,
    sleep_ms: u64,
) -> Arc<pool::WorkerPool> {
    let compiles = compiles.clone();
    let cells = cells.clone();
    let factory: Arc<pool::WorkerFactory> = Arc::new(move |_| {
        Ok(Box::new(PoolRunner {
            compiled: Vec::new(),
            compiles: compiles.clone(),
            cells: cells.clone(),
            stats: CacheStats::default(),
            sleep_ms,
        }) as Box<dyn CellRunner>)
    });
    Arc::new(pool::WorkerPool::new(size, "test", factory))
}

/// The serve exec shape `cpt serve` builds: every job routes through
/// one shared pool via `run_campaign_pooled`. `order` records campaign
/// names as their jobs complete (the fair-share assertion).
fn pooled_exec(
    pool: &Arc<pool::WorkerPool>,
    order: Option<Arc<Mutex<Vec<String>>>>,
) -> cpt::server::CampaignExec {
    let pool = pool.clone();
    Arc::new(move |plan, opts| {
        let fps = fingerprints(plan);
        let res = run_campaign_pooled(plan, opts, &fps, None, &pool);
        if res.is_ok() {
            if let Some(order) = &order {
                order.lock().unwrap().push(plan.name.clone());
            }
        }
        res
    })
}

#[test]
fn submit_poll_fetch_is_byte_identical_to_direct_campaign_and_caches() {
    let tmp = tmp_dir("serve_e2e");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);

    // ground truth: the identical spec through the direct campaign path
    // (`cpt campaign` reports through the same write_campaign_csv_tree)
    let direct = run_campaign_global(
        &plan,
        &CampaignRunOpts {
            root: tmp.join("direct"),
            shard: ShardId::single(),
            jobs: 2,
            resume: false,
            verbose: false,
            scheduler: SchedulerKind::Global,
        },
        &fingerprints(&plan),
        None,
        |_| Ok(CountingRunner { cells: Arc::new(AtomicUsize::new(0)) }),
    )
    .unwrap();
    let truth_dir = tmp.join("truth");
    report::write_campaign_csv_tree(
        &truth_dir,
        direct
            .members
            .iter()
            .map(|m| (m.name.as_str(), m.outcomes.as_slice())),
    )
    .unwrap();

    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let serve_root = tmp.join("serve");
    let srv = Server::start(
        serve_opts(&serve_root),
        counting_exec(execs.clone(), cells.clone(), None),
        None,
        Arc::new(TestClock::new(100.0)),
    )
    .unwrap();
    // the bound address is published for `cpt submit --connect`
    assert_eq!(
        std::fs::read_to_string(serve_root.join(jobs::SERVE_ADDR_FILE))
            .unwrap(),
        srv.addr()
    );

    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, state, attached) = client.submit(&spec_toml).unwrap();
    assert_eq!(ticket, plan.campaign_hash, "ticket IS the campaign hash");
    assert_eq!(state, JobState::Queued);
    assert!(!attached);

    let v = client.wait_done(&ticket, 5).unwrap();
    assert_eq!(v.state, JobState::Done);
    assert_eq!(v.planned, plan.total_cells());
    assert_eq!(v.done, Some(plan.total_cells()));

    let files = client.result_files(&ticket).unwrap();
    let names: Vec<&str> = files.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, vec!["a.csv", "b.csv", "campaign.csv"]);
    for (name, data) in &files {
        let want = std::fs::read_to_string(truth_dir.join(name)).unwrap();
        assert_eq!(
            data, &want,
            "{name} differs between `cpt serve` and the direct campaign"
        );
    }
    assert_eq!(execs.load(Ordering::SeqCst), 1);
    assert_eq!(cells.load(Ordering::SeqCst), plan.total_cells());

    // resubmitting the identical spec is a pure cache hit: same ticket,
    // attached to the done job, identical bytes, zero new executions
    // and zero new cells
    let (t2, s2, attached2) = client.submit(&spec_toml).unwrap();
    assert_eq!(t2, ticket);
    assert_eq!(s2, JobState::Done);
    assert!(attached2, "identical spec must dedupe onto the done job");
    assert_eq!(client.result_files(&ticket).unwrap(), files);
    assert_eq!(execs.load(Ordering::SeqCst), 1, "cache hit re-executed");
    assert_eq!(
        cells.load(Ordering::SeqCst),
        plan.total_cells(),
        "cache hit ran new cells"
    );

    // `jobs` over the wire and `cpt status <serve root>` (serve_status)
    // agree on the one done job
    let listed = client.jobs().unwrap();
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].ticket, ticket);
    assert_eq!(listed[0].state, JobState::Done);
    assert!(jobs::is_serve_root(&serve_root));
    let views = jobs::serve_status(&serve_root).unwrap();
    assert_eq!(views.len(), 1);
    assert_eq!(views[0].ticket, ticket);
    assert_eq!(views[0].state, JobState::Done);
    assert_eq!(views[0].done, Some(plan.total_cells()));

    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn simultaneous_identical_submissions_execute_exactly_once() {
    let tmp = tmp_dir("serve_race");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);
    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let gate = Arc::new(Gate::new());
    let srv = Server::start(
        serve_opts(&tmp.join("serve")),
        counting_exec(execs.clone(), cells.clone(), Some(gate.clone())),
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let addr = srv.addr().to_string();

    // two clients submit the identical spec concurrently while the
    // gate holds the executor mid-job
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec_toml.clone();
            std::thread::spawn(move || {
                Client::connect(&addr).unwrap().submit(&spec).unwrap()
            })
        })
        .collect();
    let subs: Vec<(String, JobState, bool)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert_eq!(subs[0].0, plan.campaign_hash);
    assert_eq!(subs[1].0, subs[0].0, "both clients share one ticket");
    let fresh = subs.iter().filter(|(_, _, attached)| !attached).count();
    assert_eq!(fresh, 1, "exactly one submission created the job: {subs:?}");

    // the job is in flight: result is a typed not_done error
    let ticket = subs[0].0.clone();
    let mut a = Client::connect(&addr).unwrap();
    let err = a.result_files(&ticket).unwrap_err().to_string();
    assert!(err.contains("not_done"), "{err}");

    gate.open();
    a.wait_done(&ticket, 5).unwrap();
    let fa = a.result_files(&ticket).unwrap();
    let fb = Client::connect(&addr).unwrap().result_files(&ticket).unwrap();
    assert_eq!(fa, fb, "both clients read byte-identical results");
    assert_eq!(
        execs.load(Ordering::SeqCst),
        1,
        "two submissions, one execution"
    );
    assert_eq!(cells.load(Ordering::SeqCst), plan.total_cells());

    a.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn restart_recovers_interrupted_jobs_and_fences_tampered_specs() {
    let tmp = tmp_dir("serve_recover");
    let serve_root = tmp.join("serve");
    let spec_toml = campaign_toml();
    let plan = plan_of(&spec_toml);
    let ticket = plan.campaign_hash.clone();

    // fabricate the debris of a daemon that died mid-job: a `running`
    // record whose spec is intact, and a sibling whose recorded ticket
    // does not match its spec bytes (tampered / half-written)
    jobs::init_serve_root(&serve_root).unwrap();
    cpt::util::write_atomic(
        jobs::job_dir(&serve_root, &ticket).join(jobs::JOB_SPEC_FILE),
        spec_toml.as_bytes(),
    )
    .unwrap();
    JobRecord {
        ticket: ticket.clone(),
        name: plan.name.clone(),
        state: JobState::Running,
        planned: plan.total_cells(),
        submitted: 1.0,
        finished: None,
        error: None,
        stats: None,
    }
    .store(&serve_root)
    .unwrap();
    let bad_ticket = "00000000deadbeef";
    cpt::util::write_atomic(
        jobs::job_dir(&serve_root, bad_ticket).join(jobs::JOB_SPEC_FILE),
        spec_toml.as_bytes(),
    )
    .unwrap();
    JobRecord {
        ticket: bad_ticket.to_string(),
        name: plan.name.clone(),
        state: JobState::Queued,
        planned: plan.total_cells(),
        submitted: 2.0,
        finished: None,
        error: None,
        stats: None,
    }
    .store(&serve_root)
    .unwrap();

    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let srv = Server::start(
        serve_opts(&serve_root),
        counting_exec(execs.clone(), cells.clone(), None),
        None,
        Arc::new(TestClock::new(50.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();

    // the interrupted job was requeued and runs to completion
    let v = client.wait_done(&ticket, 5).unwrap();
    assert_eq!(v.state, JobState::Done);
    assert_eq!(execs.load(Ordering::SeqCst), 1);
    client.result_files(&ticket).unwrap();

    // the tampered job was fenced to `failed` at recovery, not executed
    let bad = client.status(bad_ticket).unwrap();
    assert_eq!(bad.state, JobState::Failed);
    assert!(
        bad.error.as_deref().unwrap_or("").contains("recovery"),
        "{:?}",
        bad.error
    );

    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn a_failed_job_reports_its_error_and_leaves_the_daemon_healthy() {
    let tmp = tmp_dir("serve_fail");
    let exec: cpt::server::CampaignExec =
        Arc::new(|_, _| anyhow::bail!("injected executor failure"));
    let srv = Server::start(
        serve_opts(&tmp.join("serve")),
        exec,
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, _, _) = client.submit(&campaign_toml()).unwrap();

    let v = loop {
        let v = client.status(&ticket).unwrap();
        if v.state.is_terminal() {
            break v;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    };
    assert_eq!(v.state, JobState::Failed);
    assert!(
        v.error.as_deref().unwrap().contains("injected executor failure"),
        "{:?}",
        v.error
    );
    // `result` maps the failure to its typed code; `wait_done` to an Err
    let err = client.result_files(&ticket).unwrap_err().to_string();
    assert!(err.contains("job_failed"), "{err}");
    let err = client.wait_done(&ticket, 5).unwrap_err().to_string();
    assert!(err.contains("injected executor failure"), "{err}");
    // the executor survives a failed job
    client.ping().unwrap();
    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn a_second_job_sharing_the_model_compiles_nothing_new() {
    let tmp = tmp_dir("serve_warm");
    let compiles = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    // one worker, so exactly one compile can ever satisfy both jobs
    let pool = test_pool(1, &compiles, &cells, 0);
    let mut opts = serve_opts(&tmp.join("serve"));
    opts.jobs = 1;
    let srv = Server::start(
        opts,
        pooled_exec(&pool, None),
        None,
        Arc::new(TestClock::new(7.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();

    let plan1 = plan_of(&campaign_toml());
    let (t1, _, _) = client.submit(&campaign_toml()).unwrap();
    let v1 = client.wait_done(&t1, 5).unwrap();
    assert_eq!(compiles.load(Ordering::SeqCst), 1);
    let s1 = v1.stats.expect("done job records pool stats");
    assert_eq!(s1.compiles, 1);
    assert_eq!(s1.misses, 1);
    assert_eq!(s1.hits, plan1.total_cells() - 1);

    // a distinct spec (fresh ticket, fresh cells) sharing the model
    // fingerprint: the warm pool compiles nothing for it
    let plan2 = plan_of(&campaign_toml2());
    let (t2, _, attached) = client.submit(&campaign_toml2()).unwrap();
    assert_ne!(t2, t1, "distinct specs must get distinct tickets");
    assert!(!attached);
    let v2 = client.wait_done(&t2, 5).unwrap();
    assert_eq!(
        compiles.load(Ordering::SeqCst),
        1,
        "the second job recompiled a model the pool already holds"
    );
    let s2 = v2.stats.expect("done job records pool stats");
    assert_eq!(s2.compiles, 0, "cross-job warm start: {s2:?}");
    assert_eq!(s2.hits, plan2.total_cells());
    // `cpt jobs` surfaces both jobs' split accounting
    let listed = client.jobs().unwrap();
    assert_eq!(listed.len(), 2);
    for j in &listed {
        assert!(j.stats.is_some(), "done job {} lost its stats", j.ticket);
    }

    client.shutdown().unwrap();
    srv.wait().unwrap();
    pool.join();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn fair_share_lets_a_small_job_finish_while_a_big_one_runs() {
    let tmp = tmp_dir("serve_fair");
    let compiles = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let pool = test_pool(2, &compiles, &cells, 25);
    let order = Arc::new(Mutex::new(Vec::new()));
    let mut opts = serve_opts(&tmp.join("serve"));
    opts.concurrent = 2;
    let srv = Server::start(
        opts,
        pooled_exec(&pool, Some(order.clone())),
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();

    let big_plan = plan_of(&big_campaign_toml());
    let (big, _, _) = client.submit(&big_campaign_toml()).unwrap();
    // wait until the big job owns the pool (live done/planned counts
    // over the wire — the `cpt jobs --connect` progress surface)
    loop {
        let v = client.status(&big).unwrap();
        if v.state == JobState::Running && v.done.unwrap_or(0) >= 2 {
            assert_eq!(v.planned, big_plan.total_cells());
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(3));
    }

    let (small, _, _) = client.submit(&small_campaign_toml()).unwrap();
    let sv = client.wait_done(&small, 5).unwrap();
    assert_eq!(sv.state, JobState::Done);
    assert_ne!(
        client.status(&big).unwrap().state,
        JobState::Done,
        "fair-share: the 18-cell job beat the 2-cell job submitted \
         behind it"
    );
    client.wait_done(&big, 5).unwrap();
    assert_eq!(
        *order.lock().unwrap(),
        vec!["smallcamp".to_string(), "bigcamp".to_string()]
    );

    client.shutdown().unwrap();
    srv.wait().unwrap();
    pool.join();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn concurrent_jobs_fetch_byte_identical_csvs_to_direct_runs() {
    let tmp = tmp_dir("serve_pair");
    // ground truth: each spec through the direct campaign path
    let specs = [campaign_toml(), campaign_toml2()];
    let mut truths = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let plan = plan_of(spec);
        let direct = run_campaign_global(
            &plan,
            &CampaignRunOpts {
                root: tmp.join(format!("direct{i}")),
                shard: ShardId::single(),
                jobs: 2,
                resume: false,
                verbose: false,
                scheduler: SchedulerKind::Global,
            },
            &fingerprints(&plan),
            None,
            |_| Ok(CountingRunner { cells: Arc::new(AtomicUsize::new(0)) }),
        )
        .unwrap();
        let dir = tmp.join(format!("truth{i}"));
        report::write_campaign_csv_tree(
            &dir,
            direct
                .members
                .iter()
                .map(|m| (m.name.as_str(), m.outcomes.as_slice())),
        )
        .unwrap();
        truths.push(dir);
    }

    let compiles = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let pool = test_pool(2, &compiles, &cells, 2);
    let mut opts = serve_opts(&tmp.join("serve"));
    opts.concurrent = 2;
    let srv = Server::start(
        opts,
        pooled_exec(&pool, None),
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .unwrap();
    let addr = srv.addr().to_string();

    // both jobs in flight at once, cells interleaved on shared workers
    let handles: Vec<_> = specs
        .iter()
        .map(|spec| {
            let addr = addr.clone();
            let spec = spec.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let (t, _, _) = c.submit(&spec).unwrap();
                c.wait_done(&t, 5).unwrap();
                c.result_files(&t).unwrap()
            })
        })
        .collect();
    let results: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (files, dir) in results.iter().zip(&truths) {
        assert!(!files.is_empty());
        for (name, data) in files {
            let want = std::fs::read_to_string(dir.join(name)).unwrap();
            assert_eq!(
                data, &want,
                "{name} differs between the concurrent pool and the \
                 direct campaign"
            );
        }
    }

    Client::connect(&addr).unwrap().shutdown().unwrap();
    srv.wait().unwrap();
    pool.join();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn shutdown_drains_in_flight_jobs_and_a_restart_resumes_them() {
    let tmp = tmp_dir("serve_drain");
    let serve_root = tmp.join("serve");
    let plan = plan_of(&big_campaign_toml());
    let compiles = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let pool = test_pool(2, &compiles, &cells, 40);
    let drain: cpt::server::DrainHook = {
        let pool = pool.clone();
        Arc::new(move || pool.shutdown())
    };
    let srv = Server::start(
        serve_opts(&serve_root),
        pooled_exec(&pool, None),
        Some(drain),
        Arc::new(TestClock::new(10.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, _, _) = client.submit(&big_campaign_toml()).unwrap();
    loop {
        let v = client.status(&ticket).unwrap();
        if v.done.unwrap_or(0) >= 2 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(3));
    }
    client.shutdown().unwrap();
    srv.wait().unwrap();
    pool.join();

    let ran_first = cells.load(Ordering::SeqCst);
    assert!(ran_first >= 2, "drain fired before any cell ran");
    assert!(
        ran_first < plan.total_cells(),
        "job finished before the drain; nothing left to resume"
    );
    // the drained job is durably queued — not failed, not lost
    let views = jobs::serve_status(&serve_root).unwrap();
    assert_eq!(views.len(), 1);
    assert_eq!(
        views[0].state,
        JobState::Queued,
        "a drained job must requeue for the next daemon"
    );

    // a fresh daemon over the same root resumes it; recorded cells are
    // never re-executed
    let pool2 = test_pool(2, &compiles, &cells, 0);
    let srv2 = Server::start(
        serve_opts(&serve_root),
        pooled_exec(&pool2, None),
        None,
        Arc::new(TestClock::new(20.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv2.addr()).unwrap();
    let v = client.wait_done(&ticket, 5).unwrap();
    assert_eq!(v.state, JobState::Done);
    assert_eq!(v.done, Some(plan.total_cells()));
    assert_eq!(
        cells.load(Ordering::SeqCst),
        plan.total_cells(),
        "every cell must run exactly once across the drain/restart"
    );
    client.result_files(&ticket).unwrap();

    client.shutdown().unwrap();
    srv2.wait().unwrap();
    pool2.join();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn gc_over_the_wire_prunes_finished_jobs_only() {
    let tmp = tmp_dir("serve_gc_wire");
    let execs = Arc::new(AtomicUsize::new(0));
    let cells = Arc::new(AtomicUsize::new(0));
    let srv = Server::start(
        serve_opts(&tmp.join("serve")),
        counting_exec(execs.clone(), cells.clone(), None),
        None,
        Arc::new(TestClock::new(100.0)),
    )
    .unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();
    let (ticket, _, _) = client.submit(&campaign_toml()).unwrap();
    client.wait_done(&ticket, 5).unwrap();

    // no policy → nothing pruned
    assert_eq!(client.gc(None, None).unwrap(), (0, 0));
    // everything finished at t=100 is stale under max_age 0
    let (removed, freed) = client.gc(Some(0.0), None).unwrap();
    assert_eq!(removed, 1);
    assert!(freed > 0, "a pruned job dir must free bytes");
    let err = client.status(&ticket).unwrap_err().to_string();
    assert!(err.contains("unknown_ticket"), "{err}");
    assert!(client.jobs().unwrap().is_empty());

    // a pruned spec resubmits as a fresh job and runs again
    let (t2, s2, attached) = client.submit(&campaign_toml()).unwrap();
    assert_eq!(t2, ticket, "the ticket is still the spec hash");
    assert_eq!(s2, JobState::Queued);
    assert!(!attached);
    client.wait_done(&t2, 5).unwrap();
    assert_eq!(execs.load(Ordering::SeqCst), 2);

    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn non_loopback_listens_are_refused_without_allow_remote() {
    let tmp = tmp_dir("serve_bind");
    let exec: cpt::server::CampaignExec =
        Arc::new(|_, _| anyhow::bail!("no exec in bind tests"));
    let mut opts = serve_opts(&tmp.join("serve"));
    opts.listen = "0.0.0.0:0".to_string();
    let err = Server::start(
        opts,
        exec.clone(),
        None,
        Arc::new(TestClock::new(0.0)),
    )
    .map(|_| ())
    .unwrap_err()
    .to_string();
    assert!(err.contains("--allow-remote"), "{err}");
    assert!(err.contains("0.0.0.0:0"), "{err}");

    // the same bind is accepted once explicitly allowed
    let mut opts = serve_opts(&tmp.join("serve2"));
    opts.listen = "0.0.0.0:0".to_string();
    opts.allow_remote = true;
    let srv =
        Server::start(opts, exec, None, Arc::new(TestClock::new(0.0)))
            .unwrap();
    let port = srv.addr().rsplit(':').next().unwrap().to_string();
    let mut client = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    client.ping().unwrap();
    client.shutdown().unwrap();
    srv.wait().unwrap();
    std::fs::remove_dir_all(&tmp).ok();
}

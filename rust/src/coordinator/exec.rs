//! Shared work-queue executor: one worker pool over heterogeneous cells.
//!
//! The serial sweep path, the parallel sweep path, and the campaign
//! global scheduler all execute through [`run_items`]. Work is a flat
//! list of [`ExecItem`]s — `(member, cell)` pairs in canonical order —
//! and a pool of `jobs` workers claims items across member boundaries,
//! so a small member no longer leaves the pool idle while a large one
//! drains. A plain sweep is simply the single-member special case.
//!
//! Key properties (see rust/DESIGN-perf.md §6):
//!
//! * **Determinism** — every cell is an independently seeded run, and
//!   results land in position-addressed slots per member, so outcomes
//!   (and the CSVs aggregated from them) are byte-identical to
//!   sequential execution regardless of claim order, worker count, or
//!   cache state. Scheduling only moves wall clock.
//! * **Executable cache** — each worker owns one PJRT client plus a
//!   small LRU cache of compiled entry-point sets keyed by model
//!   fingerprint ([`PjrtCellRunner`]). Switching between members that
//!   share a model costs zero recompiles; per-worker compile counts and
//!   seconds are reported in [`ExecStats`] (and recorded into the
//!   campaign manifest). Claiming prefers items whose model the worker
//!   already holds compiled, so workers stay sticky to models when the
//!   queue allows it.
//! * **Per-member caps** — a member may bound its own in-flight cells
//!   ([`ExecMember::cap`], e.g. `jobs = 1` for memory reasons); the pool
//!   never runs more than `cap` of that member's cells concurrently.
//! * **Setup-failure semantics** — a worker that fails to compile one
//!   member's model stays alive for members it can compile: the claimed
//!   item is requeued for other workers and the model is skipped by this
//!   worker from then on. The run fails only if cells end up unclaimed
//!   (no surviving worker could compile their model), generalizing the
//!   per-sweep rule the old parallel executor applied.
//! * **Collector-per-store** — all `RunStore` writes happen on the one
//!   collector thread, routed by the item's member index, so artifact
//!   and manifest I/O stays serialized per store without locks and can
//!   never cross member boundaries.

use std::collections::{HashMap, HashSet};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::store::RunStore;
use super::{run_one_with_policy, RunOutcome, SweepCell};
use crate::policy::PolicySpec;
use crate::runtime::{LoadedModel, ModelSpec, Runtime};

/// Per-worker compiled-executable cache capacity (distinct model
/// fingerprints held at once), overridable via CPT_EXEC_CACHE. Campaigns
/// rarely mix more than a handful of models, so a small cache already
/// means zero recompiles when members share a model.
pub fn exec_cache_cap() -> usize {
    std::env::var("CPT_EXEC_CACHE")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(4)
}

/// One member of an execution request — a sweep (or the single member of
/// a plain sweep run) whose cells share a model and training shape.
#[derive(Clone, Debug)]
pub struct ExecMember {
    /// Display label ("" for a plain sweep).
    pub name: String,
    /// Model name (keys the recipe and the shared `ModelSpec` table).
    pub model: String,
    /// Compiled-model cache key. Members that share a model share a
    /// fingerprint, which is exactly when a worker's cached executables
    /// can be reused across them.
    pub fingerprint: String,
    /// Precision policy for every cell of this member (result-
    /// determining; carried here so workers can run adaptive cells —
    /// the compiled executable is policy-independent, q_t is a runtime
    /// input, so the cache key stays the model fingerprint alone).
    pub policy: PolicySpec,
    pub steps: usize,
    pub cycles: usize,
    pub eval_every: usize,
    /// Max cells of this member in flight at once (>= 1).
    pub cap: usize,
}

/// One unit of work: a cell of one member.
#[derive(Clone, Debug)]
pub struct ExecItem {
    /// Index into [`ExecRequest::members`] — also the store/slot route.
    pub member: usize,
    /// The cell's canonical index within its member's plan.
    pub cell_index: usize,
    /// Destination position in the member's slot vector.
    pub slot: usize,
    pub cell: SweepCell,
}

/// How a cell failed — the distinction drives pool survival.
pub enum CellError {
    /// The worker could not build what it needs to run cells of this
    /// model (client/compile failure). Non-fatal: the item is requeued
    /// for other workers and this worker skips the model from now on.
    Setup(anyhow::Error),
    /// The cell itself failed. Fatal for the whole run (all-or-nothing,
    /// like the serial path).
    Run(anyhow::Error),
}

/// One worker's execution backend. Implementations own whatever state a
/// worker needs (PJRT client, compiled models); a runner is created on
/// its worker thread and never crosses threads.
pub trait CellRunner {
    /// Run one cell. `cell_index` is the cell's canonical index within
    /// its member's plan (production ignores it; fabricated test runners
    /// use it to synthesize index-dependent outcomes).
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        per_step_logs: bool,
    ) -> std::result::Result<RunOutcome, CellError>;

    /// (compile count, compile seconds) accumulated so far.
    fn compile_stats(&self) -> (usize, f64);

    /// Does this worker currently hold a compiled model for this
    /// fingerprint? Used as a claim-order preference only — results
    /// never depend on it.
    fn has_cached(&self, _fingerprint: &str) -> bool {
        false
    }
}

/// Per-worker accounting, reported by [`run_items`] and recorded into
/// campaign manifests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerStats {
    pub worker: usize,
    /// Model compilations this worker performed (cache misses).
    pub compiles: usize,
    pub compile_seconds: f64,
    /// Cells this worker completed.
    pub cells: usize,
}

/// Pool-level accounting for one [`run_items`] call.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Workers actually spawned (jobs clamped to the item count).
    pub jobs: usize,
    pub workers: Vec<WorkerStats>,
}

impl ExecStats {
    pub fn total_compiles(&self) -> usize {
        self.workers.iter().map(|w| w.compiles).sum()
    }

    pub fn total_compile_seconds(&self) -> f64 {
        self.workers.iter().map(|w| w.compile_seconds).sum()
    }
}

/// One execution request: members, their flattened items, and knobs.
pub struct ExecRequest<'a> {
    /// Log prefix, e.g. `sweep mlp` or `campaign fig367`.
    pub label: String,
    pub members: &'a [ExecMember],
    pub items: &'a [ExecItem],
    pub jobs: usize,
    pub verbose: bool,
    /// Deterministic kill for tests: abort after this many freshly
    /// recorded cells, without touching process env. `None` defers to
    /// the process-wide CPT_HALT_AFTER_CELLS counter (the check.sh
    /// crash-injection knob).
    pub halt_after_cells: Option<usize>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ItemState {
    Pending,
    InFlight,
    Done,
}

struct QueueState {
    state: Vec<ItemState>,
    /// In-flight cells per member (bounded by the member's cap).
    inflight: Vec<usize>,
    stop: bool,
}

/// Unwinding guard for a claimed item: if a panic tears through
/// `run_cell`, the claim is released (marked Done), the pool is stopped,
/// and waiters are woken — otherwise the stuck `InFlight` item would
/// park the remaining workers forever and the run would hang instead of
/// propagating the panic through `thread::scope`.
struct ClaimGuard<'a> {
    queue: &'a Mutex<QueueState>,
    available: &'a Condvar,
    item: usize,
    member: usize,
    armed: bool,
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        if let Ok(mut q) = self.queue.lock() {
            q.state[self.item] = ItemState::Done;
            q.inflight[self.member] -= 1;
            q.stop = true;
        }
        self.available.notify_all();
    }
}

enum Msg {
    Done { item: usize, out: Box<RunOutcome> },
    RunErr { item: usize, err: anyhow::Error },
    SetupErr { model: String, err: anyhow::Error },
    WorkerExit { stats: WorkerStats },
}

/// Execute `req.items` over a pool of `req.jobs` workers, routing each
/// completed cell into `slots[member][slot]` and (when present) the
/// member's `RunStore` — all store writes happen on this thread, in
/// completion order, so persistence is serialized per store. Returns
/// per-worker compile/cell accounting.
///
/// Errors, in precedence order: a failed cell (lowest item index wins,
/// all-or-nothing), a store write failure, a crash-injection halt, and
/// finally unclaimed cells (every worker that tried their model failed
/// to compile it — reported with the first such compile error).
pub fn run_items<R, F>(
    req: &ExecRequest<'_>,
    stores: &mut [Option<&mut RunStore>],
    slots: &mut [Vec<Option<RunOutcome>>],
    make_worker: F,
) -> Result<ExecStats>
where
    R: CellRunner,
    F: Fn(usize) -> Result<R> + Sync,
{
    assert_eq!(req.members.len(), stores.len());
    assert_eq!(req.members.len(), slots.len());
    let jobs = req.jobs.max(1).min(req.items.len().max(1));
    if req.items.is_empty() {
        return Ok(ExecStats { jobs, workers: Vec::new() });
    }
    let per_step_logs = req.verbose && jobs == 1;
    if req.verbose && jobs > 1 {
        // workers run with per-step logging off (interleaved multi-cell
        // step logs would be unreadable); say so instead of silently
        // dropping the output the user asked for
        eprintln!(
            "[{} j{jobs}] note: per-step training logs are disabled when \
             more than one worker runs; per-cell summaries only",
            req.label
        );
    }

    let queue = Mutex::new(QueueState {
        state: vec![ItemState::Pending; req.items.len()],
        inflight: vec![0; req.members.len()],
        stop: false,
    });
    let available = Condvar::new();
    let (tx, rx) = mpsc::channel::<Msg>();

    let mut first_err: Option<(usize, anyhow::Error)> = None;
    let mut setup_errs: Vec<(String, anyhow::Error)> = Vec::new();
    let mut store_err: Option<anyhow::Error> = None;
    let mut halt_err: Option<anyhow::Error> = None;
    let mut worker_stats: Vec<WorkerStats> = Vec::new();
    let mut fresh = 0usize;

    std::thread::scope(|scope| {
        for w in 0..jobs {
            let tx = tx.clone();
            let queue = &queue;
            let available = &available;
            let make_worker = &make_worker;
            scope.spawn(move || {
                // Per-worker backend (PJRT client + executable cache in
                // production); built on this thread, never shared.
                let mut runner = match make_worker(w) {
                    Ok(r) => r,
                    Err(e) => {
                        // don't stop the pool: the queue drains on the
                        // workers that did initialize; the run only
                        // fails if cells end up unclaimed
                        let _ = tx.send(Msg::SetupErr {
                            model: String::new(),
                            err: e.context(format!("worker {w} setup")),
                        });
                        return;
                    }
                };
                let mut failed: HashSet<&str> = HashSet::new();
                let mut cells = 0usize;
                loop {
                    // Claim the next runnable item under the queue lock:
                    // first Pending item whose member has cap headroom
                    // and whose model this worker can compile —
                    // preferring one the worker already holds compiled
                    // (claim order never affects results, only compiles).
                    let claimed: Option<usize> = {
                        let mut q = queue.lock().unwrap();
                        loop {
                            if q.stop {
                                break None;
                            }
                            let mut cached: Option<usize> = None;
                            let mut cold: Option<usize> = None;
                            let mut maybe_later = false;
                            for (i, st) in q.state.iter().enumerate() {
                                if *st == ItemState::Done {
                                    continue;
                                }
                                let it = &req.items[i];
                                let m = &req.members[it.member];
                                if failed.contains(m.fingerprint.as_str()) {
                                    continue;
                                }
                                if *st == ItemState::InFlight {
                                    // another worker's setup failure may
                                    // hand this back — park, don't exit
                                    maybe_later = true;
                                    continue;
                                }
                                if q.inflight[it.member] >= m.cap.max(1) {
                                    maybe_later = true;
                                    continue;
                                }
                                if runner.has_cached(&m.fingerprint) {
                                    cached = Some(i);
                                    break;
                                }
                                if cold.is_none() {
                                    cold = Some(i);
                                }
                            }
                            match cached.or(cold) {
                                Some(i) => {
                                    q.state[i] = ItemState::InFlight;
                                    q.inflight[req.items[i].member] += 1;
                                    break Some(i);
                                }
                                // claimable-for-me items exist but are at
                                // cap or in flight: wait for a transition
                                None if maybe_later => {
                                    q = available.wait(q).unwrap();
                                }
                                // nothing left this worker could ever
                                // run (done, or its models failed here)
                                None => break None,
                            }
                        }
                    };
                    let Some(i) = claimed else { break };
                    let it = &req.items[i];
                    let m = &req.members[it.member];
                    let mut guard = ClaimGuard {
                        queue,
                        available,
                        item: i,
                        member: it.member,
                        armed: true,
                    };
                    let res = runner.run_cell(
                        m,
                        &it.cell,
                        it.cell_index,
                        per_step_logs,
                    );
                    guard.armed = false; // no panic: arms settle the claim
                    match res {
                        Ok(out) => {
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Done;
                                q.inflight[it.member] -= 1;
                            }
                            available.notify_all();
                            cells += 1;
                            if tx
                                .send(Msg::Done { item: i, out: Box::new(out) })
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(CellError::Setup(err)) => {
                            // this worker cannot run this member's model:
                            // hand the item back and skip the model
                            failed.insert(m.fingerprint.as_str());
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Pending;
                                q.inflight[it.member] -= 1;
                            }
                            available.notify_all();
                            let _ = tx.send(Msg::SetupErr {
                                model: m.model.clone(),
                                err,
                            });
                        }
                        Err(CellError::Run(err)) => {
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Done;
                                q.inflight[it.member] -= 1;
                                q.stop = true;
                            }
                            available.notify_all();
                            let _ = tx.send(Msg::RunErr { item: i, err });
                        }
                    }
                }
                let (compiles, compile_seconds) = runner.compile_stats();
                let _ = tx.send(Msg::WorkerExit {
                    stats: WorkerStats {
                        worker: w,
                        compiles,
                        compile_seconds,
                        cells,
                    },
                });
            });
        }
        drop(tx); // the collector exits once every worker hangs up

        // Collector: the only thread that touches slots and stores.
        for msg in rx {
            match msg {
                Msg::Done { item, out } => {
                    let it = &req.items[item];
                    let m = &req.members[it.member];
                    if req.verbose {
                        let who = if m.name.is_empty() {
                            m.model.clone()
                        } else {
                            format!("{}:{}", m.name, m.model)
                        };
                        eprintln!(
                            "[{} j{jobs}] {who} {} qmax={} trial={} -> metric={:.4} ({:.3} GBitOps)",
                            req.label,
                            out.schedule,
                            out.q_max,
                            out.trial,
                            out.metric,
                            out.gbitops
                        );
                    }
                    if store_err.is_none() && halt_err.is_none() {
                        if let Some(st) = stores[it.member].as_mut() {
                            if let Err(e) = st.record(it.cell_index, &out) {
                                // persistence failure is fatal: stop
                                // claiming new cells, drain, and report
                                queue.lock().unwrap().stop = true;
                                available.notify_all();
                                store_err = Some(e);
                            }
                        }
                        if store_err.is_none() {
                            fresh += 1;
                            let halted = match req.halt_after_cells {
                                Some(n) => {
                                    if n > 0 && fresh >= n {
                                        Some(anyhow!(
                                            "halted after {fresh} freshly \
                                             computed cell(s) \
                                             (halt_after_cells={n} crash \
                                             injection)"
                                        ))
                                    } else {
                                        None
                                    }
                                }
                                None => super::crash_injection_point().err(),
                            };
                            if let Some(e) = halted {
                                queue.lock().unwrap().stop = true;
                                available.notify_all();
                                halt_err = Some(e);
                            }
                        }
                    }
                    slots[it.member][it.slot] = Some(*out);
                }
                Msg::RunErr { item, err } => {
                    let is_first =
                        first_err.as_ref().map_or(true, |(i, _)| item < *i);
                    if is_first {
                        first_err = Some((item, err));
                    }
                }
                Msg::SetupErr { model, err } => {
                    setup_errs.push((model, err));
                }
                Msg::WorkerExit { stats } => worker_stats.push(stats),
            }
        }
    });

    worker_stats.sort_by_key(|s| s.worker);
    let done = req
        .items
        .iter()
        .filter(|it| slots[it.member][it.slot].is_some())
        .count();
    // a real cell failure always wins (reported at its true identity)
    if let Some((i, e)) = first_err {
        let it = &req.items[i];
        let m = &req.members[it.member];
        let who = if m.name.is_empty() {
            m.model.clone()
        } else {
            m.name.clone()
        };
        return Err(e.context(format!(
            "{}: cell {} of '{who}' failed ({done}/{} complete)",
            req.label,
            it.cell_index,
            req.items.len()
        )));
    }
    if let Some(e) = store_err {
        return Err(e.context("persisting cell artifact"));
    }
    if let Some(e) = halt_err {
        return Err(e);
    }
    if done != req.items.len() {
        // cells went unclaimed — every worker that tried their model
        // failed to compile it (or died on setup). Prefer a compile
        // error that names a model over a bare worker-init failure: the
        // init error may be an unrelated worker, while a named compile
        // failure is what actually left cells unclaimed.
        let e = match setup_errs.iter().position(|(m, _)| !m.is_empty()) {
            Some(i) => {
                let (model, e) = setup_errs.swap_remove(i);
                e.context(format!("compiling model '{model}'"))
            }
            None => setup_errs
                .into_iter()
                .next()
                .map(|(_, e)| e)
                .unwrap_or_else(|| anyhow!("worker(s) exited early")),
        };
        return Err(e.context(format!(
            "{}: {} of {} cells unclaimed (no worker could run them)",
            req.label,
            req.items.len() - done,
            req.items.len()
        )));
    }
    if !setup_errs.is_empty() {
        // all cells ran on the surviving workers — degraded but complete
        let (model, e) = &setup_errs[0];
        let what = if model.is_empty() {
            "a worker failed to initialize".to_string()
        } else {
            format!("a worker could not compile model '{model}'")
        };
        eprintln!(
            "[{}] note: {what} ({e:#}); all cells completed on the \
             remaining workers",
            req.label
        );
    }
    Ok(ExecStats { jobs, workers: worker_stats })
}

/// Production [`CellRunner`]: one PJRT client plus an LRU cache of
/// compiled entry-point sets keyed by model fingerprint. Compilation is
/// the dominant fixed cost per worker (DESIGN-perf §1), so the cache is
/// what makes cross-member scheduling cheap: claiming a cell of a member
/// whose model is already cached costs zero recompiles.
pub struct PjrtCellRunner<'a> {
    rt: Runtime,
    /// Pre-validated specs shared by every worker, keyed by model name.
    specs: &'a HashMap<String, ModelSpec>,
    /// LRU order: most recently used last.
    cache: Vec<(String, LoadedModel)>,
    cache_cap: usize,
    compiles: usize,
    compile_seconds: f64,
}

impl<'a> PjrtCellRunner<'a> {
    pub fn new(
        specs: &'a HashMap<String, ModelSpec>,
        cache_cap: usize,
    ) -> Result<Self> {
        Ok(PjrtCellRunner {
            rt: Runtime::cpu()?,
            specs,
            cache: Vec::new(),
            cache_cap: cache_cap.max(1),
            compiles: 0,
            compile_seconds: 0.0,
        })
    }

    /// Cache lookup, compiling (and evicting least-recently-used) on miss.
    fn model_for(&mut self, member: &ExecMember) -> Result<&LoadedModel> {
        if let Some(pos) = self
            .cache
            .iter()
            .position(|(fp, _)| fp == &member.fingerprint)
        {
            let entry = self.cache.remove(pos);
            self.cache.push(entry);
        } else {
            let spec = self.specs.get(&member.model).with_context(|| {
                format!("no shared spec for model '{}'", member.model)
            })?;
            let t0 = Instant::now();
            let model = self.rt.load_model(spec)?;
            self.compiles += 1;
            self.compile_seconds += t0.elapsed().as_secs_f64();
            if self.cache.len() >= self.cache_cap {
                self.cache.remove(0);
            }
            self.cache.push((member.fingerprint.clone(), model));
        }
        Ok(&self.cache.last().unwrap().1)
    }
}

impl CellRunner for PjrtCellRunner<'_> {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        _cell_index: usize,
        per_step_logs: bool,
    ) -> std::result::Result<RunOutcome, CellError> {
        let model = match self.model_for(member) {
            Ok(m) => m,
            Err(e) => return Err(CellError::Setup(e)),
        };
        run_one_with_policy(
            model,
            &member.model,
            &member.policy,
            &cell.schedule,
            cell.q_max,
            cell.trial,
            member.steps,
            member.cycles,
            member.eval_every,
            per_step_logs,
        )
        .map_err(CellError::Run)
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiles, self.compile_seconds)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.cache.iter().any(|(fp, _)| fp == fingerprint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::group_of;
    use std::sync::Arc;

    fn member(name: &str, fp: &str, cap: usize) -> ExecMember {
        ExecMember {
            name: name.into(),
            model: format!("model-{fp}"),
            fingerprint: fp.into(),
            policy: PolicySpec::StaticSuite,
            steps: 8,
            cycles: 8,
            eval_every: 0,
            cap,
        }
    }

    fn items_for(members: &[ExecMember], cells_each: usize) -> Vec<ExecItem> {
        let mut items = Vec::new();
        for (mi, _) in members.iter().enumerate() {
            for c in 0..cells_each {
                items.push(ExecItem {
                    member: mi,
                    cell_index: c,
                    slot: c,
                    cell: SweepCell {
                        schedule: "CR".into(),
                        q_max: 8.0,
                        trial: c,
                    },
                });
            }
        }
        items
    }

    fn fab(member: &ExecMember, cell: &SweepCell, index: usize) -> RunOutcome {
        RunOutcome {
            model: member.model.clone(),
            schedule: cell.schedule.clone(),
            group: group_of(&cell.schedule).label().into(),
            q_max: cell.q_max,
            trial: cell.trial,
            gbitops: 1.0 + index as f64,
            metric: 0.5 + index as f64 * 0.125,
            eval_loss: 0.25,
            steps: member.steps,
            mean_q: 0.75,
            realized_cost: 0.5,
            exec_seconds: 0.01,
            history: crate::metrics::History::default(),
        }
    }

    /// Fabricated runner: optional per-fingerprint compile failures,
    /// optional per-fingerprint concurrency gauge, simulated compile
    /// cache.
    struct FabRunner {
        fail: HashSet<String>,
        compiled: Vec<String>,
        compiles: usize,
        fail_cell: Option<(usize, usize)>, // (member, cell_index) to fail
        gauge: Option<Arc<Gauge>>,
        sleep_ms: u64,
    }

    /// Concurrency high-water mark per fingerprint (members and
    /// fingerprints are 1:1 in these tests).
    struct Gauge {
        inner: Mutex<std::collections::HashMap<String, (usize, usize)>>,
    }

    impl Gauge {
        fn new() -> Gauge {
            Gauge { inner: Mutex::new(std::collections::HashMap::new()) }
        }

        fn enter(&self, fp: &str) {
            let mut g = self.inner.lock().unwrap();
            let e = g.entry(fp.to_string()).or_insert((0, 0));
            e.0 += 1;
            e.1 = e.1.max(e.0);
        }

        fn exit(&self, fp: &str) {
            let mut g = self.inner.lock().unwrap();
            g.get_mut(fp).unwrap().0 -= 1;
        }

        fn high_water(&self, fp: &str) -> usize {
            self.inner.lock().unwrap().get(fp).map_or(0, |e| e.1)
        }
    }

    impl FabRunner {
        fn plain() -> FabRunner {
            FabRunner {
                fail: HashSet::new(),
                compiled: Vec::new(),
                compiles: 0,
                fail_cell: None,
                gauge: None,
                sleep_ms: 0,
            }
        }
    }

    impl CellRunner for FabRunner {
        fn run_cell(
            &mut self,
            member: &ExecMember,
            cell: &SweepCell,
            cell_index: usize,
            _per_step_logs: bool,
        ) -> std::result::Result<RunOutcome, CellError> {
            if self.fail.contains(&member.fingerprint) {
                return Err(CellError::Setup(anyhow!(
                    "injected compile failure for {}",
                    member.fingerprint
                )));
            }
            if !self.compiled.contains(&member.fingerprint) {
                self.compiled.push(member.fingerprint.clone());
                self.compiles += 1;
            }
            if let Some(g) = &self.gauge {
                g.enter(&member.fingerprint);
            }
            if self.sleep_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    self.sleep_ms,
                ));
            }
            if let Some(g) = &self.gauge {
                g.exit(&member.fingerprint);
            }
            if self.fail_cell == Some((0, cell_index)) {
                return Err(CellError::Run(anyhow!("injected cell failure")));
            }
            Ok(fab(member, cell, cell_index))
        }

        fn compile_stats(&self) -> (usize, f64) {
            (self.compiles, 0.0)
        }

        fn has_cached(&self, fingerprint: &str) -> bool {
            self.compiled.iter().any(|f| f == fingerprint)
        }
    }

    fn run(
        members: &[ExecMember],
        items: &[ExecItem],
        jobs: usize,
        halt: Option<usize>,
        make: impl Fn(usize) -> Result<FabRunner> + Sync,
    ) -> (Result<ExecStats>, Vec<Vec<Option<RunOutcome>>>) {
        let req = ExecRequest {
            label: "test".into(),
            members,
            items,
            jobs,
            verbose: false,
            halt_after_cells: halt,
        };
        let mut stores: Vec<Option<&mut RunStore>> =
            members.iter().map(|_| None).collect();
        let cells = items
            .iter()
            .fold(vec![0usize; members.len()], |mut acc, it| {
                acc[it.member] = acc[it.member].max(it.slot + 1);
                acc
            });
        let mut slots: Vec<Vec<Option<RunOutcome>>> =
            cells.into_iter().map(|n| vec![None; n]).collect();
        let res = run_items(&req, &mut stores, &mut slots, make);
        (res, slots)
    }

    #[test]
    fn pool_completes_all_items_across_members() {
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 3);
        let (res, slots) =
            run(&members, &items, 3, None, |_| Ok(FabRunner::plain()));
        let stats = res.unwrap();
        assert!(stats.jobs <= 3);
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        // every worker compiled each fingerprint it touched at most once
        for w in &stats.workers {
            assert!(w.compiles <= 2, "{w:?}");
        }
        assert_eq!(
            stats.workers.iter().map(|w| w.cells).sum::<usize>(),
            items.len()
        );
        // outcomes landed in the right member/slot (index-dependent fab)
        for (mi, m) in members.iter().enumerate() {
            for (ci, out) in slots[mi].iter().enumerate() {
                let out = out.as_ref().unwrap();
                assert_eq!(out.model, m.model);
                assert_eq!(out.metric, 0.5 + ci as f64 * 0.125);
            }
        }
    }

    #[test]
    fn compile_failure_keeps_worker_alive_for_other_members() {
        // worker 0 cannot compile fpA; worker 1 can compile everything —
        // the pool still completes, and worker 0 contributed fpB cells
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 4);
        let (res, slots) = run(&members, &items, 2, None, |w| {
            let mut r = FabRunner::plain();
            if w == 0 {
                r.fail.insert("fpA".into());
            }
            r.sleep_ms = 1; // overlap so worker 0 gets claims
            Ok(r)
        });
        let stats = res.unwrap();
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        let w0 = stats.workers.iter().find(|w| w.worker == 0).unwrap();
        // worker 0 never compiled fpA (its one attempt failed, uncounted)
        assert!(w0.compiles <= 1, "{w0:?}");
    }

    #[test]
    fn unclaimed_cells_fail_with_the_compile_error() {
        // no worker can compile fpA: member a's cells are unclaimed and
        // the run fails with the compile error; member b still completed
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 2);
        let (res, slots) = run(&members, &items, 2, None, |_| {
            let mut r = FabRunner::plain();
            r.fail.insert("fpA".into());
            Ok(r)
        });
        let err = res.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unclaimed"), "{msg}");
        assert!(msg.contains("injected compile failure"), "{msg}");
        assert!(slots[1].iter().all(|o| o.is_some()), "member b must run");
        assert!(slots[0].iter().all(|o| o.is_none()));
    }

    #[test]
    fn worker_setup_failure_is_nonfatal_when_pool_survives() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 3);
        let (res, slots) = run(&members, &items, 2, None, |w| {
            if w == 0 {
                anyhow::bail!("injected worker init failure");
            }
            Ok(FabRunner::plain())
        });
        res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
    }

    #[test]
    fn cell_failure_aborts_the_whole_run() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 4);
        let (res, _) = run(&members, &items, 2, None, |_| {
            let mut r = FabRunner::plain();
            r.fail_cell = Some((0, 1));
            Ok(r)
        });
        let err = res.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected cell failure"), "{msg}");
        assert!(msg.contains("cell 1"), "{msg}");
    }

    #[test]
    fn injected_halt_stops_after_n_fresh_cells() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 5);
        let (res, slots) =
            run(&members, &items, 1, Some(2), |_| Ok(FabRunner::plain()));
        let err = res.unwrap_err();
        assert!(format!("{err:#}").contains("halted after 2"), "{err:#}");
        // at least the halted-on cells completed (the worker may have
        // computed more before observing the stop flag — the *recorded*
        // count is what the halt bounds exactly, asserted in
        // tests/global_sched.rs against a real store)
        let done = slots[0].iter().filter(|o| o.is_some()).count();
        assert!((2..=5).contains(&done), "{done}");
    }

    #[test]
    fn per_member_cap_bounds_inflight_cells() {
        // member a has cap 1: even with 4 workers, its cells never
        // overlap; member b (cap 4) soaks up the rest of the pool
        let members = [member("a", "fpA", 1), member("b", "fpB", 4)];
        let items = items_for(&members, 6);
        let gauge = Arc::new(Gauge::new());
        let (res, slots) = run(&members, &items, 4, None, |_| {
            let mut r = FabRunner::plain();
            r.gauge = Some(gauge.clone());
            r.sleep_ms = 2;
            Ok(r)
        });
        res.unwrap();
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        assert!(
            gauge.high_water("fpA") <= 1,
            "cap-1 member overlapped: {}",
            gauge.high_water("fpA")
        );
        assert!(gauge.high_water("fpB") <= 4);
    }
}

//! Shared work-queue executor: one worker pool over heterogeneous cells.
//!
//! The serial sweep path, the parallel sweep path, and the campaign
//! global scheduler all execute through [`run_items`]. Work is a flat
//! list of [`ExecItem`]s — `(member, cell)` pairs in canonical order —
//! and a pool of `jobs` workers claims items across member boundaries,
//! so a small member no longer leaves the pool idle while a large one
//! drains. A plain sweep is simply the single-member special case.
//!
//! Key properties (see rust/DESIGN-perf.md §6):
//!
//! * **Determinism** — every cell is an independently seeded run, and
//!   results land in position-addressed slots per member, so outcomes
//!   (and the CSVs aggregated from them) are byte-identical to
//!   sequential execution regardless of claim order, worker count, or
//!   cache state. Scheduling only moves wall clock.
//! * **Executable cache** — each worker owns one PJRT client plus a
//!   small LRU cache of compiled entry-point sets keyed by model
//!   fingerprint ([`PjrtCellRunner`]). Switching between members that
//!   share a model costs zero recompiles; per-worker compile counts and
//!   seconds are reported in [`ExecStats`] (and recorded into the
//!   campaign manifest). Claiming prefers items whose model the worker
//!   already holds compiled, so workers stay sticky to models when the
//!   queue allows it. With `CPT_AOT_CACHE` set (and a backend that can
//!   serialize executables), the LRU is backed by the persistent AOT
//!   store (`coordinator::aot`), so new processes warm-start from
//!   compiles published by earlier ones.
//! * **Per-member caps** — a member may bound its own in-flight cells
//!   ([`ExecMember::cap`], e.g. `jobs = 1` for memory reasons); the pool
//!   never runs more than `cap` of that member's cells concurrently.
//! * **Setup-failure semantics** — a worker that fails to compile one
//!   member's model stays alive for members it can compile: the claimed
//!   item is requeued for other workers and the model is skipped by this
//!   worker from then on. The run fails only if cells end up unclaimed
//!   (no surviving worker could compile their model), generalizing the
//!   per-sweep rule the old parallel executor applied.
//! * **Collector-per-store** — all `RunStore` writes happen on the one
//!   collector thread, routed by the item's member index, so artifact
//!   and manifest I/O stays serialized per store without locks and can
//!   never cross member boundaries.

use std::collections::{HashMap, HashSet};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::aot::{self, AotStore};
use super::store::RunStore;
use super::{run_one_with_policy, RunOutcome, SweepCell};
use crate::obs::trace::{self, Event};
use crate::policy::PolicySpec;
use crate::runtime::{LoadedModel, ModelSpec, Runtime};

/// Per-worker compiled-executable cache capacity (distinct model
/// fingerprints held at once), overridable via CPT_EXEC_CACHE. Campaigns
/// rarely mix more than a handful of models, so a small cache already
/// means zero recompiles when members share a model. An unparsable or
/// zero CPT_EXEC_CACHE fails loudly rather than silently falling back.
pub fn exec_cache_cap() -> Result<usize> {
    match super::env_parse::<usize>("CPT_EXEC_CACHE")? {
        Some(0) => bail!("CPT_EXEC_CACHE must be >= 1"),
        Some(n) => Ok(n),
        None => Ok(4),
    }
}

/// Transient setup failures (PJRT client init, model compile) are
/// retried this many times per (worker, model) with exponential backoff
/// before the worker permanently skips the model and the item is handed
/// back to the pool.
pub(crate) const SETUP_ATTEMPTS: usize = 3;
const SETUP_BACKOFF_MS: u64 = 50;

/// Backoff before retry `attempt` (1-based): 50ms, 200ms, ...
pub(crate) fn setup_backoff(attempt: usize) -> Duration {
    Duration::from_millis(SETUP_BACKOFF_MS * 4u64.pow(attempt.min(4) as u32 - 1))
}

/// One member of an execution request — a sweep (or the single member of
/// a plain sweep run) whose cells share a model and training shape.
#[derive(Clone, Debug)]
pub struct ExecMember {
    /// Display label ("" for a plain sweep).
    pub name: String,
    /// Model name (keys the recipe and the shared `ModelSpec` table).
    pub model: String,
    /// Compiled-model cache key. Members that share a model share a
    /// fingerprint, which is exactly when a worker's cached executables
    /// can be reused across them.
    pub fingerprint: String,
    /// Precision policy for every cell of this member (result-
    /// determining; carried here so workers can run adaptive cells —
    /// the compiled executable is policy-independent, q_t is a runtime
    /// input, so the cache key stays the model fingerprint alone).
    pub policy: PolicySpec,
    pub steps: usize,
    pub cycles: usize,
    pub eval_every: usize,
    /// Max cells of this member in flight at once (>= 1).
    pub cap: usize,
}

/// One unit of work: a cell of one member.
#[derive(Clone, Debug)]
pub struct ExecItem {
    /// Index into [`ExecRequest::members`] — also the store/slot route.
    pub member: usize,
    /// The cell's canonical index within its member's plan.
    pub cell_index: usize,
    /// Destination position in the member's slot vector.
    pub slot: usize,
    pub cell: SweepCell,
}

/// How a cell failed — the distinction drives pool survival.
pub enum CellError {
    /// The worker could not build what it needs to run cells of this
    /// model (client/compile failure). Non-fatal: the item is requeued
    /// for other workers and this worker skips the model from now on.
    Setup(anyhow::Error),
    /// The cell itself failed. Fatal for the whole run (all-or-nothing,
    /// like the serial path).
    Run(anyhow::Error),
}

/// One worker's execution backend. Implementations own whatever state a
/// worker needs (PJRT client, compiled models); a runner is created on
/// its worker thread and never crosses threads.
pub trait CellRunner {
    /// Run one cell. `cell_index` is the cell's canonical index within
    /// its member's plan (production ignores it; fabricated test runners
    /// use it to synthesize index-dependent outcomes).
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        cell_index: usize,
        per_step_logs: bool,
    ) -> std::result::Result<RunOutcome, CellError>;

    /// (compile count, compile seconds) accumulated so far.
    fn compile_stats(&self) -> (usize, f64);

    /// Does this worker currently hold a compiled model for this
    /// fingerprint? Used as a claim-order preference only — results
    /// never depend on it.
    fn has_cached(&self, _fingerprint: &str) -> bool {
        false
    }

    /// Model-lookup cache accounting so far (in-memory hits, AOT disk
    /// hits, misses). Purely observational — results never depend on it.
    fn cache_stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

/// Where a worker's model lookups were served from. `misses` counts
/// lookups not answered by the in-memory LRU; each miss is then either
/// an AOT `disk_hits` or a compile, so `misses == disk_hits + compiles`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Lookups served by the worker's in-memory LRU.
    pub hits: usize,
    /// LRU misses served by deserializing an AOT cache entry.
    pub disk_hits: usize,
    /// Lookups the in-memory LRU could not serve.
    pub misses: usize,
}

/// Per-worker accounting, reported by [`run_items`] and recorded into
/// campaign manifests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerStats {
    pub worker: usize,
    /// Model compilations this worker performed (cache misses).
    pub compiles: usize,
    pub compile_seconds: f64,
    /// Cells this worker completed.
    pub cells: usize,
    /// Setup attempts this worker retried after a transient failure
    /// (each is one backoff-and-try-again beyond a first attempt).
    pub retries: usize,
    /// Model lookups served by this worker's in-memory LRU.
    pub hits: usize,
    /// LRU misses served by the AOT disk cache instead of a compile.
    pub disk_hits: usize,
    /// Model lookups the in-memory LRU could not serve
    /// (`disk_hits + compiles`).
    pub misses: usize,
}

/// Pool-level accounting for one [`run_items`] call.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Workers actually spawned (jobs clamped to the item count).
    pub jobs: usize,
    pub workers: Vec<WorkerStats>,
    /// Completed cells whose sink declined to persist them (claim mode:
    /// the cell was committed by another claimer first / the lease was
    /// lost). Always 0 outside claim mode.
    pub refused: usize,
}

impl ExecStats {
    pub fn total_compiles(&self) -> usize {
        self.workers.iter().map(|w| w.compiles).sum()
    }

    pub fn total_compile_seconds(&self) -> f64 {
        self.workers.iter().map(|w| w.compile_seconds).sum()
    }

    pub fn total_retries(&self) -> usize {
        self.workers.iter().map(|w| w.retries).sum()
    }
}

/// Where a completed cell lands. `RunStore` is the plain implementation
/// (always persists); the claim-mode recorder (`coordinator::lease`) may
/// *refuse* a cell — commit it nowhere — when its lease was lost and the
/// cell already belongs to another claimer. Refusal is not an error: the
/// outcome still fills its slot, it just isn't persisted here.
pub trait CellSink {
    fn record_cell(&mut self, index: usize, out: &RunOutcome) -> Result<Recorded>;
}

/// Outcome of a [`CellSink::record_cell`] call.
pub enum Recorded {
    /// Persisted by this sink.
    Stored,
    /// Declined, with the reason (already committed elsewhere / lease
    /// lost). The run continues; the cell is complete globally.
    Refused(String),
}

impl CellSink for RunStore {
    fn record_cell(&mut self, index: usize, out: &RunOutcome) -> Result<Recorded> {
        self.record(index, out)?;
        Ok(Recorded::Stored)
    }
}

/// A dynamic work feed for [`run_items`]: when the queue has nothing a
/// worker can claim, one worker at a time asks the source for more. This
/// is how claim mode keeps one long-lived pool (compiled executables and
/// all) while leases are acquired incrementally — instead of tearing the
/// pool down between claim rounds.
pub trait ItemSource: Sync {
    /// Produce more items, ask the pool to wait (work exists but is
    /// currently owned elsewhere), or declare the feed exhausted
    /// (nothing will ever be produced again). An error is fatal to the
    /// run. `Refill::Items` slots/members must stay within the bounds
    /// the request was built with.
    fn refill(&self) -> Result<Refill>;

    /// A worker permanently gave up compiling `fingerprint` (after
    /// bounded retries). Sources can stop feeding cells that need it —
    /// and, in claim mode, release their leases so other claimers take
    /// over.
    fn model_failed(&self, _fingerprint: &str) {}
}

/// One answer from [`ItemSource::refill`].
pub enum Refill {
    Items(Vec<ExecItem>),
    Wait(Duration),
    Exhausted,
}

/// One execution request: members, their flattened items, and knobs.
pub struct ExecRequest<'a> {
    /// Log prefix, e.g. `sweep mlp` or `campaign fig367`.
    pub label: String,
    pub members: &'a [ExecMember],
    /// Items enqueued up-front. With a `source`, this is just the seed —
    /// the queue grows as the source produces more.
    pub items: &'a [ExecItem],
    pub jobs: usize,
    pub verbose: bool,
    /// Deterministic kill for tests: abort after this many freshly
    /// recorded cells, without touching process env. `None` defers to
    /// the process-wide CPT_HALT_AFTER_CELLS counter (the check.sh
    /// crash-injection knob).
    pub halt_after_cells: Option<usize>,
    /// Dynamic work feed (claim mode); `None` for the static paths.
    pub source: Option<&'a dyn ItemSource>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ItemState {
    Pending,
    InFlight,
    Done,
}

struct QueueState {
    /// The work list. Static runs fix it up-front; with an
    /// [`ItemSource`] it grows as the source produces items.
    items: Vec<ExecItem>,
    state: Vec<ItemState>,
    /// In-flight cells per member (bounded by the member's cap).
    inflight: Vec<usize>,
    stop: bool,
    /// One worker at a time consults the source; the rest park.
    refilling: bool,
    /// The source declared itself exhausted — no more items, ever.
    source_done: bool,
}

/// Unwinding guard for a claimed item: if a panic tears through
/// `run_cell`, the claim is released (marked Done), the pool is stopped,
/// and waiters are woken — otherwise the stuck `InFlight` item would
/// park the remaining workers forever and the run would hang instead of
/// propagating the panic through `thread::scope`.
struct ClaimGuard<'a> {
    queue: &'a Mutex<QueueState>,
    available: &'a Condvar,
    item: usize,
    member: usize,
    armed: bool,
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        if let Ok(mut q) = self.queue.lock() {
            q.state[self.item] = ItemState::Done;
            q.inflight[self.member] -= 1;
            q.stop = true;
        }
        self.available.notify_all();
    }
}

enum Msg {
    Done { item: usize, worker: usize, out: Box<RunOutcome> },
    RunErr { item: usize, err: anyhow::Error },
    SetupErr { model: String, err: anyhow::Error },
    SourceErr { err: anyhow::Error },
    WorkerExit { stats: WorkerStats },
}

/// Execute `req.items` (plus whatever `req.source` feeds in) over a pool
/// of `req.jobs` workers, routing each completed cell into
/// `slots[member][slot]` and (when present) the member's [`CellSink`] —
/// all sink writes happen on this thread, in completion order, so
/// persistence is serialized per sink. Returns per-worker compile/cell
/// accounting.
///
/// Errors, in precedence order: a failed cell (lowest item index wins,
/// all-or-nothing), a sink write failure, a source failure, a
/// crash-injection halt, and finally unclaimed cells (every worker that
/// tried their model failed to compile it — reported with the first such
/// compile error; sourced runs skip this check because their source
/// decides completion).
pub fn run_items<R, F>(
    req: &ExecRequest<'_>,
    sinks: &mut [Option<&mut dyn CellSink>],
    slots: &mut [Vec<Option<RunOutcome>>],
    make_worker: F,
) -> Result<ExecStats>
where
    R: CellRunner,
    F: Fn(usize) -> Result<R> + Sync,
{
    assert_eq!(req.members.len(), sinks.len());
    assert_eq!(req.members.len(), slots.len());
    let jobs = if req.source.is_some() {
        // the queue can outgrow the seed, so don't clamp to it
        req.jobs.max(1)
    } else {
        req.jobs.max(1).min(req.items.len().max(1))
    };
    if req.items.is_empty() && req.source.is_none() {
        return Ok(ExecStats { jobs, workers: Vec::new(), refused: 0 });
    }
    let per_step_logs = req.verbose && jobs == 1;
    if req.verbose && jobs > 1 {
        // workers run with per-step logging off (interleaved multi-cell
        // step logs would be unreadable); say so instead of silently
        // dropping the output the user asked for
        crate::log_info!(
            "[{} j{jobs}] note: per-step training logs are disabled when \
             more than one worker runs; per-cell summaries only",
            req.label
        );
    }

    let queue = Mutex::new(QueueState {
        items: req.items.to_vec(),
        state: vec![ItemState::Pending; req.items.len()],
        inflight: vec![0; req.members.len()],
        stop: false,
        refilling: false,
        source_done: req.source.is_none(),
    });
    let available = Condvar::new();
    let (tx, rx) = mpsc::channel::<Msg>();

    let mut first_err: Option<(usize, anyhow::Error)> = None;
    let mut setup_errs: Vec<(String, anyhow::Error)> = Vec::new();
    let mut store_err: Option<anyhow::Error> = None;
    let mut source_err: Option<anyhow::Error> = None;
    let mut halt_err: Option<anyhow::Error> = None;
    let mut worker_stats: Vec<WorkerStats> = Vec::new();
    let mut fresh = 0usize;
    let mut refused = 0usize;

    std::thread::scope(|scope| {
        for w in 0..jobs {
            let tx = tx.clone();
            let queue = &queue;
            let available = &available;
            let make_worker = &make_worker;
            let label = &req.label;
            scope.spawn(move || {
                let mut retries = 0usize;
                // Per-worker backend (PJRT client + executable cache in
                // production); built on this thread, never shared.
                // Transient init failures get bounded retries with
                // backoff before the worker gives up.
                let mut init_attempt = 1usize;
                let mut runner = loop {
                    match make_worker(w) {
                        Ok(r) => break r,
                        Err(e) if init_attempt < SETUP_ATTEMPTS => {
                            crate::log_warn!(
                                "[{label}] note: worker {w} setup failed \
                                 (attempt {init_attempt}/{SETUP_ATTEMPTS}): \
                                 {e:#}; retrying",
                            );
                            std::thread::sleep(setup_backoff(init_attempt));
                            init_attempt += 1;
                            retries += 1;
                        }
                        Err(e) => {
                            // don't stop the pool: the queue drains on
                            // the workers that did initialize; the run
                            // only fails if cells end up unclaimed
                            let _ = tx.send(Msg::SetupErr {
                                model: String::new(),
                                err: e.context(format!("worker {w} setup")),
                            });
                            return;
                        }
                    }
                };
                let mut failed: HashSet<&str> = HashSet::new();
                let mut attempts: HashMap<&str, usize> = HashMap::new();
                let mut cells = 0usize;
                loop {
                    // Claim the next runnable item under the queue lock:
                    // first Pending item whose member has cap headroom
                    // and whose model this worker can compile —
                    // preferring one the worker already holds compiled
                    // (claim order never affects results, only compiles).
                    // When nothing is claimable and a source exists, one
                    // worker at a time consults it for more items.
                    let claim_t0 = Instant::now();
                    let claimed: Option<(usize, ExecItem)> = {
                        let mut q = queue.lock().unwrap();
                        loop {
                            if q.stop {
                                break None;
                            }
                            let mut cached: Option<usize> = None;
                            let mut cold: Option<usize> = None;
                            let mut maybe_later = false;
                            for (i, st) in q.state.iter().enumerate() {
                                if *st == ItemState::Done {
                                    continue;
                                }
                                let it = &q.items[i];
                                let m = &req.members[it.member];
                                if failed.contains(m.fingerprint.as_str()) {
                                    continue;
                                }
                                if *st == ItemState::InFlight {
                                    // another worker's setup failure may
                                    // hand this back — park, don't exit
                                    maybe_later = true;
                                    continue;
                                }
                                if q.inflight[it.member] >= m.cap.max(1) {
                                    maybe_later = true;
                                    continue;
                                }
                                if runner.has_cached(&m.fingerprint) {
                                    cached = Some(i);
                                    break;
                                }
                                if cold.is_none() {
                                    cold = Some(i);
                                }
                            }
                            match cached.or(cold) {
                                Some(i) => {
                                    q.state[i] = ItemState::InFlight;
                                    let it = q.items[i].clone();
                                    q.inflight[it.member] += 1;
                                    break Some((i, it));
                                }
                                // claimable-for-me items exist but are at
                                // cap or in flight: wait for a transition
                                None if maybe_later => {
                                    q = available.wait(q).unwrap();
                                }
                                None if !q.source_done => {
                                    if q.refilling {
                                        // someone else is asking; park
                                        // until they publish the answer
                                        q = available.wait(q).unwrap();
                                        continue;
                                    }
                                    q.refilling = true;
                                    drop(q);
                                    let r = req.source.unwrap().refill();
                                    q = queue.lock().unwrap();
                                    match r {
                                        Ok(Refill::Items(new)) => {
                                            q.refilling = false;
                                            for it in new {
                                                q.items.push(it);
                                                q.state
                                                    .push(ItemState::Pending);
                                            }
                                            available.notify_all();
                                        }
                                        Ok(Refill::Wait(d)) => {
                                            // sleep off-lock in slices so
                                            // a stop can cut the wait
                                            // short; `refilling` stays set
                                            // to keep the poll single-file
                                            drop(q);
                                            let deadline = Instant::now() + d;
                                            loop {
                                                let left = deadline
                                                    .saturating_duration_since(
                                                        Instant::now(),
                                                    );
                                                if left.is_zero() {
                                                    break;
                                                }
                                                std::thread::sleep(left.min(
                                                    Duration::from_millis(100),
                                                ));
                                                if queue
                                                    .lock()
                                                    .unwrap()
                                                    .stop
                                                {
                                                    break;
                                                }
                                            }
                                            q = queue.lock().unwrap();
                                            q.refilling = false;
                                            available.notify_all();
                                        }
                                        Ok(Refill::Exhausted) => {
                                            q.refilling = false;
                                            q.source_done = true;
                                            available.notify_all();
                                        }
                                        Err(err) => {
                                            q.refilling = false;
                                            q.stop = true;
                                            available.notify_all();
                                            let _ = tx
                                                .send(Msg::SourceErr { err });
                                        }
                                    }
                                }
                                // nothing left this worker could ever
                                // run (done, or its models failed here)
                                None => break None,
                            }
                        }
                    };
                    let Some((i, it)) = claimed else { break };
                    let m = &req.members[it.member];
                    // Span accounting (no-ops unless --trace installed a
                    // tracer): queue-wait is the time blocked claiming;
                    // compile vs exec is split by the runner's own
                    // compile-seconds delta across this one cell.
                    if trace::enabled() {
                        trace::set_cell_ctx(w, it.member, it.cell_index);
                        let wait = claim_t0.elapsed().as_secs_f64();
                        trace::emit(
                            Event::new(trace::now() - wait, "claim")
                                .dur(wait),
                        );
                    }
                    let (bc, bsec) = runner.compile_stats();
                    let bcache = runner.cache_stats();
                    let cell_t0 = Instant::now();
                    let mut guard = ClaimGuard {
                        queue,
                        available,
                        item: i,
                        member: it.member,
                        armed: true,
                    };
                    let res = runner.run_cell(
                        m,
                        &it.cell,
                        it.cell_index,
                        per_step_logs,
                    );
                    guard.armed = false; // no panic: arms settle the claim
                    if trace::enabled() {
                        if res.is_ok() {
                            let wall = cell_t0.elapsed().as_secs_f64();
                            let (ac, asec) = runner.compile_stats();
                            let acache = runner.cache_stats();
                            let dsec = (asec - bsec).max(0.0).min(wall);
                            let now = trace::now();
                            let outcome = if acache.hits > bcache.hits {
                                "hit"
                            } else if acache.disk_hits > bcache.disk_hits {
                                "disk_hit"
                            } else if acache.misses > bcache.misses {
                                "miss"
                            } else {
                                ""
                            };
                            if ac > bc {
                                trace::emit(
                                    Event::new(now - wall, "compile")
                                        .dur(dsec)
                                        .tag_str("fp", &m.fingerprint)
                                        .tag_str("outcome", outcome),
                                );
                            }
                            trace::emit(
                                Event::new(now - wall + dsec, "exec")
                                    .dur(wall - dsec)
                                    .tag_str("name", &m.name)
                                    .tag_str("model", &m.model)
                                    .tag_str("fp", &m.fingerprint)
                                    .tag_str("outcome", outcome),
                            );
                        }
                        // sink writes happen here, at the cell boundary —
                        // never inside the train loop
                        trace::flush();
                        trace::clear_cell_ctx();
                    }
                    match res {
                        Ok(out) => {
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Done;
                                q.inflight[it.member] -= 1;
                            }
                            available.notify_all();
                            cells += 1;
                            if tx
                                .send(Msg::Done {
                                    item: i,
                                    worker: w,
                                    out: Box::new(out),
                                })
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(CellError::Setup(err)) => {
                            // hand the item back first so another worker
                            // can take it while this one backs off
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Pending;
                                q.inflight[it.member] -= 1;
                            }
                            available.notify_all();
                            let n = attempts
                                .entry(m.fingerprint.as_str())
                                .or_insert(0);
                            *n += 1;
                            if *n < SETUP_ATTEMPTS {
                                // transient? back off and try again
                                retries += 1;
                                crate::log_warn!(
                                    "[{label}] note: worker {w} setup for \
                                     model '{}' failed (attempt \
                                     {n}/{SETUP_ATTEMPTS}): {err:#}; \
                                     retrying",
                                    m.model
                                );
                                std::thread::sleep(setup_backoff(*n));
                            } else {
                                // out of attempts: this worker skips the
                                // model from now on
                                failed.insert(m.fingerprint.as_str());
                                if let Some(src) = req.source {
                                    src.model_failed(&m.fingerprint);
                                }
                                let _ = tx.send(Msg::SetupErr {
                                    model: m.model.clone(),
                                    err,
                                });
                            }
                        }
                        Err(CellError::Run(err)) => {
                            {
                                let mut q = queue.lock().unwrap();
                                q.state[i] = ItemState::Done;
                                q.inflight[it.member] -= 1;
                                q.stop = true;
                            }
                            available.notify_all();
                            let _ = tx.send(Msg::RunErr { item: i, err });
                        }
                    }
                }
                let (compiles, compile_seconds) = runner.compile_stats();
                let cache = runner.cache_stats();
                let _ = tx.send(Msg::WorkerExit {
                    stats: WorkerStats {
                        worker: w,
                        compiles,
                        compile_seconds,
                        cells,
                        retries,
                        hits: cache.hits,
                        disk_hits: cache.disk_hits,
                        misses: cache.misses,
                    },
                });
            });
        }
        drop(tx); // the collector exits once every worker hangs up

        // Collector: the only thread that touches slots and sinks.
        for msg in rx {
            match msg {
                Msg::Done { item, worker, out } => {
                    let it = queue.lock().unwrap().items[item].clone();
                    let m = &req.members[it.member];
                    if req.verbose {
                        let who = if m.name.is_empty() {
                            m.model.clone()
                        } else {
                            format!("{}:{}", m.name, m.model)
                        };
                        crate::log_info!(
                            "[{} j{jobs}] {who} {} qmax={} trial={} -> metric={:.4} ({:.3} GBitOps)",
                            req.label,
                            out.schedule,
                            out.q_max,
                            out.trial,
                            out.metric,
                            out.gbitops
                        );
                    }
                    if store_err.is_none() && halt_err.is_none() {
                        let mut stored = true;
                        if let Some(st) = sinks[it.member].as_mut() {
                            let rec_t0 = Instant::now();
                            let rec = st.record_cell(it.cell_index, &out);
                            if trace::enabled() {
                                let d = rec_t0.elapsed().as_secs_f64();
                                trace::emit(
                                    Event::new(trace::now() - d, "record")
                                        .dur(d)
                                        .worker(worker)
                                        .member(it.member)
                                        .cell(it.cell_index),
                                );
                                trace::flush();
                            }
                            match rec {
                                Ok(Recorded::Stored) => {}
                                Ok(Recorded::Refused(reason)) => {
                                    // the cell is complete globally, just
                                    // not ours to persist (claim mode)
                                    stored = false;
                                    refused += 1;
                                    if req.verbose {
                                        crate::log_info!(
                                            "[{}] note: cell {} not \
                                             recorded here: {reason}",
                                            req.label, it.cell_index
                                        );
                                    }
                                }
                                Err(e) => {
                                    // persistence failure is fatal: stop
                                    // claiming new cells, drain, report
                                    stored = false;
                                    queue.lock().unwrap().stop = true;
                                    available.notify_all();
                                    store_err = Some(e);
                                }
                            }
                        }
                        if store_err.is_none() && stored {
                            fresh += 1;
                            let halted = match req.halt_after_cells {
                                Some(n) => {
                                    if n > 0 && fresh >= n {
                                        Some(anyhow!(
                                            "halted after {fresh} freshly \
                                             computed cell(s) \
                                             (halt_after_cells={n} crash \
                                             injection)"
                                        ))
                                    } else {
                                        None
                                    }
                                }
                                None => super::crash_injection_point().err(),
                            };
                            if let Some(e) = halted {
                                queue.lock().unwrap().stop = true;
                                available.notify_all();
                                halt_err = Some(e);
                            }
                        }
                    }
                    slots[it.member][it.slot] = Some(*out);
                }
                Msg::RunErr { item, err } => {
                    let is_first =
                        first_err.as_ref().map_or(true, |(i, _)| item < *i);
                    if is_first {
                        first_err = Some((item, err));
                    }
                }
                Msg::SetupErr { model, err } => {
                    setup_errs.push((model, err));
                }
                Msg::SourceErr { err } => {
                    if source_err.is_none() {
                        source_err = Some(err);
                    }
                }
                Msg::WorkerExit { stats } => worker_stats.push(stats),
            }
        }
    });

    worker_stats.sort_by_key(|s| s.worker);
    let q = queue.into_inner().unwrap();
    let done = q
        .items
        .iter()
        .filter(|it| slots[it.member][it.slot].is_some())
        .count();
    // a real cell failure always wins (reported at its true identity)
    if let Some((i, e)) = first_err {
        let it = &q.items[i];
        let m = &req.members[it.member];
        let who = if m.name.is_empty() {
            m.model.clone()
        } else {
            m.name.clone()
        };
        return Err(e.context(format!(
            "{}: cell {} of '{who}' failed ({done}/{} complete)",
            req.label,
            it.cell_index,
            q.items.len()
        )));
    }
    if let Some(e) = store_err {
        return Err(e.context("persisting cell artifact"));
    }
    if let Some(e) = source_err {
        return Err(e.context(format!("{}: item source failed", req.label)));
    }
    if let Some(e) = halt_err {
        return Err(e);
    }
    if req.source.is_none() && done != q.items.len() {
        // cells went unclaimed — every worker that tried their model
        // failed to compile it (or died on setup). Prefer a compile
        // error that names a model over a bare worker-init failure: the
        // init error may be an unrelated worker, while a named compile
        // failure is what actually left cells unclaimed. (Sourced runs
        // skip this: their source decides global completion, and an
        // enqueued item another claimer finished is not a failure.)
        let e = match setup_errs.iter().position(|(m, _)| !m.is_empty()) {
            Some(i) => {
                let (model, e) = setup_errs.swap_remove(i);
                e.context(format!("compiling model '{model}'"))
            }
            None => setup_errs
                .into_iter()
                .next()
                .map(|(_, e)| e)
                .unwrap_or_else(|| anyhow!("worker(s) exited early")),
        };
        return Err(e.context(format!(
            "{}: {} of {} cells unclaimed (no worker could run them)",
            req.label,
            q.items.len() - done,
            q.items.len()
        )));
    }
    if !setup_errs.is_empty() {
        // all cells ran on the surviving workers — degraded but complete
        let (model, e) = &setup_errs[0];
        let what = if model.is_empty() {
            "a worker failed to initialize".to_string()
        } else {
            format!("a worker could not compile model '{model}'")
        };
        crate::log_warn!(
            "[{}] note: {what} ({e:#}); all cells completed on the \
             remaining workers",
            req.label
        );
    }
    Ok(ExecStats { jobs, workers: worker_stats, refused })
}

/// Shared, append-only registry of pre-validated model specs keyed by
/// model name. The static paths (sweep, campaign, claim) fill it once
/// up-front; a long-lived `cpt serve` pool keeps one registry for the
/// daemon's whole lifetime and registers each job's models at submit
/// time, so workers spawned before a job existed can still resolve its
/// specs. Append-only by convention: a model name always maps to the
/// same spec content within one process (the artifact manifest is
/// fixed), so re-registration is an idempotent overwrite.
#[derive(Default)]
pub struct SpecRegistry {
    specs: RwLock<HashMap<String, ModelSpec>>,
}

impl SpecRegistry {
    pub fn new() -> SpecRegistry {
        SpecRegistry::default()
    }

    /// Wrap an already-built spec table (the static one-shot paths).
    pub fn from_map(specs: HashMap<String, ModelSpec>) -> SpecRegistry {
        SpecRegistry { specs: RwLock::new(specs) }
    }

    /// Register (or idempotently re-register) one model spec.
    pub fn insert(&self, name: &str, spec: ModelSpec) {
        self.specs.write().unwrap().insert(name.to_string(), spec);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.specs.read().unwrap().contains_key(name)
    }

    /// Clone out a spec (specs are small metadata; lookups happen only
    /// on executable-cache misses).
    pub fn get(&self, name: &str) -> Option<ModelSpec> {
        self.specs.read().unwrap().get(name).cloned()
    }
}

/// Production [`CellRunner`]: one PJRT client plus a two-level cache of
/// compiled entry-point sets keyed by model fingerprint — an in-memory
/// LRU, optionally backed by the persistent AOT disk store
/// (`coordinator::aot`). Compilation is the dominant fixed cost per
/// worker (DESIGN-perf §1), so the cache is what makes cross-member
/// scheduling cheap: claiming a cell of a member whose model is already
/// cached costs zero recompiles, and with a populated AOT store even a
/// brand-new process warm-starts. Ownership is `Arc`-shared (not
/// borrowed) so a runner can live on a detached `'static` pool thread
/// that outlives any one run (`coordinator::pool`).
pub struct PjrtCellRunner {
    rt: Runtime,
    /// Pre-validated specs shared by every worker, keyed by model name.
    specs: Arc<SpecRegistry>,
    /// Second level below the LRU; `None` runs memory-only.
    aot: Option<Arc<AotStore>>,
    /// LRU order: most recently used last.
    cache: Vec<(String, LoadedModel)>,
    cache_cap: usize,
    compiles: usize,
    compile_seconds: f64,
    cache_stats: CacheStats,
    aot_noted: bool,
}

impl PjrtCellRunner {
    pub fn new(
        specs: Arc<SpecRegistry>,
        cache_cap: usize,
        aot: Option<Arc<AotStore>>,
    ) -> Result<Self> {
        Ok(PjrtCellRunner {
            rt: Runtime::cpu()?,
            specs,
            aot,
            cache: Vec::new(),
            cache_cap: cache_cap.max(1),
            compiles: 0,
            compile_seconds: 0.0,
            cache_stats: CacheStats::default(),
            aot_noted: false,
        })
    }

    /// Two-level cache lookup: in-memory LRU, then the AOT disk store,
    /// then compile (publishing the result for future processes). The
    /// in-memory insert evicts least-recently-used at capacity.
    fn model_for(&mut self, member: &ExecMember) -> Result<&LoadedModel> {
        if let Some(pos) = self
            .cache
            .iter()
            .position(|(fp, _)| fp == &member.fingerprint)
        {
            let entry = self.cache.remove(pos);
            self.cache.push(entry);
            self.cache_stats.hits += 1;
            return Ok(&self.cache.last().unwrap().1);
        }
        self.cache_stats.misses += 1;
        let spec = self.specs.get(&member.model).with_context(|| {
            format!("no shared spec for model '{}'", member.model)
        })?;
        let model = match self.aot_load(member, &spec) {
            Some(model) => {
                self.cache_stats.disk_hits += 1;
                model
            }
            None => {
                let t0 = Instant::now();
                let model = self.rt.load_model(&spec)?;
                self.compiles += 1;
                self.compile_seconds += t0.elapsed().as_secs_f64();
                self.aot_publish(member, &model);
                model
            }
        };
        if self.cache.len() >= self.cache_cap {
            self.cache.remove(0);
        }
        self.cache.push((member.fingerprint.clone(), model));
        Ok(&self.cache.last().unwrap().1)
    }

    /// Whether this fingerprint may address the disk store. Store-less
    /// sweeps fall back to a name-derived pseudo-fingerprint
    /// (`model:<name>`, see `run_sweep_timed`) that identifies no spec
    /// content, so it must never key persistent entries.
    fn aot_addressable(&self, member: &ExecMember) -> bool {
        self.aot.is_some() && !member.fingerprint.starts_with("model:")
    }

    /// Disk-level lookup. Any failure — absent or damaged entry, backend
    /// refusing to deserialize — degrades to a plain compile.
    fn aot_load(
        &mut self,
        member: &ExecMember,
        spec: &ModelSpec,
    ) -> Option<LoadedModel> {
        if !self.aot_addressable(member) {
            return None;
        }
        let key = aot::AotKey::new(
            &member.fingerprint,
            &self.rt.platform(),
            aot::CODEC_PJRT,
        );
        let payloads = self.aot.as_ref()?.load(&key)?;
        match self.rt.load_model_from_bytes(spec, &payloads) {
            Ok(model) => Some(model),
            Err(err) => {
                self.note_once(&format!(
                    "cached executable for '{}' failed to load ({err:#}); \
                     recompiling",
                    member.model
                ));
                None
            }
        }
    }

    /// Best-effort publication of a fresh compile so later processes
    /// warm-start. Never fails the run: a backend that cannot serialize
    /// (or a full disk) costs one note and nothing else.
    fn aot_publish(&mut self, member: &ExecMember, model: &LoadedModel) {
        if !self.aot_addressable(member) {
            return;
        }
        let key = aot::AotKey::new(
            &member.fingerprint,
            &self.rt.platform(),
            aot::CODEC_PJRT,
        );
        match self.rt.serialize_model(model) {
            Ok(payloads) => {
                if let Err(err) = self
                    .aot
                    .as_ref()
                    .unwrap()
                    .publish(&key, &member.model, &payloads)
                {
                    self.note_once(&format!(
                        "could not publish executable for '{}' ({err:#})",
                        member.model
                    ));
                }
            }
            Err(err) => self.note_once(&format!("{err:#}")),
        }
    }

    fn note_once(&mut self, msg: &str) {
        if !self.aot_noted {
            self.aot_noted = true;
            crate::log_warn!("[aot] note: {msg}");
        }
    }
}

impl CellRunner for PjrtCellRunner {
    fn run_cell(
        &mut self,
        member: &ExecMember,
        cell: &SweepCell,
        _cell_index: usize,
        per_step_logs: bool,
    ) -> std::result::Result<RunOutcome, CellError> {
        let model = match self.model_for(member) {
            Ok(m) => m,
            Err(e) => return Err(CellError::Setup(e)),
        };
        run_one_with_policy(
            model,
            &member.model,
            &member.policy,
            &cell.schedule,
            cell.q_max,
            cell.trial,
            member.steps,
            member.cycles,
            member.eval_every,
            per_step_logs,
        )
        .map_err(CellError::Run)
    }

    fn compile_stats(&self) -> (usize, f64) {
        (self.compiles, self.compile_seconds)
    }

    fn has_cached(&self, fingerprint: &str) -> bool {
        self.cache.iter().any(|(fp, _)| fp == fingerprint)
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache_stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::group_of;
    use std::sync::Arc;

    fn member(name: &str, fp: &str, cap: usize) -> ExecMember {
        ExecMember {
            name: name.into(),
            model: format!("model-{fp}"),
            fingerprint: fp.into(),
            policy: PolicySpec::StaticSuite,
            steps: 8,
            cycles: 8,
            eval_every: 0,
            cap,
        }
    }

    fn items_for(members: &[ExecMember], cells_each: usize) -> Vec<ExecItem> {
        let mut items = Vec::new();
        for (mi, _) in members.iter().enumerate() {
            for c in 0..cells_each {
                items.push(ExecItem {
                    member: mi,
                    cell_index: c,
                    slot: c,
                    cell: SweepCell {
                        schedule: "CR".into(),
                        q_max: 8.0,
                        trial: c,
                    },
                });
            }
        }
        items
    }

    fn fab(member: &ExecMember, cell: &SweepCell, index: usize) -> RunOutcome {
        RunOutcome {
            model: member.model.clone(),
            schedule: cell.schedule.clone(),
            group: group_of(&cell.schedule).label().into(),
            q_max: cell.q_max,
            trial: cell.trial,
            gbitops: 1.0 + index as f64,
            metric: 0.5 + index as f64 * 0.125,
            eval_loss: 0.25,
            steps: member.steps,
            mean_q: 0.75,
            realized_cost: 0.5,
            exec_seconds: 0.01,
            history: crate::metrics::History::default(),
        }
    }

    /// Fabricated runner: optional per-fingerprint compile failures,
    /// optional per-fingerprint concurrency gauge, simulated compile
    /// cache.
    struct FabRunner {
        fail: HashSet<String>,
        /// Per-fingerprint countdown of *transient* setup failures: the
        /// first N attempts fail, then the model compiles fine (shared
        /// across workers so the count is per pool, like a flaky device).
        transient: Option<Arc<Mutex<HashMap<String, usize>>>>,
        compiled: Vec<String>,
        compiles: usize,
        fail_cell: Option<(usize, usize)>, // (member, cell_index) to fail
        gauge: Option<Arc<Gauge>>,
        sleep_ms: u64,
    }

    /// Concurrency high-water mark per fingerprint (members and
    /// fingerprints are 1:1 in these tests).
    struct Gauge {
        inner: Mutex<std::collections::HashMap<String, (usize, usize)>>,
    }

    impl Gauge {
        fn new() -> Gauge {
            Gauge { inner: Mutex::new(std::collections::HashMap::new()) }
        }

        fn enter(&self, fp: &str) {
            let mut g = self.inner.lock().unwrap();
            let e = g.entry(fp.to_string()).or_insert((0, 0));
            e.0 += 1;
            e.1 = e.1.max(e.0);
        }

        fn exit(&self, fp: &str) {
            let mut g = self.inner.lock().unwrap();
            g.get_mut(fp).unwrap().0 -= 1;
        }

        fn high_water(&self, fp: &str) -> usize {
            self.inner.lock().unwrap().get(fp).map_or(0, |e| e.1)
        }
    }

    impl FabRunner {
        fn plain() -> FabRunner {
            FabRunner {
                fail: HashSet::new(),
                transient: None,
                compiled: Vec::new(),
                compiles: 0,
                fail_cell: None,
                gauge: None,
                sleep_ms: 0,
            }
        }
    }

    impl CellRunner for FabRunner {
        fn run_cell(
            &mut self,
            member: &ExecMember,
            cell: &SweepCell,
            cell_index: usize,
            _per_step_logs: bool,
        ) -> std::result::Result<RunOutcome, CellError> {
            if self.fail.contains(&member.fingerprint) {
                return Err(CellError::Setup(anyhow!(
                    "injected compile failure for {}",
                    member.fingerprint
                )));
            }
            if let Some(t) = &self.transient {
                let mut t = t.lock().unwrap();
                if let Some(n) = t.get_mut(&member.fingerprint) {
                    if *n > 0 {
                        *n -= 1;
                        return Err(CellError::Setup(anyhow!(
                            "injected transient setup failure for {}",
                            member.fingerprint
                        )));
                    }
                }
            }
            if !self.compiled.contains(&member.fingerprint) {
                self.compiled.push(member.fingerprint.clone());
                self.compiles += 1;
            }
            if let Some(g) = &self.gauge {
                g.enter(&member.fingerprint);
            }
            if self.sleep_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    self.sleep_ms,
                ));
            }
            if let Some(g) = &self.gauge {
                g.exit(&member.fingerprint);
            }
            if self.fail_cell == Some((0, cell_index)) {
                return Err(CellError::Run(anyhow!("injected cell failure")));
            }
            Ok(fab(member, cell, cell_index))
        }

        fn compile_stats(&self) -> (usize, f64) {
            (self.compiles, 0.0)
        }

        fn has_cached(&self, fingerprint: &str) -> bool {
            self.compiled.iter().any(|f| f == fingerprint)
        }
    }

    fn run(
        members: &[ExecMember],
        items: &[ExecItem],
        jobs: usize,
        halt: Option<usize>,
        make: impl Fn(usize) -> Result<FabRunner> + Sync,
    ) -> (Result<ExecStats>, Vec<Vec<Option<RunOutcome>>>) {
        let req = ExecRequest {
            label: "test".into(),
            members,
            items,
            jobs,
            verbose: false,
            halt_after_cells: halt,
            source: None,
        };
        let mut stores: Vec<Option<&mut dyn CellSink>> =
            members.iter().map(|_| None).collect();
        let cells = items
            .iter()
            .fold(vec![0usize; members.len()], |mut acc, it| {
                acc[it.member] = acc[it.member].max(it.slot + 1);
                acc
            });
        let mut slots: Vec<Vec<Option<RunOutcome>>> =
            cells.into_iter().map(|n| vec![None; n]).collect();
        let res = run_items(&req, &mut stores, &mut slots, make);
        (res, slots)
    }

    #[test]
    fn pool_completes_all_items_across_members() {
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 3);
        let (res, slots) =
            run(&members, &items, 3, None, |_| Ok(FabRunner::plain()));
        let stats = res.unwrap();
        assert!(stats.jobs <= 3);
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        // every worker compiled each fingerprint it touched at most once
        for w in &stats.workers {
            assert!(w.compiles <= 2, "{w:?}");
        }
        assert_eq!(
            stats.workers.iter().map(|w| w.cells).sum::<usize>(),
            items.len()
        );
        // outcomes landed in the right member/slot (index-dependent fab)
        for (mi, m) in members.iter().enumerate() {
            for (ci, out) in slots[mi].iter().enumerate() {
                let out = out.as_ref().unwrap();
                assert_eq!(out.model, m.model);
                assert_eq!(out.metric, 0.5 + ci as f64 * 0.125);
            }
        }
    }

    #[test]
    fn compile_failure_keeps_worker_alive_for_other_members() {
        // worker 0 cannot compile fpA; worker 1 can compile everything —
        // the pool still completes, and worker 0 contributed fpB cells
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 4);
        let (res, slots) = run(&members, &items, 2, None, |w| {
            let mut r = FabRunner::plain();
            if w == 0 {
                r.fail.insert("fpA".into());
            }
            r.sleep_ms = 1; // overlap so worker 0 gets claims
            Ok(r)
        });
        let stats = res.unwrap();
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        let w0 = stats.workers.iter().find(|w| w.worker == 0).unwrap();
        // worker 0 never compiled fpA (its one attempt failed, uncounted)
        assert!(w0.compiles <= 1, "{w0:?}");
    }

    #[test]
    fn unclaimed_cells_fail_with_the_compile_error() {
        // no worker can compile fpA: member a's cells are unclaimed and
        // the run fails with the compile error; member b still completed
        let members = [member("a", "fpA", 4), member("b", "fpB", 4)];
        let items = items_for(&members, 2);
        let (res, slots) = run(&members, &items, 2, None, |_| {
            let mut r = FabRunner::plain();
            r.fail.insert("fpA".into());
            Ok(r)
        });
        let err = res.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unclaimed"), "{msg}");
        assert!(msg.contains("injected compile failure"), "{msg}");
        assert!(slots[1].iter().all(|o| o.is_some()), "member b must run");
        assert!(slots[0].iter().all(|o| o.is_none()));
    }

    #[test]
    fn worker_setup_failure_is_nonfatal_when_pool_survives() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 3);
        let (res, slots) = run(&members, &items, 2, None, |w| {
            if w == 0 {
                anyhow::bail!("injected worker init failure");
            }
            Ok(FabRunner::plain())
        });
        res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
    }

    #[test]
    fn cell_failure_aborts_the_whole_run() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 4);
        let (res, _) = run(&members, &items, 2, None, |_| {
            let mut r = FabRunner::plain();
            r.fail_cell = Some((0, 1));
            Ok(r)
        });
        let err = res.unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected cell failure"), "{msg}");
        assert!(msg.contains("cell 1"), "{msg}");
    }

    #[test]
    fn injected_halt_stops_after_n_fresh_cells() {
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 5);
        let (res, slots) =
            run(&members, &items, 1, Some(2), |_| Ok(FabRunner::plain()));
        let err = res.unwrap_err();
        assert!(format!("{err:#}").contains("halted after 2"), "{err:#}");
        // at least the halted-on cells completed (the worker may have
        // computed more before observing the stop flag — the *recorded*
        // count is what the halt bounds exactly, asserted in
        // tests/global_sched.rs against a real store)
        let done = slots[0].iter().filter(|o| o.is_some()).count();
        assert!((2..=5).contains(&done), "{done}");
    }

    #[test]
    fn transient_setup_failure_is_retried_and_counted() {
        // the first two compile attempts for fpA fail, the third works:
        // a single worker must ride through on retries alone (no second
        // worker exists to take the item), completing everything
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 3);
        let transient = Arc::new(Mutex::new(HashMap::from([(
            "fpA".to_string(),
            2usize,
        )])));
        let (res, slots) = run(&members, &items, 1, None, |_| {
            let mut r = FabRunner::plain();
            r.transient = Some(transient.clone());
            Ok(r)
        });
        let stats = res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
        assert_eq!(stats.total_retries(), 2, "{:?}", stats.workers);
        // the failure healed within the attempt budget: no scary
        // "unclaimed"/setup note path was taken (run returned Ok above)
        assert_eq!(
            stats.workers.iter().map(|w| w.cells).sum::<usize>(),
            items.len()
        );
    }

    #[test]
    fn exhausted_transient_budget_still_skips_the_model() {
        // permanent failure: retries burn out, the model is skipped, and
        // with no other worker the cells end up unclaimed
        let members = [member("a", "fpA", 4)];
        let items = items_for(&members, 2);
        let (res, _) = run(&members, &items, 1, None, |_| {
            let mut r = FabRunner::plain();
            r.fail.insert("fpA".into());
            Ok(r)
        });
        let msg = format!("{:#}", res.unwrap_err());
        assert!(msg.contains("unclaimed"), "{msg}");
    }

    /// Scripted ItemSource: hands out `batches` in order, then reports
    /// Wait once (exercising the poll path), then Exhausted.
    struct FabSource {
        batches: Mutex<Vec<Vec<ExecItem>>>,
        waits: Mutex<usize>,
    }

    impl ItemSource for FabSource {
        fn refill(&self) -> Result<Refill> {
            if let Some(batch) = self.batches.lock().unwrap().pop() {
                return Ok(Refill::Items(batch));
            }
            let mut w = self.waits.lock().unwrap();
            if *w > 0 {
                *w -= 1;
                return Ok(Refill::Wait(Duration::from_millis(5)));
            }
            Ok(Refill::Exhausted)
        }
    }

    #[test]
    fn item_source_feeds_the_pool_incrementally() {
        let members = [member("a", "fpA", 4)];
        let all = items_for(&members, 6);
        // seed two, source the other four in two batches
        let seed = &all[..2];
        let batches = vec![all[4..].to_vec(), all[2..4].to_vec()];
        let source = FabSource {
            batches: Mutex::new(batches),
            waits: Mutex::new(2),
        };
        let req = ExecRequest {
            label: "test".into(),
            members: &members,
            items: seed,
            jobs: 3,
            verbose: false,
            halt_after_cells: None,
            source: Some(&source),
        };
        let mut sinks: Vec<Option<&mut dyn CellSink>> = vec![None];
        let mut slots: Vec<Vec<Option<RunOutcome>>> = vec![vec![None; 6]];
        let stats =
            run_items(&req, &mut sinks, &mut slots, |_| Ok(FabRunner::plain()))
                .unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
        assert_eq!(
            stats.workers.iter().map(|w| w.cells).sum::<usize>(),
            6
        );
        // every handed-out batch was drained and the waits were consumed
        assert!(source.batches.lock().unwrap().is_empty());
        assert_eq!(*source.waits.lock().unwrap(), 0);
    }

    #[test]
    fn item_source_error_is_fatal() {
        struct BadSource;
        impl ItemSource for BadSource {
            fn refill(&self) -> Result<Refill> {
                anyhow::bail!("injected source failure")
            }
        }
        let members = [member("a", "fpA", 4)];
        let req = ExecRequest {
            label: "test".into(),
            members: &members,
            items: &[],
            jobs: 2,
            verbose: false,
            halt_after_cells: None,
            source: Some(&BadSource),
        };
        let mut sinks: Vec<Option<&mut dyn CellSink>> = vec![None];
        let mut slots: Vec<Vec<Option<RunOutcome>>> = vec![vec![]];
        let err =
            run_items(&req, &mut sinks, &mut slots, |_| Ok(FabRunner::plain()))
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected source failure"), "{msg}");
        assert!(msg.contains("item source failed"), "{msg}");
    }

    #[test]
    fn per_member_cap_bounds_inflight_cells() {
        // member a has cap 1: even with 4 workers, its cells never
        // overlap; member b (cap 4) soaks up the rest of the pool
        let members = [member("a", "fpA", 1), member("b", "fpB", 4)];
        let items = items_for(&members, 6);
        let gauge = Arc::new(Gauge::new());
        let (res, slots) = run(&members, &items, 4, None, |_| {
            let mut r = FabRunner::plain();
            r.gauge = Some(gauge.clone());
            r.sleep_ms = 2;
            Ok(r)
        });
        res.unwrap();
        assert!(slots.iter().all(|s| s.iter().all(|o| o.is_some())));
        assert!(
            gauge.high_water("fpA") <= 1,
            "cap-1 member overlapped: {}",
            gauge.high_water("fpA")
        );
        assert!(gauge.high_water("fpB") <= 4);
    }
}

//! Persistent run store: one artifact per completed sweep cell, governed
//! by a `run-manifest.json`.
//!
//! Layout of a run directory (one per shard):
//!
//! ```text
//! <run-dir>/
//!   run-manifest.json            # schema version, spec hash, shard id,
//!                                # per-cell file + checksum
//!   00000-CR-q6-t0.json          # RunOutcome artifact, canonical index 0
//!   00002-RR-q6-t0.json          # ... only the cells this shard owns
//! ```
//!
//! Invariants (see rust/DESIGN-sharding.md):
//! * every write is atomic (tmp sibling + rename) — a crash never leaves
//!   a truncated manifest or artifact;
//! * the manifest's `spec_hash` is the [`SweepPlan`] content hash and
//!   `model_fingerprint` covers the compiled model (metadata + HLO file
//!   bytes), so artifacts from incompatible sweeps — or from a
//!   regenerated `artifacts/` tree — can never be resumed into or
//!   merged with each other;
//! * each manifest entry carries an FNV-1a checksum of the artifact
//!   bytes plus the cell's executable seconds (so `cpt status` reports
//!   progress and per-cell cost from the manifest alone); on resume,
//!   entries whose artifact is missing or corrupt are dropped (the cell
//!   is simply recomputed);
//! * [`compact_run_dir`] (`cpt gc`) strips per-step histories from
//!   recorded artifacts — aggregates read only scalar fields, so merged
//!   CSVs are unchanged while artifact size drops by an order of
//!   magnitude on long runs;
//! * artifact JSON round-trips every `RunOutcome` field bit-exactly —
//!   f32 histories, `-0.0`, infinities, and f64 NaNs with their payload
//!   bits — so a resumed or merged sweep reports byte-identical
//!   aggregates to a fresh one. (The one caveat: an f32 NaN's payload
//!   passes through the platform's f32↔f64 widening casts.)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::plan::{ShardId, SweepPlan};
use super::RunOutcome;
use crate::metrics::History;
use crate::runtime::ModelSpec;
use crate::util::hash::{fnv1a64_hex, Fnv1a64};
use crate::util::json::{num, obj, s, Json};
use crate::util::write_atomic;

pub const MANIFEST_FILE: &str = "run-manifest.json";
const MANIFEST_KIND: &str = "cpt-sweep-run";
const CELL_KIND: &str = "cpt-cell";
const SCHEMA_VERSION: usize = 1;
/// Training-code version recorded in every manifest and fenced on
/// resume/merge: spec hash + model fingerprint cannot see a trainer or
/// schedule code change that alters results with identical artifacts.
/// Granularity is the crate version — bump it (as every PR here does)
/// when training semantics change; same-version code edits are the
/// residual blind spot.
const CODE_VERSION: &str = env!("CARGO_PKG_VERSION");

/// Content fingerprint of a compiled model artifact: the machine-
/// independent spec metadata plus the bytes of every referenced HLO
/// file. Recorded in the run manifest and checked on resume and merge,
/// because the sweep-spec hash alone cannot see a regenerated
/// `artifacts/` tree — without this, cells trained against an old model
/// could silently mix with cells trained against a new one. File paths
/// are deliberately excluded (only logical keys + contents), so shards
/// produced on different machines still fingerprint identically.
pub fn model_fingerprint(spec: &ModelSpec) -> Result<String> {
    let mut h = Fnv1a64::new();
    h.update(
        format!(
            "cpt-model-v1;name={};params={};opt={};chunk={};optimizer={};\
             metric={};qflops={};fpflops={};aggq={};aggfp={};\
             inputs={:?};param_entries={:?}",
            spec.name,
            spec.param_count,
            spec.opt_state_count,
            spec.chunk,
            spec.optimizer,
            spec.metric,
            spec.q_gemm_flops_fwd,
            spec.fp_gemm_flops_fwd,
            spec.agg_q_gemm_flops_fwd,
            spec.agg_fp_gemm_flops_fwd,
            spec.data_inputs,
            spec.params,
        )
        .as_bytes(),
    );
    for (key, path) in &spec.files {
        let bytes = std::fs::read(path).with_context(|| {
            format!("fingerprint model file {}", path.display())
        })?;
        // length-prefix each field so (key, contents) boundaries are
        // unambiguous in the hash stream
        h.update(&(key.len() as u64).to_le_bytes());
        h.update(key.as_bytes());
        h.update(&(bytes.len() as u64).to_le_bytes());
        h.update(&bytes);
    }
    Ok(h.finish_hex())
}

/// Manifest record for one completed cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CellEntry {
    pub file: String,
    pub checksum: String,
    /// Executable wall-clock seconds the cell cost when it was computed
    /// (recorded so `cpt status` reports per-cell cost straight from the
    /// manifest, without opening any artifact).
    pub seconds: f64,
    /// Compact trace summary: realized mean q_t/q_max of the cell's run.
    /// `None` on manifests written before the policy subsystem — every
    /// reader falls back silently.
    pub mean_q: Option<f64>,
    /// Compact trace summary: realized relative cost vs static q_max.
    pub realized_cost: Option<f64>,
}

/// Parsed, validated view of one `run-manifest.json` — the shared input
/// to resume (`RunStore::open`), `merge_run_dirs`, `cpt status`, and
/// `cpt gc`.
#[derive(Clone, Debug)]
pub struct ManifestSummary {
    pub cpt_version: String,
    pub spec_hash: String,
    pub model_fingerprint: String,
    pub model: String,
    pub shard: ShardId,
    pub total_cells: usize,
    pub cells: BTreeMap<usize, CellEntry>,
}

impl ManifestSummary {
    /// Cells this shard is responsible for.
    pub fn planned(&self) -> usize {
        self.shard.owned_count(self.total_cells)
    }

    /// Cells recorded with an artifact (validated lazily on use).
    pub fn done(&self) -> usize {
        self.cells.len()
    }

    /// Cells still to compute; `done + remaining == planned` always
    /// (read_manifest rejects manifests recording un-owned indices).
    pub fn remaining(&self) -> usize {
        self.planned() - self.done()
    }

    /// Total executable seconds across recorded cells.
    pub fn exec_seconds(&self) -> f64 {
        self.cells.values().map(|e| e.seconds).sum()
    }

    /// Mean realized q_t/q_max over the recorded cells that carry a
    /// trace summary; `None` when none do (pre-policy manifests), so
    /// `cpt status` can fall back silently.
    pub fn mean_q(&self) -> Option<f64> {
        mean_of(self.cells.values().filter_map(|e| e.mean_q))
    }

    /// Mean realized relative cost over cells with a trace summary.
    pub fn realized_cost(&self) -> Option<f64> {
        mean_of(self.cells.values().filter_map(|e| e.realized_cost))
    }
}

fn mean_of(vals: impl Iterator<Item = f64>) -> Option<f64> {
    let (mut sum, mut n) = (0.0, 0usize);
    for v in vals {
        sum += v;
        n += 1;
    }
    if n == 0 {
        None
    } else {
        Some(sum / n as f64)
    }
}

/// A run directory opened for one shard of one sweep plan.
pub struct RunStore {
    dir: PathBuf,
    m: ManifestSummary,
}

impl RunStore {
    /// Open `dir` for `plan`. A fresh directory is initialized with an
    /// empty manifest. An existing run is reopened only when `resume` is
    /// set, and only if its manifest matches the plan (spec hash, model
    /// fingerprint, shard, cell count) — recorded cells with valid
    /// artifacts are kept so the executor can skip them.
    pub fn open(
        dir: &Path,
        plan: &SweepPlan,
        model_fingerprint: &str,
        resume: bool,
    ) -> Result<RunStore> {
        let manifest_path = dir.join(MANIFEST_FILE);
        if !manifest_path.exists() {
            if dir.join(super::campaign::CAMPAIGN_MANIFEST_FILE).exists() {
                // the mirror of open_campaign_root's guard: a dir answers
                // to exactly one manifest kind, or status/gc/merge would
                // dispatch on whichever they look for first
                bail!(
                    "{} is a campaign root (it contains {}); sweep run \
                     dirs live in its member subdirectories",
                    dir.display(),
                    super::campaign::CAMPAIGN_MANIFEST_FILE
                );
            }
            let store = RunStore {
                dir: dir.to_path_buf(),
                m: ManifestSummary {
                    cpt_version: CODE_VERSION.to_string(),
                    spec_hash: plan.spec_hash.clone(),
                    model_fingerprint: model_fingerprint.to_string(),
                    model: plan.model.clone(),
                    shard: plan.shard,
                    total_cells: plan.total_cells(),
                    cells: BTreeMap::new(),
                },
            };
            store.write_manifest()?;
            return Ok(store);
        }
        if !resume {
            bail!(
                "run dir {} already contains {MANIFEST_FILE}; pass --resume \
                 to continue it, or point --run-dir at a fresh directory",
                dir.display()
            );
        }
        let m = read_manifest(dir)?;
        if m.spec_hash != plan.spec_hash {
            bail!(
                "cannot resume {}: it was created for a different sweep spec \
                 (manifest spec_hash {}, requested {})",
                dir.display(),
                m.spec_hash,
                plan.spec_hash
            );
        }
        if m.model_fingerprint != model_fingerprint {
            bail!(
                "cannot resume {}: the compiled model artifact has changed \
                 since this run dir was created (fingerprint {} vs {}) — \
                 its recorded cells were trained against a different model; \
                 use a fresh run directory",
                dir.display(),
                m.model_fingerprint,
                model_fingerprint
            );
        }
        if m.cpt_version != CODE_VERSION {
            bail!(
                "cannot resume {}: it was written by cpt {} but this binary \
                 is {} — training code may have changed, so its cells \
                 cannot be mixed with fresh ones; use a fresh run directory",
                dir.display(),
                m.cpt_version,
                CODE_VERSION
            );
        }
        if m.shard != plan.shard {
            bail!(
                "cannot resume {}: it belongs to shard {} but this run is \
                 shard {}",
                dir.display(),
                m.shard,
                plan.shard
            );
        }
        if m.total_cells != plan.total_cells() || m.model != plan.model {
            // unreachable if the hash matches, but fail loudly rather
            // than trusting a hand-edited manifest
            bail!("manifest in {} is inconsistent with the plan", dir.display());
        }
        // artifact bytes are validated lazily, one read per cell, when
        // the executor asks for them (`take_valid_outcome`)
        Ok(RunStore { dir: dir.to_path_buf(), m })
    }

    /// The training-code version this build stamps into manifests.
    pub fn code_version() -> &'static str {
        CODE_VERSION
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Is the cell at this canonical index recorded with a valid artifact?
    pub fn completed(&self, index: usize) -> bool {
        self.m.cells.contains_key(&index)
    }

    /// Number of recorded cells.
    pub fn completed_count(&self) -> usize {
        self.m.cells.len()
    }

    /// Load the recorded outcome for a cell (checksum-verified); errors
    /// if the cell is unrecorded or its artifact fails validation.
    pub fn load_outcome(&self, index: usize) -> Result<RunOutcome> {
        let e = self
            .m
            .cells
            .get(&index)
            .with_context(|| format!("cell {index} is not recorded"))?;
        load_artifact(&self.dir.join(&e.file), &e.checksum, &self.m.spec_hash, index)
    }

    /// Resume path: load the recorded outcome if its artifact is present
    /// and intact — one read per artifact. On any validation failure
    /// (missing file, checksum mismatch, undecodable contents) the entry
    /// is dropped with a note and `None` is returned, so the caller
    /// simply recomputes that cell; corruption can never propagate.
    pub fn take_valid_outcome(&mut self, index: usize) -> Option<RunOutcome> {
        let e = self.m.cells.get(&index)?;
        match load_artifact(
            &self.dir.join(&e.file),
            &e.checksum,
            &self.m.spec_hash,
            index,
        ) {
            Ok(out) => Some(out),
            Err(err) => {
                crate::log_warn!(
                    "[store] note: cell {index} artifact invalid ({err:#}); \
                     it will be recomputed"
                );
                self.m.cells.remove(&index);
                None
            }
        }
    }

    /// Persist one completed cell: atomic artifact write, then atomic
    /// manifest rewrite. A crash between the two leaves an artifact the
    /// manifest does not reference — resume recomputes that cell and
    /// overwrites it, so the store never lies about completion.
    pub fn record(&mut self, index: usize, out: &RunOutcome) -> Result<()> {
        let file = format!(
            "{index:05}-{}-q{}-t{}.json",
            out.schedule, out.q_max, out.trial
        );
        let bytes = encode_cell_artifact(&self.m.spec_hash, index, out);
        write_atomic(self.dir.join(&file), bytes.as_bytes())
            .with_context(|| format!("record cell {index}"))?;
        let checksum = fnv1a64_hex(bytes.as_bytes());
        self.m.cells.insert(
            index,
            CellEntry {
                file,
                checksum,
                seconds: out.exec_seconds,
                mean_q: Some(out.mean_q),
                realized_cost: Some(out.realized_cost),
            },
        );
        self.write_manifest()
    }

    fn write_manifest(&self) -> Result<()> {
        write_manifest_file(&self.dir, &self.m)
    }
}

/// Serialize and atomically write a manifest. Factored out of `RunStore`
/// so `cpt gc` can rewrite a manifest it loaded from disk while
/// preserving the original `cpt_version` stamp (compaction changes
/// artifact bytes, never what computed them), and so the claim-mode
/// finalizer (`coordinator::lease`) can materialize a manifest from its
/// commit entries.
pub(crate) fn write_manifest_file(dir: &Path, m: &ManifestSummary) -> Result<()> {
    let mut cells = BTreeMap::new();
    for (index, e) in &m.cells {
        let mut fields =
            vec![("checksum", s(&e.checksum)), ("file", s(&e.file))];
        // trace summary keys are written only when known, so a manifest
        // that predates them (gc/status of an old tree) round-trips
        // byte-compatibly instead of growing fabricated zeros
        if let Some(mq) = e.mean_q {
            fields.push(("mean_q", num(mq)));
        }
        if let Some(rc) = e.realized_cost {
            fields.push(("realized_cost", num(rc)));
        }
        fields.push(("seconds", num(e.seconds)));
        cells.insert(format!("{index:05}"), obj(fields));
    }
    let doc = obj(vec![
        ("kind", s(MANIFEST_KIND)),
        ("version", num(SCHEMA_VERSION as f64)),
        ("cpt_version", s(&m.cpt_version)),
        ("spec_hash", s(&m.spec_hash)),
        ("model_fingerprint", s(&m.model_fingerprint)),
        ("model", s(&m.model)),
        ("shard_index", num(m.shard.index as f64)),
        ("shard_count", num(m.shard.count as f64)),
        ("total_cells", num(m.total_cells as f64)),
        ("cells", Json::Obj(cells)),
    ]);
    doc.write_atomic(dir.join(MANIFEST_FILE))
        .with_context(|| format!("write manifest in {}", dir.display()))
}

/// Load and validate the `run-manifest.json` governing `dir`.
pub fn read_manifest(dir: &Path) -> Result<ManifestSummary> {
    let path = dir.join(MANIFEST_FILE);
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&src)
        .with_context(|| format!("parse {}", path.display()))?;
    if j.get("kind")?.as_str()? != MANIFEST_KIND {
        bail!("{}: not a cpt run manifest", path.display());
    }
    let version = j.get("version")?.as_usize()?;
    if version != SCHEMA_VERSION {
        bail!(
            "{}: schema version {version} (this build reads version \
             {SCHEMA_VERSION})",
            path.display()
        );
    }
    let shard = ShardId {
        index: j.get("shard_index")?.as_usize()?,
        count: j.get("shard_count")?.as_usize()?,
    };
    let total_cells = j.get("total_cells")?.as_usize()?;
    if shard.count == 0 || shard.index == 0 || shard.index > shard.count {
        bail!("shard {}/{} out of range in {}", shard.index, shard.count, path.display());
    }
    let mut cells = BTreeMap::new();
    for (key, entry) in j.get("cells")?.as_obj()? {
        let index: usize = key
            .parse()
            .with_context(|| format!("bad cell index '{key}' in manifest"))?;
        if index >= total_cells {
            bail!("cell index {index} out of range in {}", path.display());
        }
        if !shard.owns(index) {
            // a genuine store only records owned cells; rejecting here
            // keeps done <= planned, so status arithmetic cannot wrap
            bail!(
                "cell index {index} not owned by shard {shard} in {}",
                path.display()
            );
        }
        cells.insert(
            index,
            CellEntry {
                file: entry.get("file")?.as_str()?.to_string(),
                checksum: entry.get("checksum")?.as_str()?.to_string(),
                // absent in pre-0.4 manifests (which nothing current can
                // resume anyway, but status/gc still read them)
                seconds: entry
                    .opt("seconds")
                    .map(|v| v.as_f64())
                    .transpose()?
                    .unwrap_or(0.0),
                // trace summaries are absent on pre-policy manifests —
                // readers (status, gc) fall back silently
                mean_q: entry
                    .opt("mean_q")
                    .map(|v| v.as_f64())
                    .transpose()?,
                realized_cost: entry
                    .opt("realized_cost")
                    .map(|v| v.as_f64())
                    .transpose()?,
            },
        );
    }
    Ok(ManifestSummary {
        cpt_version: j.get("cpt_version")?.as_str()?.to_string(),
        spec_hash: j.get("spec_hash")?.as_str()?.to_string(),
        model_fingerprint: j.get("model_fingerprint")?.as_str()?.to_string(),
        model: j.get("model")?.as_str()?.to_string(),
        shard,
        total_cells,
        cells,
    })
}

/// Merge N shard run directories into the full outcome list, in canonical
/// cell order. Validates that all manifests share one spec hash / model /
/// cell count, that no cell appears twice, that no cell is missing, and
/// that every artifact passes its checksum — so the result is exactly
/// what a single-process run of the same spec would have returned.
/// Returns `(model, outcomes)`.
pub fn merge_run_dirs(dirs: &[PathBuf]) -> Result<(String, Vec<RunOutcome>)> {
    if dirs.is_empty() {
        bail!("merge needs at least one run directory");
    }
    struct Head {
        cpt_version: String,
        spec_hash: String,
        model_fingerprint: String,
        model: String,
        total_cells: usize,
    }
    let mut head: Option<Head> = None;
    let mut located: BTreeMap<usize, (PathBuf, CellEntry)> = BTreeMap::new();
    for dir in dirs {
        let m = read_manifest(dir)
            .with_context(|| format!("load shard {}", dir.display()))?;
        match &head {
            None => {
                head = Some(Head {
                    cpt_version: m.cpt_version.clone(),
                    spec_hash: m.spec_hash.clone(),
                    model_fingerprint: m.model_fingerprint.clone(),
                    model: m.model.clone(),
                    total_cells: m.total_cells,
                })
            }
            Some(h) => {
                if h.cpt_version != m.cpt_version {
                    bail!(
                        "cannot merge {}: its cells were computed by cpt {} \
                         but other shards used {} — training code may differ \
                         between builds",
                        dir.display(),
                        m.cpt_version,
                        h.cpt_version
                    );
                }
                if h.spec_hash != m.spec_hash {
                    bail!(
                        "cannot merge {}: spec hash {} does not match {} — \
                         the shards come from different sweep specs",
                        dir.display(),
                        m.spec_hash,
                        h.spec_hash
                    );
                }
                if h.model_fingerprint != m.model_fingerprint {
                    bail!(
                        "cannot merge {}: its cells were trained against a \
                         different compiled model (fingerprint {} vs {})",
                        dir.display(),
                        m.model_fingerprint,
                        h.model_fingerprint
                    );
                }
                if h.model != m.model || h.total_cells != m.total_cells {
                    bail!(
                        "cannot merge {}: manifest disagrees on model/cell \
                         count despite matching spec hash",
                        dir.display()
                    );
                }
            }
        }
        for (index, e) in m.cells {
            if let Some((prev, _)) = located.get(&index) {
                bail!(
                    "duplicate cell {index}: recorded in both {} and {}",
                    prev.display(),
                    dir.display()
                );
            }
            located.insert(index, (dir.clone(), e));
        }
    }
    let h = head.unwrap();
    let total_cells = h.total_cells;
    let missing: Vec<usize> =
        (0..total_cells).filter(|i| !located.contains_key(i)).collect();
    if !missing.is_empty() {
        bail!(
            "merge incomplete: {} of {total_cells} cells missing (first: \
             {:?}) — did every shard finish?",
            missing.len(),
            &missing[..missing.len().min(8)]
        );
    }
    let mut outs = Vec::with_capacity(total_cells);
    for (index, (dir, e)) in located {
        outs.push(load_artifact(
            &dir.join(&e.file),
            &e.checksum,
            &h.spec_hash,
            index,
        )?);
    }
    Ok((h.model, outs))
}

/// What one gc pass did to a directory — `compact_run_dir` over a run
/// dir, or `AotStore::gc` over an executable cache dir.
#[derive(Clone, Copy, Debug, Default)]
pub struct GcStats {
    /// Cells recorded in the manifest (for a cache dir: valid entries
    /// remaining).
    pub cells: usize,
    /// Cells whose artifact was rewritten (non-empty history stripped).
    pub compacted: usize,
    /// Cells skipped because their artifact was missing or corrupt
    /// (left untouched; resume recomputes them).
    pub skipped: usize,
    /// Orphaned `*.tmp` staging files removed — the residue of writers
    /// that crashed between staging and publishing (see
    /// `util::write_atomic`).
    pub orphaned_tmp: usize,
    /// AOT cache entries removed — damaged ones (healing their poisoned
    /// keys) plus least-recently-used ones over the byte budget. Always
    /// 0 for run dirs (their gc never deletes cells).
    pub evicted: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// Remove every `*.tmp` file under `dir`, recursively. These are
/// staging files whose writer crashed before the publishing rename or
/// link; once the writer is gone they can never be referenced, only
/// leak. Only call this on quiescent trees — a live writer's staging
/// file looks identical to an orphan. Returns the number removed.
pub(crate) fn sweep_orphaned_tmp(dir: &Path) -> Result<usize> {
    let mut removed = 0usize;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d)
            .with_context(|| format!("read dir {}", d.display()))?;
        for e in entries {
            let e = e.with_context(|| format!("read dir {}", d.display()))?;
            let path = e.path();
            let ty = e.file_type()?;
            if ty.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "tmp") {
                std::fs::remove_file(&path)
                    .with_context(|| format!("remove {}", path.display()))?;
                removed += 1;
            }
        }
    }
    Ok(removed)
}

/// `cpt gc`: strip per-step histories (losses/metrics/evals/precisions)
/// from every recorded cell artifact, keeping all scalar fields. The
/// aggregate report reads only scalars, so merged CSVs are byte-identical
/// before and after compaction — histories just dominate artifact size on
/// long campaigns. Idempotent; artifacts that fail their checksum are
/// skipped (resume recomputes them). Each artifact is rewritten
/// atomically first and the manifest (with refreshed checksums, original
/// `cpt_version` preserved) last, so a crash mid-gc degrades to
/// recompute-on-resume for the cells caught in between, never corruption.
pub fn compact_run_dir(dir: &Path) -> Result<GcStats> {
    let mut m = read_manifest(dir)?;
    let mut stats = GcStats { cells: m.cells.len(), ..GcStats::default() };
    let mut rewritten = false;
    for (index, e) in m.cells.iter_mut() {
        let path = dir.join(&e.file);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(err) => {
                crate::log_warn!(
                    "[gc] note: cell {index} artifact unreadable ({err}); \
                     skipped"
                );
                stats.skipped += 1;
                continue;
            }
        };
        if fnv1a64_hex(&bytes) != e.checksum {
            crate::log_warn!(
                "[gc] note: cell {index} artifact fails its checksum; \
                 skipped (resume will recompute it)"
            );
            stats.skipped += 1;
            continue;
        }
        stats.bytes_before += bytes.len() as u64;
        let parsed = std::str::from_utf8(&bytes)
            .map_err(anyhow::Error::from)
            .and_then(Json::parse)
            .with_context(|| format!("parse {}", path.display()))?;
        let (doc, changed) = strip_history(parsed);
        if !changed {
            stats.bytes_after += bytes.len() as u64;
            continue;
        }
        let out = doc.to_string_pretty();
        write_atomic(&path, out.as_bytes())
            .with_context(|| format!("compact cell {index}"))?;
        e.checksum = fnv1a64_hex(out.as_bytes());
        stats.bytes_after += out.len() as u64;
        stats.compacted += 1;
        rewritten = true;
    }
    if rewritten {
        write_manifest_file(dir, &m)?;
    }
    stats.orphaned_tmp = sweep_orphaned_tmp(dir)?;
    Ok(stats)
}

/// Empty the per-step history arrays of a cell artifact document,
/// leaving every scalar (including the history's gbitops/exec_seconds)
/// in place. Returns the document and whether anything changed.
fn strip_history(mut doc: Json) -> (Json, bool) {
    let mut changed = false;
    if let Json::Obj(top) = &mut doc {
        if let Some(Json::Obj(h)) = top.get_mut("history") {
            for key in ["losses", "metrics", "evals", "precisions"] {
                if let Some(Json::Arr(v)) = h.get_mut(key) {
                    if !v.is_empty() {
                        v.clear();
                        changed = true;
                    }
                }
            }
        }
    }
    (doc, changed)
}

pub(crate) fn load_artifact(
    path: &Path,
    want_checksum: &str,
    want_spec_hash: &str,
    want_index: usize,
) -> Result<RunOutcome> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    if fnv1a64_hex(&bytes) != want_checksum {
        bail!(
            "{}: checksum mismatch (truncated or corrupt artifact)",
            path.display()
        );
    }
    let j = Json::parse(std::str::from_utf8(&bytes)?)
        .with_context(|| format!("parse {}", path.display()))?;
    if j.get("kind")?.as_str()? != CELL_KIND {
        bail!("{}: not a cpt cell artifact", path.display());
    }
    if j.get("version")?.as_usize()? != SCHEMA_VERSION {
        bail!("{}: unsupported cell schema version", path.display());
    }
    if j.get("spec_hash")?.as_str()? != want_spec_hash {
        bail!("{}: artifact spec hash disagrees with manifest", path.display());
    }
    if j.get("cell_index")?.as_usize()? != want_index {
        bail!("{}: artifact cell index disagrees with manifest", path.display());
    }
    outcome_from_json(&j)
        .with_context(|| format!("decode {}", path.display()))
}

// ---- outcome (de)serialization -----------------------------------------
//
// f64 values go through the shortest-roundtrip Display path in
// util::json, which is bit-exact; f32 values are widened to f64 (exact)
// and narrowed back on read (exact, because the value is f32-representable).
// Non-finite values would not survive the JSON number grammar, so they
// are encoded as strings: "inf" / "-inf", and NaN with its full bit
// pattern ("nan:0x7ff8000000000000") so even a nonstandard NaN payload
// (e.g. the negative qNaN x86 produces for 0/0) survives the f64 level
// of the round trip bit-exactly. (An f32 NaN still rides through the
// f32→f64→f32 widening casts, whose payload handling is the platform's.)

fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str(format!("nan:{:#018x}", x.to_bits()))
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn jf32(x: f32) -> Json {
    jnum(x as f64)
}

fn as_num(j: &Json) -> Result<f64> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN), // legacy spelling, canonical quiet NaN
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => match other.strip_prefix("nan:") {
                Some(hex) => {
                    let bits = u64::from_str_radix(
                        hex.trim_start_matches("0x"),
                        16,
                    )
                    .with_context(|| format!("bad NaN encoding '{other}'"))?;
                    let x = f64::from_bits(bits);
                    if !x.is_nan() {
                        bail!("NaN encoding '{other}' is not a NaN");
                    }
                    Ok(x)
                }
                None => bail!("not a number: {s:?}"),
            },
        },
        _ => bail!("not a number: {j:?}"),
    }
}

fn as_f32(j: &Json) -> Result<f32> {
    Ok(as_num(j)? as f32)
}

/// Serialize one cell artifact to its canonical on-disk bytes. Shared by
/// `RunStore::record` and the claim-mode recorder (`coordinator::lease`),
/// so both paths write bit-identical artifacts for identical outcomes.
pub(crate) fn encode_cell_artifact(
    spec_hash: &str,
    index: usize,
    out: &RunOutcome,
) -> String {
    outcome_to_json(spec_hash, index, out).to_string_pretty()
}

fn outcome_to_json(spec_hash: &str, index: usize, out: &RunOutcome) -> Json {
    let h = &out.history;
    let pair_f32 = |v: &[(usize, f32)]| {
        Json::Arr(
            v.iter()
                .map(|&(t, x)| Json::Arr(vec![num(t as f64), jf32(x)]))
                .collect(),
        )
    };
    let history = obj(vec![
        ("losses", pair_f32(&h.losses)),
        ("metrics", pair_f32(&h.metrics)),
        (
            "evals",
            Json::Arr(
                h.evals
                    .iter()
                    .map(|&(t, l, m)| {
                        Json::Arr(vec![num(t as f64), jf32(l), jf32(m)])
                    })
                    .collect(),
            ),
        ),
        (
            "precisions",
            Json::Arr(
                h.precisions
                    .iter()
                    .map(|&(t, q)| {
                        Json::Arr(vec![num(t as f64), num(q as f64)])
                    })
                    .collect(),
            ),
        ),
        ("gbitops", jnum(h.gbitops)),
        ("mean_q", jnum(h.mean_q)),
        ("realized_cost", jnum(h.realized_cost)),
        ("exec_seconds", jnum(h.exec_seconds)),
        ("total_seconds", jnum(h.total_seconds)),
    ]);
    obj(vec![
        ("kind", s(CELL_KIND)),
        ("version", num(SCHEMA_VERSION as f64)),
        ("spec_hash", s(spec_hash)),
        ("cell_index", num(index as f64)),
        ("model", s(&out.model)),
        ("schedule", s(&out.schedule)),
        ("group", s(&out.group)),
        ("q_max", jnum(out.q_max)),
        ("trial", num(out.trial as f64)),
        ("gbitops", jnum(out.gbitops)),
        ("metric", jnum(out.metric)),
        ("eval_loss", jnum(out.eval_loss)),
        ("steps", num(out.steps as f64)),
        ("mean_q", jnum(out.mean_q)),
        ("realized_cost", jnum(out.realized_cost)),
        ("exec_seconds", jnum(out.exec_seconds)),
        ("history", history),
    ])
}

fn outcome_from_json(j: &Json) -> Result<RunOutcome> {
    // tuples are length-checked before indexing: a structurally mangled
    // artifact must surface as Err (-> dropped and recomputed), never a
    // panic that aborts the whole resume/merge
    fn tuple(p: &Json, len: usize) -> Result<&[Json]> {
        let p = p.as_arr()?;
        if p.len() != len {
            bail!("history entry has {} fields, expected {len}", p.len());
        }
        Ok(p)
    }
    let pair_f32 = |v: &Json| -> Result<Vec<(usize, f32)>> {
        v.as_arr()?
            .iter()
            .map(|p| {
                let p = tuple(p, 2)?;
                Ok((p[0].as_usize()?, as_f32(&p[1])?))
            })
            .collect()
    };
    let hj = j.get("history")?;
    let history = History {
        losses: pair_f32(hj.get("losses")?)?,
        metrics: pair_f32(hj.get("metrics")?)?,
        evals: hj
            .get("evals")?
            .as_arr()?
            .iter()
            .map(|p| {
                let p = tuple(p, 3)?;
                Ok((p[0].as_usize()?, as_f32(&p[1])?, as_f32(&p[2])?))
            })
            .collect::<Result<_>>()?,
        precisions: hj
            .get("precisions")?
            .as_arr()?
            .iter()
            .map(|p| {
                let p = tuple(p, 2)?;
                Ok((p[0].as_usize()?, p[1].as_usize()? as u32))
            })
            .collect::<Result<_>>()?,
        gbitops: as_num(hj.get("gbitops")?)?,
        mean_q: as_num(hj.get("mean_q")?)?,
        realized_cost: as_num(hj.get("realized_cost")?)?,
        exec_seconds: as_num(hj.get("exec_seconds")?)?,
        total_seconds: as_num(hj.get("total_seconds")?)?,
    };
    Ok(RunOutcome {
        model: j.get("model")?.as_str()?.to_string(),
        schedule: j.get("schedule")?.as_str()?.to_string(),
        group: j.get("group")?.as_str()?.to_string(),
        q_max: as_num(j.get("q_max")?)?,
        trial: j.get("trial")?.as_usize()?,
        gbitops: as_num(j.get("gbitops")?)?,
        metric: as_num(j.get("metric")?)?,
        eval_loss: as_num(j.get("eval_loss")?)?,
        steps: j.get("steps")?.as_usize()?,
        mean_q: as_num(j.get("mean_q")?)?,
        realized_cost: as_num(j.get("realized_cost")?)?,
        exec_seconds: as_num(j.get("exec_seconds")?)?,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SweepCell, SweepSpec};
    use crate::schedule::group_of;

    fn spec() -> SweepSpec {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "RR".into()];
        s.q_maxes = vec![8.0];
        s.trials = 2;
        s.steps = Some(8);
        s
    }

    fn fab(cell: &SweepCell, index: usize) -> RunOutcome {
        RunOutcome {
            model: "mlp".into(),
            schedule: cell.schedule.clone(),
            group: group_of(&cell.schedule).label().into(),
            q_max: cell.q_max,
            trial: cell.trial,
            gbitops: 1.5 + index as f64 * 0.1,
            metric: 0.5 + index as f64 * 0.0625,
            eval_loss: 0.125,
            steps: 8,
            mean_q: 0.6875 + index as f64 * 0.0625,
            realized_cost: 0.5 + index as f64 * 0.03125,
            exec_seconds: 0.25,
            history: History {
                losses: vec![(0, 1.25), (1, 0.5 + index as f32 * 0.125)],
                metrics: vec![(0, 0.1)],
                evals: vec![(1, 0.75, 0.875)],
                precisions: vec![(0, 3), (1, 8)],
                gbitops: 1.5 + index as f64 * 0.1,
                mean_q: 0.6875 + index as f64 * 0.0625,
                realized_cost: 0.5 + index as f64 * 0.03125,
                exec_seconds: 0.25,
                total_seconds: 0.5,
            },
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cpt_store_{name}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn assert_outcome_eq(a: &RunOutcome, b: &RunOutcome) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.group, b.group);
        assert_eq!(a.q_max.to_bits(), b.q_max.to_bits());
        assert_eq!(a.trial, b.trial);
        assert_eq!(a.gbitops.to_bits(), b.gbitops.to_bits());
        assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits());
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.mean_q.to_bits(), b.mean_q.to_bits());
        assert_eq!(a.realized_cost.to_bits(), b.realized_cost.to_bits());
        assert_eq!(a.exec_seconds.to_bits(), b.exec_seconds.to_bits());
        assert_eq!(a.history.losses, b.history.losses);
        assert_eq!(a.history.metrics, b.history.metrics);
        assert_eq!(a.history.evals, b.history.evals);
        assert_eq!(a.history.precisions, b.history.precisions);
        assert_eq!(a.history.gbitops.to_bits(), b.history.gbitops.to_bits());
        // metric may be NaN — compare bit patterns, not values
        assert_eq!(a.metric.to_bits(), b.metric.to_bits());
    }

    #[test]
    fn outcome_roundtrip_is_bit_exact_including_awkward_floats() {
        let dir = tmp("roundtrip");
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        let mut out = fab(&plan.cells[0], 0);
        // a NaN with sign bit + payload set, like x86's 0/0 result —
        // the bit pattern itself must survive
        out.metric = f64::from_bits(0xfff8_0000_0000_1234);
        out.eval_loss = f64::NEG_INFINITY;
        out.history.losses = vec![
            (0, std::f32::consts::PI),
            (1, -0.0f32),
            (2, f32::MIN_POSITIVE),
        ];
        st.record(0, &out).unwrap();
        let back = st.load_outcome(0).unwrap();
        assert_outcome_eq(&out, &back);
        assert!(back.metric.is_nan());
        assert_eq!(
            back.history.losses[1].1.to_bits(),
            (-0.0f32).to_bits(),
            "sign of -0.0 must survive"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_decode_rejects_short_tuples_without_panicking() {
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut doc = outcome_to_json("h", 0, &fab(&plan.cells[0], 0));
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(h)) = m.get_mut("history") {
                h.insert("losses".into(), Json::parse("[[0]]").unwrap());
            }
        }
        let err = outcome_from_json(&doc).unwrap_err();
        assert!(err.to_string().contains("expected 2"), "{err:#}");
    }

    #[test]
    fn refuses_existing_dir_without_resume() {
        let dir = tmp("noresume");
        let plan = SweepPlan::build(&spec()).unwrap();
        drop(RunStore::open(&dir, &plan, "fp-test", false).unwrap());
        let err = RunStore::open(&dir, &plan, "fp-test", false).unwrap_err();
        assert!(err.to_string().contains("--resume"), "{err:#}");
        assert!(RunStore::open(&dir, &plan, "fp-test", true).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_mismatched_spec_hash() {
        let dir = tmp("hash_mismatch");
        let plan = SweepPlan::build(&spec()).unwrap();
        drop(RunStore::open(&dir, &plan, "fp-test", false).unwrap());
        let mut other = spec();
        other.trials = 5;
        let plan2 = SweepPlan::build(&other).unwrap();
        let err = RunStore::open(&dir, &plan2, "fp-test", true).unwrap_err();
        assert!(err.to_string().contains("different sweep spec"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_manifest_from_different_code_version() {
        let dir = tmp("codever");
        let plan = SweepPlan::build(&spec()).unwrap();
        drop(RunStore::open(&dir, &plan, "fp-test", false).unwrap());
        let mp = dir.join(MANIFEST_FILE);
        let edited = std::fs::read_to_string(&mp)
            .unwrap()
            .replace(CODE_VERSION, "0.0.0-other-build");
        std::fs::write(&mp, edited).unwrap();
        let err = RunStore::open(&dir, &plan, "fp-test", true).unwrap_err();
        assert!(err.to_string().contains("this binary"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_rejects_changed_model_fingerprint() {
        let dir = tmp("fp_mismatch");
        let plan = SweepPlan::build(&spec()).unwrap();
        drop(RunStore::open(&dir, &plan, "fp-test", false).unwrap());
        let err =
            RunStore::open(&dir, &plan, "fp-regenerated", true).unwrap_err();
        assert!(
            err.to_string().contains("model artifact has changed"),
            "{err:#}"
        );
        // unchanged fingerprint still resumes
        assert!(RunStore::open(&dir, &plan, "fp-test", true).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_drops_missing_and_corrupt_artifacts() {
        let dir = tmp("corrupt");
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        for i in 0..3 {
            st.record(i, &fab(&plan.cells[i], i)).unwrap();
        }
        // corrupt cell 1's artifact, delete cell 2's
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        for n in &names {
            if n.starts_with("00001") {
                let p = dir.join(n);
                let mut b = std::fs::read(&p).unwrap();
                b.push(b'x');
                std::fs::write(&p, &b).unwrap();
            }
            if n.starts_with("00002") {
                std::fs::remove_file(dir.join(n)).unwrap();
            }
        }
        let mut st = RunStore::open(&dir, &plan, "fp-test", true).unwrap();
        assert!(st.take_valid_outcome(0).is_some());
        assert!(
            st.take_valid_outcome(1).is_none(),
            "corrupt artifact must not count"
        );
        assert!(
            st.take_valid_outcome(2).is_none(),
            "missing artifact must not count"
        );
        // invalid entries were dropped; the good one is still recorded
        assert_eq!(st.completed_count(), 1);
        assert!(st.completed(0));
        assert!(!st.completed(1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_summary_reports_progress_and_seconds() {
        let dir = tmp("status");
        let mut sp = spec();
        sp.shard = Some(ShardId { index: 1, count: 2 });
        let plan = SweepPlan::build(&sp).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        let m0 = read_manifest(&dir).unwrap();
        assert_eq!(m0.planned(), 2); // 4 cells, shard 1/2 owns indices 0+2
        assert_eq!((m0.done(), m0.remaining()), (0, 2));
        let pc = plan.owned();
        st.record(pc[0].index, &fab(&pc[0].cell, pc[0].index)).unwrap();
        let m1 = read_manifest(&dir).unwrap();
        assert_eq!((m1.done(), m1.remaining()), (1, 1));
        assert!((m1.exec_seconds() - 0.25).abs() < 1e-12);
        // the compact trace summary rides in the manifest (status needs
        // no artifact reads)
        let e = m1.cells.values().next().unwrap();
        assert_eq!(e.mean_q, Some(0.6875));
        assert_eq!(e.realized_cost, Some(0.5));
        assert_eq!(m1.mean_q(), Some(0.6875));
        assert_eq!(m1.realized_cost(), Some(0.5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifests_without_trace_summaries_fall_back_silently() {
        // a pre-policy manifest has no mean_q/realized_cost keys: reading
        // yields None, aggregates yield None, and a rewrite (gc) does not
        // invent them
        let dir = tmp("no_trace");
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        st.record(0, &fab(&plan.cells[0], 0)).unwrap();
        let mp = dir.join(MANIFEST_FILE);
        let src = std::fs::read_to_string(&mp).unwrap();
        let mut doc = Json::parse(&src).unwrap();
        if let Json::Obj(top) = &mut doc {
            if let Some(Json::Obj(cells)) = top.get_mut("cells") {
                for cell in cells.values_mut() {
                    if let Json::Obj(e) = cell {
                        e.remove("mean_q");
                        e.remove("realized_cost");
                    }
                }
            }
        }
        std::fs::write(&mp, doc.to_string_pretty()).unwrap();
        let m = read_manifest(&dir).unwrap();
        let e = m.cells.values().next().unwrap();
        assert_eq!((e.mean_q, e.realized_cost), (None, None));
        assert_eq!(m.mean_q(), None);
        assert_eq!(m.realized_cost(), None);
        write_manifest_file(&dir, &m).unwrap();
        let back = std::fs::read_to_string(&mp).unwrap();
        assert!(
            !back.contains("mean_q") && !back.contains("realized_cost"),
            "rewrite must not fabricate trace summaries"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_manifest_rejects_cells_outside_the_shard() {
        let dir = tmp("unowned");
        let mut sp = spec();
        sp.shard = Some(ShardId { index: 1, count: 2 });
        let plan = SweepPlan::build(&sp).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        st.record(0, &fab(&plan.cells[0], 0)).unwrap();
        let mp = dir.join(MANIFEST_FILE);
        // move the recorded cell to an index shard 1/2 does not own
        let edited = std::fs::read_to_string(&mp)
            .unwrap()
            .replace("\"00000\"", "\"00001\"");
        std::fs::write(&mp, edited).unwrap();
        let err = read_manifest(&dir).unwrap_err();
        assert!(err.to_string().contains("not owned"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_strips_histories_keeps_scalars_and_is_idempotent() {
        let dir = tmp("gc");
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        for i in 0..2 {
            st.record(i, &fab(&plan.cells[i], i)).unwrap();
        }
        let before: Vec<RunOutcome> =
            (0..2).map(|i| st.load_outcome(i).unwrap()).collect();
        let stats = compact_run_dir(&dir).unwrap();
        assert_eq!((stats.cells, stats.compacted, stats.skipped), (2, 2, 0));
        assert!(stats.bytes_after < stats.bytes_before, "{stats:?}");
        // reopens cleanly: checksums were refreshed along with artifacts
        let st2 = RunStore::open(&dir, &plan, "fp-test", true).unwrap();
        for (i, want) in before.iter().enumerate() {
            let out = st2.load_outcome(i).unwrap();
            assert!(out.history.losses.is_empty(), "history must be gone");
            assert!(out.history.evals.is_empty());
            assert_eq!(out.metric.to_bits(), want.metric.to_bits());
            assert_eq!(out.gbitops.to_bits(), want.gbitops.to_bits());
            assert_eq!(out.exec_seconds.to_bits(), want.exec_seconds.to_bits());
            // the per-cell trace summary survives gc even though the
            // precision history it came from is stripped
            assert_eq!(out.mean_q.to_bits(), want.mean_q.to_bits());
            assert_eq!(
                out.realized_cost.to_bits(),
                want.realized_cost.to_bits()
            );
            assert_eq!(out.history.mean_q.to_bits(), want.history.mean_q.to_bits());
            assert_eq!(
                out.history.gbitops.to_bits(),
                want.history.gbitops.to_bits(),
                "history scalars survive compaction"
            );
        }
        // idempotent: a second pass rewrites nothing
        let stats2 = compact_run_dir(&dir).unwrap();
        assert_eq!(stats2.compacted, 0);
        assert_eq!(stats2.bytes_before, stats2.bytes_after);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_skips_corrupt_artifacts() {
        let dir = tmp("gc_corrupt");
        let plan = SweepPlan::build(&spec()).unwrap();
        let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
        for i in 0..2 {
            st.record(i, &fab(&plan.cells[i], i)).unwrap();
        }
        let victim = dir.join(&read_manifest(&dir).unwrap().cells[&1].file);
        std::fs::write(&victim, b"torn").unwrap();
        let stats = compact_run_dir(&dir).unwrap();
        assert_eq!((stats.compacted, stats.skipped), (1, 1));
        // the corrupt cell is still recorded with its stale checksum, so
        // resume drops it for recomputation as usual
        let mut st2 = RunStore::open(&dir, &plan, "fp-test", true).unwrap();
        assert!(st2.take_valid_outcome(0).is_some());
        assert!(st2.take_valid_outcome(1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_two_shards_restores_canonical_order() {
        let base = tmp("merge_ok");
        let mut dirs = Vec::new();
        for index in 1..=2 {
            let mut sp = spec();
            sp.shard = Some(ShardId { index, count: 2 });
            let plan = SweepPlan::build(&sp).unwrap();
            let dir = base.join(format!("shard{index}"));
            let mut st = RunStore::open(&dir, &plan, "fp-test", false).unwrap();
            for pc in plan.owned() {
                st.record(pc.index, &fab(&pc.cell, pc.index)).unwrap();
            }
            dirs.push(dir);
        }
        let (model, outs) = merge_run_dirs(&dirs).unwrap();
        assert_eq!(model, "mlp");
        let plan = SweepPlan::build(&spec()).unwrap();
        assert_eq!(outs.len(), plan.total_cells());
        for (i, out) in outs.iter().enumerate() {
            assert_outcome_eq(out, &fab(&plan.cells[i], i));
        }
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn merge_rejects_mismatched_spec_hashes() {
        let base = tmp("merge_hash");
        let mut sp1 = spec();
        sp1.shard = Some(ShardId { index: 1, count: 2 });
        let plan1 = SweepPlan::build(&sp1).unwrap();
        let d1 = base.join("a");
        let mut st = RunStore::open(&d1, &plan1, "fp-test", false).unwrap();
        for pc in plan1.owned() {
            st.record(pc.index, &fab(&pc.cell, pc.index)).unwrap();
        }
        let mut sp2 = spec();
        sp2.steps = Some(99); // different spec -> different hash
        sp2.shard = Some(ShardId { index: 2, count: 2 });
        let plan2 = SweepPlan::build(&sp2).unwrap();
        let d2 = base.join("b");
        let mut st2 = RunStore::open(&d2, &plan2, "fp-test", false).unwrap();
        for pc in plan2.owned() {
            st2.record(pc.index, &fab(&pc.cell, pc.index)).unwrap();
        }
        let err = merge_run_dirs(&[d1, d2]).unwrap_err();
        assert!(err.to_string().contains("spec hash"), "{err:#}");
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn merge_rejects_duplicates_and_missing_cells() {
        let base = tmp("merge_dup");
        let mut sp = spec();
        sp.shard = Some(ShardId { index: 1, count: 2 });
        let plan = SweepPlan::build(&sp).unwrap();
        let d1 = base.join("a");
        let mut st = RunStore::open(&d1, &plan, "fp-test", false).unwrap();
        for pc in plan.owned() {
            st.record(pc.index, &fab(&pc.cell, pc.index)).unwrap();
        }
        // same dir twice -> duplicate cells
        let err = merge_run_dirs(&[d1.clone(), d1.clone()]).unwrap_err();
        assert!(err.to_string().contains("duplicate cell"), "{err:#}");
        // only shard 1 of 2 -> missing cells
        let err = merge_run_dirs(&[d1]).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err:#}");
        std::fs::remove_dir_all(&base).ok();
    }
}

//! Result reporting: paper-style tables to stdout + CSV under results/.

use std::path::Path;

use anyhow::Result;

use super::{AggRow, RunOutcome, SweepTiming};
use crate::metrics::CsvWriter;

/// The run-deterministic aggregate columns shared by the stable sweep
/// CSV and the campaign CSV (which prefixes a `sweep` key column).
/// `mean_q` / `realized_cost` are the *realized* trace figures recorded
/// per run — for schedule-driven cells they reproduce the analytic
/// schedule numbers; for adaptive policies they are data-dependent and
/// exist nowhere else.
const STABLE_COLUMNS: [&str; 10] = [
    "model", "schedule", "group", "q_max", "gbitops", "metric_mean",
    "metric_std", "trials", "mean_q", "realized_cost",
];

/// Values for [`STABLE_COLUMNS`] — one formatting path, so sweep and
/// campaign CSVs can never drift apart.
fn stable_fields(r: &AggRow) -> Vec<String> {
    vec![
        r.model.clone(),
        r.schedule.clone(),
        r.group.clone(),
        format!("{}", r.q_max),
        format!("{:.6}", r.gbitops),
        format!("{:.6}", r.metric_mean),
        format!("{:.6}", r.metric_std),
        format!("{}", r.trials),
        format!("{:.6}", r.mean_q),
        format!("{:.6}", r.realized_cost),
    ]
}

/// Pretty-printer + CSV emitter for a sweep.
pub struct SweepReport<'a> {
    pub title: &'a str,
    pub metric_name: &'a str,
    pub higher_is_better: bool,
}

impl<'a> SweepReport<'a> {
    pub fn new(title: &'a str, metric_name: &'a str, higher_is_better: bool) -> Self {
        SweepReport { title, metric_name, higher_is_better }
    }

    /// Print the aggregated table, grouped by q_max, sorted by GBitOps
    /// (the x-axis of the paper's scatter figures).
    pub fn print(&self, rows: &[AggRow]) {
        println!("\n=== {} ===", self.title);
        let mut q_maxes: Vec<f64> = rows.iter().map(|r| r.q_max).collect();
        q_maxes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        q_maxes.dedup();
        for q in q_maxes {
            println!("\n-- q_max = {q} --");
            println!(
                "{:<10} {:<10} {:>12} {:>18}",
                "schedule", "group", "GBitOps", self.metric_name
            );
            let mut sub: Vec<&AggRow> =
                rows.iter().filter(|r| r.q_max == q).collect();
            sub.sort_by(|a, b| a.gbitops.partial_cmp(&b.gbitops).unwrap());
            for r in sub {
                println!(
                    "{:<10} {:<10} {:>12.4} {:>12.4} ± {:.4}",
                    r.schedule, r.group, r.gbitops, r.metric_mean, r.metric_std
                );
            }
        }
        // headline: best schedule vs static baseline
        if let Some(best) = self.best_row(rows) {
            if let Some(stat) = rows
                .iter()
                .filter(|r| r.schedule == "STATIC")
                .max_by(|a, b| a.q_max.partial_cmp(&b.q_max).unwrap())
            {
                let save = 100.0 * (1.0 - best.gbitops / stat.gbitops);
                println!(
                    "\nbest CPT: {} (q_max={}) {}={:.4} at {:.1}% less compute than STATIC ({:.4})",
                    best.schedule, best.q_max, self.metric_name,
                    best.metric_mean, save, stat.metric_mean
                );
            }
        }
    }

    fn best_row<'r>(&self, rows: &'r [AggRow]) -> Option<&'r AggRow> {
        rows.iter()
            .filter(|r| r.schedule != "STATIC" && r.schedule != "NONE")
            .max_by(|a, b| {
                let (x, y) = if self.higher_is_better {
                    (a.metric_mean, b.metric_mean)
                } else {
                    (-a.metric_mean, -b.metric_mean)
                };
                x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal)
            })
    }

    /// Write aggregated rows as CSV (no sweep timing columns).
    pub fn write_csv(&self, rows: &[AggRow], path: impl AsRef<Path>) -> Result<()> {
        self.csv(rows, None, true).write_to(path)
    }

    /// Write aggregated rows as CSV including sweep wall-clock and job
    /// count, so serial-vs-parallel speedup is visible in results/
    /// without re-instrumenting.
    pub fn write_csv_with_timing(
        &self,
        rows: &[AggRow],
        timing: SweepTiming,
        path: impl AsRef<Path>,
    ) -> Result<()> {
        self.csv(rows, Some(timing), true).write_to(path)
    }

    /// Write only the run-deterministic aggregate columns — everything
    /// except wall-clock-derived fields. Two executions of the same spec
    /// (serial, parallel, or sharded + merged) produce byte-identical
    /// output, which is what `cpt merge` emits and what the shard/merge
    /// equivalence test compares.
    pub fn write_csv_stable(
        &self,
        rows: &[AggRow],
        path: impl AsRef<Path>,
    ) -> Result<()> {
        self.csv(rows, None, false).write_to(path)
    }

    fn csv(
        &self,
        rows: &[AggRow],
        timing: Option<SweepTiming>,
        exec_cols: bool,
    ) -> CsvWriter {
        let mut header = STABLE_COLUMNS.to_vec();
        if exec_cols {
            header.push("exec_seconds_mean");
        }
        if timing.is_some() {
            header.extend(["sweep_wall_seconds", "sweep_jobs"]);
        }
        let mut w = CsvWriter::new(&header);
        for r in rows {
            let mut fields = stable_fields(r);
            if exec_cols {
                fields.push(format!("{:.4}", r.exec_seconds_mean));
            }
            if let Some(t) = timing {
                fields.push(format!("{:.4}", t.wall_seconds));
                fields.push(format!("{}", t.jobs));
            }
            w.row(&fields);
        }
        w
    }

    /// Write a campaign-level CSV: every member sweep's stable aggregate
    /// rows keyed by a leading `sweep` column, in campaign member order.
    /// Formatting is identical to [`Self::write_csv_stable`], so any
    /// member's slice of the campaign CSV is byte-identical (minus the
    /// key column) to the CSV an independent run of that sweep writes.
    pub fn write_campaign_csv(
        members: &[(String, Vec<AggRow>)],
        path: impl AsRef<Path>,
    ) -> Result<()> {
        let mut header = vec!["sweep"];
        header.extend(STABLE_COLUMNS);
        let mut w = CsvWriter::new(&header);
        for (name, rows) in members {
            for r in rows {
                let mut fields = vec![name.clone()];
                fields.extend(stable_fields(r));
                w.row(&fields);
            }
        }
        w.write_to(path)
    }

    /// Write per-run loss curves (for the e2e example / Fig 5 style
    /// validation curves).
    pub fn write_curves_csv(
        &self,
        outs: &[RunOutcome],
        path: impl AsRef<Path>,
    ) -> Result<()> {
        let mut w = CsvWriter::new(&[
            "model", "schedule", "q_max", "trial", "step", "train_loss",
            "q_t",
        ]);
        for o in outs {
            for (i, &(step, loss)) in o.history.losses.iter().enumerate() {
                let q = o
                    .history
                    .precisions
                    .get(i)
                    .map(|&(_, q)| q)
                    .unwrap_or(0);
                w.row(&[
                    o.model.clone(),
                    o.schedule.clone(),
                    format!("{}", o.q_max),
                    format!("{}", o.trial),
                    format!("{step}"),
                    format!("{loss:.6}"),
                    format!("{q}"),
                ]);
            }
        }
        w.write_to(path)
    }
}

/// Write the canonical campaign CSV tree under `dir`: one stable
/// per-member CSV (`<member>.csv`) plus the keyed `campaign.csv`. This
/// is THE path for campaign results — `cpt campaign` reports through it
/// and `cpt serve` caches its output, so a fetched serve result is
/// byte-identical to a direct run of the same spec. Returns the
/// aggregated rows keyed by member, for printing.
pub fn write_campaign_csv_tree<'m>(
    dir: &Path,
    members: impl IntoIterator<Item = (&'m str, &'m [RunOutcome])>,
) -> Result<Vec<(String, Vec<AggRow>)>> {
    let mut keyed: Vec<(String, Vec<AggRow>)> = Vec::new();
    for (name, outs) in members {
        let rows = super::aggregate(outs);
        SweepReport::new(name, "metric", true)
            .write_csv_stable(&rows, dir.join(format!("{name}.csv")))?;
        keyed.push((name.to_string(), rows));
    }
    SweepReport::write_campaign_csv(&keyed, dir.join("campaign.csv"))?;
    Ok(keyed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::History;

    fn row(s: &str, q: f64, g: f64, m: f64) -> AggRow {
        AggRow {
            model: "m".into(),
            schedule: s.into(),
            group: "-".into(),
            q_max: q,
            gbitops: g,
            metric_mean: m,
            metric_std: 0.0,
            trials: 1,
            mean_q: 0.75,
            realized_cost: 0.5,
            exec_seconds_mean: 0.25,
        }
    }

    #[test]
    fn csv_emission() {
        let rows = vec![row("CR", 8.0, 1.0, 0.9), row("STATIC", 8.0, 2.0, 0.88)];
        let rep = SweepReport::new("t", "acc", true);
        let dir = std::env::temp_dir().join("cpt_report_test");
        let p = dir.join("a.csv");
        rep.write_csv(&rows, &p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("model,schedule,group"));
        assert!(s.lines().next().unwrap().ends_with("exec_seconds_mean"));
        assert_eq!(s.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_with_timing_adds_sweep_columns() {
        let rows = vec![row("CR", 8.0, 1.0, 0.9)];
        let rep = SweepReport::new("t", "acc", true);
        let timing = SweepTiming {
            wall_seconds: 12.5,
            jobs: 4,
            cells: 22,
            resumed: 0,
        };
        let dir = std::env::temp_dir().join("cpt_report_test_timing");
        let p = dir.join("b.csv");
        rep.write_csv_with_timing(&rows, timing, &p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let header = s.lines().next().unwrap();
        assert!(header.ends_with("sweep_wall_seconds,sweep_jobs"), "{header}");
        assert!(s.lines().nth(1).unwrap().ends_with("12.5000,4"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stable_csv_omits_wall_clock_columns() {
        let rows = vec![row("CR", 8.0, 1.0, 0.9)];
        let rep = SweepReport::new("t", "acc", true);
        let dir = std::env::temp_dir().join("cpt_report_test_stable");
        let p = dir.join("c.csv");
        rep.write_csv_stable(&rows, &p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let header = s.lines().next().unwrap();
        assert_eq!(
            header,
            "model,schedule,group,q_max,gbitops,metric_mean,metric_std,\
             trials,mean_q,realized_cost"
        );
        assert!(!s.contains("exec_seconds"), "{s}");
        // the realized columns carry the row's trace figures
        assert!(
            s.lines().nth(1).unwrap().ends_with("0.750000,0.500000"),
            "{s}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_csv_keys_rows_by_sweep_and_matches_stable_format() {
        let dir = std::env::temp_dir().join("cpt_report_test_campaign");
        std::fs::remove_dir_all(&dir).ok();
        let a_rows = vec![row("CR", 8.0, 1.0, 0.9), row("STATIC", 8.0, 2.0, 0.88)];
        let b_rows = vec![row("RR", 6.0, 2.0, 0.8)];
        let members = vec![
            ("a".to_string(), a_rows.clone()),
            ("b".to_string(), b_rows),
        ];
        let p = dir.join("campaign.csv");
        SweepReport::write_campaign_csv(&members, &p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let mut lines = s.lines();
        assert_eq!(
            lines.next().unwrap(),
            "sweep,model,schedule,group,q_max,gbitops,metric_mean,\
             metric_std,trials,mean_q,realized_cost"
        );
        // stripping the sweep key must reproduce the member's stable CSV
        let ps = dir.join("a.csv");
        SweepReport::new("a", "acc", true)
            .write_csv_stable(&a_rows, &ps)
            .unwrap();
        let stable = std::fs::read_to_string(&ps).unwrap();
        let mut stable_lines = stable.lines().skip(1);
        for _ in 0..2 {
            let c = lines.next().unwrap();
            let (key, rest) = c.split_once(',').unwrap();
            assert_eq!(key, "a");
            assert_eq!(rest, stable_lines.next().unwrap());
        }
        assert!(lines.next().unwrap().starts_with("b,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn print_does_not_panic() {
        let rows = vec![row("CR", 8.0, 1.0, 0.9), row("STATIC", 8.0, 2.0, 0.88)];
        SweepReport::new("t", "acc", true).print(&rows);
        let _o = RunOutcome {
            model: "m".into(),
            schedule: "CR".into(),
            group: "-".into(),
            q_max: 8.0,
            trial: 0,
            gbitops: 1.0,
            metric: 0.9,
            eval_loss: 0.1,
            steps: 10,
            mean_q: 0.75,
            realized_cost: 0.5,
            exec_seconds: 0.0,
            history: History::default(),
        };
    }
}

//! Persistent shared worker pool: one long-lived set of workers
//! multiplexing cells from many concurrent jobs.
//!
//! [`super::exec::run_items`] builds its pool per call and tears it down
//! when the call returns — fine for one-shot sweeps, but a `cpt serve`
//! daemon that runs jobs back to back through it recompiles every model
//! for every job. [`WorkerPool`] inverts the ownership: workers (each a
//! [`CellRunner`] — in production one PJRT client plus its compiled-
//! executable LRU) outlive any single job, and jobs *attach* to the pool
//! via [`WorkerPool::run_job`], which blocks as that job's collector
//! until the job's cells settle. Consequences:
//!
//! * **Cross-job warm compiles** — a worker's executable cache persists
//!   across jobs, so a second job sharing a model fingerprint with an
//!   earlier one costs zero recompiles (the cross-process warm start the
//!   AOT store cannot deliver while the vendored backend refuses to
//!   serialize, delivered cross-job in-process instead).
//! * **Fair-share claiming** — when several jobs have runnable cells, an
//!   idle worker claims from the attached job with the fewest in-flight
//!   cells (ties broken by attach order), so a 4-cell job submitted
//!   behind a 400-cell one finishes in seconds instead of queueing
//!   behind it. Within the chosen job claiming stays model-affine
//!   (prefer a cell whose model the worker already holds compiled), and
//!   per-member `jobs = N` caps are honored exactly as in `run_items`.
//! * **Determinism** — scheduling only moves wall clock. Every cell is
//!   an independently seeded run routed to its job's position-addressed
//!   slot, and each job's sink writes happen on that job's own collector
//!   thread (the `run_job` caller), serialized per store — so per-job
//!   results stay byte-identical to a direct `cpt campaign` run.
//! * **Graceful drain** — [`WorkerPool::shutdown`] lets in-flight cells
//!   finish and refuses new claims; a job with unstarted cells gets an
//!   error downcasting to [`Drained`] so the daemon can demote it to
//!   `queued` (its recorded cells stay durable and resume later).
//!
//! Failure semantics mirror `run_items`: a failed cell stops its own job
//! (and only it); a worker that cannot compile a model retries with
//! backoff, then skips that model for good — a job whose remaining cells
//! no live worker can compile stops with the compile error instead of
//! hanging.

use std::collections::{HashMap, HashSet};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use super::exec::{
    self, CellError, CellRunner, CellSink, ExecItem, ExecMember, ExecStats,
    Recorded, WorkerStats,
};
use super::RunOutcome;
use crate::obs::trace::{self, Event};
use crate::obs::metrics;

/// Builds one worker's backend on its own pool thread (a runner never
/// crosses threads). Shared by every worker, so `Send + Sync`.
pub type WorkerFactory =
    dyn Fn(usize) -> Result<Box<dyn CellRunner>> + Send + Sync;

/// Sentinel error cause: the pool shut down while this job still had
/// unstarted cells. Callers downcast (`err.downcast_ref::<Drained>()`)
/// to tell "drained for resume" from a real failure.
#[derive(Debug)]
pub struct Drained;

impl std::fmt::Display for Drained {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("worker pool drained before the job completed")
    }
}

impl std::error::Error for Drained {}

/// One job attached to the pool: members, flattened items, and knobs —
/// the long-lived analogue of `exec::ExecRequest`.
pub struct PoolRequest {
    /// Log prefix, e.g. `campaign fig367` or `job 00ab34cd`.
    pub label: String,
    pub members: Vec<ExecMember>,
    pub items: Vec<ExecItem>,
    pub verbose: bool,
    /// Deterministic kill for tests: stop this job after this many
    /// freshly recorded cells. `None` defers to the process-wide
    /// CPT_HALT_AFTER_CELLS counter.
    pub halt_after_cells: Option<usize>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ItemState {
    Pending,
    InFlight,
    Done,
}

enum FinishReason {
    /// Every item settled.
    Complete,
    /// Pool shutdown drained the job with unstarted cells remaining.
    Drained,
    /// The job was stopped early (cell failure, unclaimable models,
    /// crash-injection halt, worker panic) — the message says why.
    Stopped(String),
}

enum JobMsg {
    Done {
        item: usize,
        out: Box<RunOutcome>,
        /// Per-cell deltas of the running worker's compile/cache
        /// counters — how per-job stats are carved out of shared
        /// workers.
        stats: WorkerStats,
    },
    RunErr {
        item: usize,
        err: anyhow::Error,
    },
    SetupErr {
        model: String,
        err: anyhow::Error,
    },
    Retried {
        worker: usize,
    },
    /// Always the job's final message (sent under the state lock, after
    /// any Done/RunErr for the same transition).
    Finished {
        reason: FinishReason,
    },
}

struct JobEntry {
    members: Vec<ExecMember>,
    items: Vec<ExecItem>,
    state: Vec<ItemState>,
    /// In-flight cells per member (bounded by the member's cap).
    inflight_member: Vec<usize>,
    inflight_total: usize,
    pending: usize,
    done: usize,
    /// No further claims for this job (it failed or was halted).
    stopped: bool,
    /// Why it stopped (first stop wins).
    fail: Option<String>,
    finished_sent: bool,
    tx: mpsc::Sender<JobMsg>,
}

struct PoolState {
    jobs: HashMap<u64, JobEntry>,
    /// Attach order — the fair-share tiebreak.
    order: Vec<u64>,
    next_id: u64,
    shutdown: bool,
    /// Workers still running their claim loop (ids removed on exit, even
    /// by panic, via `WorkerGuard`).
    alive: HashSet<usize>,
    /// Per-fingerprint set of workers that permanently failed to compile
    /// it; once that covers every live worker the fingerprint's cells
    /// are unclaimable and jobs needing them stop.
    fp_failed: HashMap<String, HashSet<usize>>,
    last_init_err: Option<String>,
}

struct Shared {
    state: Mutex<PoolState>,
    work: Condvar,
}

/// The pool itself. Create once, share behind an `Arc`, attach jobs from
/// any number of threads via [`WorkerPool::run_job`].
pub struct WorkerPool {
    shared: Arc<Shared>,
    size: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// If any live worker could still compile this fingerprint, its cells
/// remain claimable.
fn live_can_claim(st: &PoolState, fp: &str) -> bool {
    if st.alive.is_empty() {
        return false;
    }
    match st.fp_failed.get(fp) {
        Some(failed) => st.alive.iter().any(|w| !failed.contains(w)),
        None => true,
    }
}

/// Send the job's `Finished` message once nothing is in flight and
/// nothing more will run — called after every settle, on shutdown, and
/// whenever the claimable-set shrinks (worker exit, fingerprint failure).
fn maybe_finish(st: &mut PoolState, jid: u64) {
    let reason = {
        let Some(job) = st.jobs.get(&jid) else { return };
        if job.finished_sent || job.inflight_total > 0 {
            return;
        }
        if job.done == job.items.len() {
            FinishReason::Complete
        } else if job.stopped {
            FinishReason::Stopped(
                job.fail.clone().unwrap_or_else(|| "job stopped".to_string()),
            )
        } else if st.shutdown {
            FinishReason::Drained
        } else {
            let claimable = job.state.iter().enumerate().any(|(i, s)| {
                *s == ItemState::Pending
                    && live_can_claim(
                        st,
                        &job.members[job.items[i].member].fingerprint,
                    )
            });
            if claimable {
                return; // workers will get to it
            }
            FinishReason::Stopped(format!(
                "{} of {} cells unclaimed (no live worker could compile \
                 their model)",
                job.pending,
                job.items.len()
            ))
        }
    };
    let job = st.jobs.get_mut(&jid).unwrap();
    job.finished_sent = true;
    let _ = job.tx.send(JobMsg::Finished { reason });
}

fn maybe_finish_all(st: &mut PoolState) {
    for jid in st.order.clone() {
        maybe_finish(st, jid);
    }
}

/// Removes an exiting worker from the live set — even when the thread
/// unwinds — and re-checks every job, since a smaller live set can
/// strand pending cells.
struct WorkerGuard<'a> {
    shared: &'a Shared,
    worker: usize,
}

impl Drop for WorkerGuard<'_> {
    fn drop(&mut self) {
        if let Ok(mut st) = self.shared.state.lock() {
            st.alive.remove(&self.worker);
            if !st.shutdown {
                maybe_finish_all(&mut st);
            }
        }
        self.shared.work.notify_all();
    }
}

/// Unwinding guard for one claimed cell: a panic inside `run_cell`
/// settles the claim and stops the job (reported as a cell failure), so
/// the job's collector unblocks instead of waiting forever.
struct CellGuard<'a> {
    shared: &'a Shared,
    job: u64,
    item: usize,
    member: usize,
    armed: bool,
}

impl Drop for CellGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        if let Ok(mut st) = self.shared.state.lock() {
            if let Some(job) = st.jobs.get_mut(&self.job) {
                job.state[self.item] = ItemState::Done;
                job.inflight_member[self.member] -= 1;
                job.inflight_total -= 1;
                job.done += 1;
                if !job.stopped {
                    job.stopped = true;
                    job.fail = Some("a worker panicked mid-cell".to_string());
                }
                let _ = job.tx.send(JobMsg::RunErr {
                    item: self.item,
                    err: anyhow!("worker panicked while running this cell"),
                });
            }
            maybe_finish(&mut st, self.job);
        }
        self.shared.work.notify_all();
    }
}

fn worker_main(shared: &Shared, w: usize, make: &WorkerFactory, label: &str) {
    let _guard = WorkerGuard { shared, worker: w };
    // Bounded init retries with backoff, like run_items workers; a
    // worker that never initializes leaves the live set via the guard.
    let mut init_attempt = 1usize;
    let mut runner = loop {
        match make(w) {
            Ok(r) => break r,
            Err(e) if init_attempt < exec::SETUP_ATTEMPTS => {
                crate::log_warn!(
                    "[{label}] note: pool worker {w} setup failed (attempt \
                     {init_attempt}/{}): {e:#}; retrying",
                    exec::SETUP_ATTEMPTS
                );
                std::thread::sleep(exec::setup_backoff(init_attempt));
                init_attempt += 1;
            }
            Err(e) => {
                crate::log_warn!(
                    "[{label}] note: pool worker {w} failed to initialize: \
                     {e:#}"
                );
                if let Ok(mut st) = shared.state.lock() {
                    st.last_init_err = Some(format!("{e:#}"));
                }
                return;
            }
        }
    };
    // Worker-local transient-setup attempt counts per fingerprint.
    let mut attempts: HashMap<String, usize> = HashMap::new();
    // Which job caused this worker's compile of each fingerprint — a
    // later cache hit under a *different* job is a cross-job warm hit
    // (the thing the pool exists to deliver; counted in the global
    // metrics registry and surfaced by `cpt stats`).
    let mut compiled_by_job: HashMap<String, u64> = HashMap::new();
    loop {
        // Claim under the lock: fair-share across jobs (least in-flight
        // wins, attach order ties), model-affine within the job.
        let claim_t0 = std::time::Instant::now();
        let claimed = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    break None;
                }
                let mut best: Option<(u64, usize, usize)> = None;
                for &jid in &st.order {
                    let Some(job) = st.jobs.get(&jid) else { continue };
                    if job.stopped || job.finished_sent {
                        continue;
                    }
                    let mut cand: Option<usize> = None;
                    for (i, s) in job.state.iter().enumerate() {
                        if *s != ItemState::Pending {
                            continue;
                        }
                        let it = &job.items[i];
                        let m = &job.members[it.member];
                        if st
                            .fp_failed
                            .get(&m.fingerprint)
                            .map_or(false, |f| f.contains(&w))
                        {
                            continue;
                        }
                        if job.inflight_member[it.member] >= m.cap.max(1) {
                            continue;
                        }
                        if runner.has_cached(&m.fingerprint) {
                            cand = Some(i);
                            break;
                        }
                        if cand.is_none() {
                            cand = Some(i);
                        }
                    }
                    if let Some(i) = cand {
                        let load = job.inflight_total;
                        if best.map_or(true, |(_, _, bl)| load < bl) {
                            best = Some((jid, i, load));
                        }
                    }
                }
                match best {
                    Some((jid, i, _)) => {
                        let job = st.jobs.get_mut(&jid).unwrap();
                        let mi = job.items[i].member;
                        job.state[i] = ItemState::InFlight;
                        job.inflight_member[mi] += 1;
                        job.inflight_total += 1;
                        job.pending -= 1;
                        let it = job.items[i].clone();
                        let m = job.members[mi].clone();
                        break Some((jid, i, it, m));
                    }
                    None => {
                        st = shared.work.wait(st).unwrap();
                    }
                }
            }
        };
        let Some((jid, i, it, m)) = claimed else { break };
        if trace::enabled() {
            trace::set_cell_ctx(w, it.member, it.cell_index);
            let wait = claim_t0.elapsed().as_secs_f64();
            trace::emit(
                Event::new(trace::now() - wait, "claim")
                    .dur(wait)
                    .tag_num("job", jid as f64),
            );
        }
        metrics::global().inc("pool.claims", 1);
        let (bc, bsec) = runner.compile_stats();
        let bcache = runner.cache_stats();
        let cell_t0 = std::time::Instant::now();
        let mut guard = CellGuard {
            shared,
            job: jid,
            item: i,
            member: it.member,
            armed: true,
        };
        let res = runner.run_cell(&m, &it.cell, it.cell_index, false);
        guard.armed = false;
        match res {
            Ok(out) => {
                let (ac, asec) = runner.compile_stats();
                let acache = runner.cache_stats();
                let stats = WorkerStats {
                    worker: w,
                    compiles: ac - bc,
                    compile_seconds: asec - bsec,
                    cells: 1,
                    retries: 0,
                    hits: acache.hits - bcache.hits,
                    disk_hits: acache.disk_hits - bcache.disk_hits,
                    misses: acache.misses - bcache.misses,
                };
                if stats.compiles > 0 {
                    compiled_by_job.insert(m.fingerprint.clone(), jid);
                }
                let cross_job_warm = stats.compiles == 0
                    && stats.hits > 0
                    && compiled_by_job
                        .get(&m.fingerprint)
                        .map_or(true, |&j| j != jid);
                if cross_job_warm {
                    metrics::global().inc("pool.cross_job_warm_hits", 1);
                    crate::log_debug!(
                        "[{label}] pool worker {w} warm-hit model '{}' for \
                         job {jid} (compiled under an earlier job)",
                        m.model
                    );
                }
                if trace::enabled() {
                    let wall = cell_t0.elapsed().as_secs_f64();
                    let dsec =
                        stats.compile_seconds.max(0.0).min(wall);
                    let now = trace::now();
                    let outcome = if stats.hits > 0 {
                        if cross_job_warm { "cross_job_hit" } else { "hit" }
                    } else if stats.disk_hits > 0 {
                        "disk_hit"
                    } else if stats.misses > 0 {
                        "miss"
                    } else {
                        ""
                    };
                    if stats.compiles > 0 {
                        trace::emit(
                            Event::new(now - wall, "compile")
                                .dur(dsec)
                                .tag_str("fp", &m.fingerprint)
                                .tag_str("outcome", outcome)
                                .tag_num("job", jid as f64),
                        );
                    }
                    trace::emit(
                        Event::new(now - wall + dsec, "exec")
                            .dur(wall - dsec)
                            .tag_str("name", &m.name)
                            .tag_str("model", &m.model)
                            .tag_str("fp", &m.fingerprint)
                            .tag_str("outcome", outcome)
                            .tag_num("job", jid as f64),
                    );
                    trace::flush();
                    trace::clear_cell_ctx();
                }
                let mut st = shared.state.lock().unwrap();
                if let Some(job) = st.jobs.get_mut(&jid) {
                    job.state[i] = ItemState::Done;
                    job.inflight_member[it.member] -= 1;
                    job.inflight_total -= 1;
                    job.done += 1;
                    let _ = job.tx.send(JobMsg::Done {
                        item: i,
                        out: Box::new(out),
                        stats,
                    });
                }
                maybe_finish(&mut st, jid);
                drop(st);
                shared.work.notify_all();
            }
            Err(CellError::Setup(err)) => {
                if trace::enabled() {
                    trace::flush();
                    trace::clear_cell_ctx();
                }
                let n = {
                    let e = attempts.entry(m.fingerprint.clone()).or_insert(0);
                    *e += 1;
                    *e
                };
                let give_up = n >= exec::SETUP_ATTEMPTS;
                let err_msg = format!("{err:#}");
                {
                    let mut st = shared.state.lock().unwrap();
                    if let Some(job) = st.jobs.get_mut(&jid) {
                        // hand the cell back so another worker (or this
                        // one after backoff) can take it
                        job.state[i] = ItemState::Pending;
                        job.inflight_member[it.member] -= 1;
                        job.inflight_total -= 1;
                        job.pending += 1;
                    }
                    if give_up {
                        st.fp_failed
                            .entry(m.fingerprint.clone())
                            .or_default()
                            .insert(w);
                        if let Some(job) = st.jobs.get_mut(&jid) {
                            let _ = job.tx.send(JobMsg::SetupErr {
                                model: m.model.clone(),
                                err,
                            });
                        }
                        // the claimable set shrank — some job's pending
                        // cells may now be unclaimable by anyone
                        maybe_finish_all(&mut st);
                    } else if let Some(job) = st.jobs.get_mut(&jid) {
                        let _ = job.tx.send(JobMsg::Retried { worker: w });
                    }
                }
                shared.work.notify_all();
                if !give_up {
                    crate::log_warn!(
                        "[{label}] note: pool worker {w} setup for model \
                         '{}' failed (attempt {n}/{}): {err_msg}; retrying",
                        m.model,
                        exec::SETUP_ATTEMPTS
                    );
                    std::thread::sleep(exec::setup_backoff(n));
                }
            }
            Err(CellError::Run(err)) => {
                if trace::enabled() {
                    trace::flush();
                    trace::clear_cell_ctx();
                }
                let mut st = shared.state.lock().unwrap();
                if let Some(job) = st.jobs.get_mut(&jid) {
                    job.state[i] = ItemState::Done;
                    job.inflight_member[it.member] -= 1;
                    job.inflight_total -= 1;
                    job.done += 1;
                    if !job.stopped {
                        job.stopped = true;
                        job.fail = Some("a cell failed".to_string());
                    }
                    let _ = job.tx.send(JobMsg::RunErr { item: i, err });
                }
                maybe_finish(&mut st, jid);
                drop(st);
                shared.work.notify_all();
            }
        }
    }
}

impl WorkerPool {
    /// Spawn `size` workers (each building its backend via `make` on its
    /// own thread) and return the pool ready for [`WorkerPool::run_job`].
    pub fn new(size: usize, label: &str, make: Arc<WorkerFactory>) -> WorkerPool {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                jobs: HashMap::new(),
                order: Vec::new(),
                next_id: 0,
                shutdown: false,
                alive: (0..size).collect(),
                fp_failed: HashMap::new(),
                last_init_err: None,
            }),
            work: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(size);
        for w in 0..size {
            let shared = shared.clone();
            let make = make.clone();
            let label = label.to_string();
            handles.push(std::thread::spawn(move || {
                worker_main(&shared, w, &*make, &label)
            }));
        }
        WorkerPool { shared, size, handles: Mutex::new(handles) }
    }

    /// Worker count the pool was built with.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Attach a job and run it to completion: this call is the job's
    /// collector — the only thread that touches this job's `sinks` and
    /// `slots` — and blocks until every item settles (or the job stops).
    /// Per-worker stats in the returned [`ExecStats`] are this job's
    /// share of the pool's work, not pool lifetime totals.
    ///
    /// Error precedence mirrors `run_items`: a failed cell (lowest item
    /// index), a sink write failure, a crash-injection halt, unclaimable
    /// cells (with the compile error), and finally [`Drained`] when a
    /// shutdown interrupted the job.
    pub fn run_job(
        &self,
        req: &PoolRequest,
        sinks: &mut [Option<&mut dyn CellSink>],
        slots: &mut [Vec<Option<RunOutcome>>],
    ) -> Result<ExecStats> {
        assert_eq!(req.members.len(), sinks.len());
        assert_eq!(req.members.len(), slots.len());
        if req.items.is_empty() {
            return Ok(ExecStats {
                jobs: self.size,
                workers: Vec::new(),
                refused: 0,
            });
        }
        let (tx, rx) = mpsc::channel::<JobMsg>();
        let jid = {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown {
                return Err(anyhow::Error::new(Drained).context(format!(
                    "{}: pool is shutting down",
                    req.label
                )));
            }
            if st.alive.is_empty() {
                let why = st
                    .last_init_err
                    .clone()
                    .unwrap_or_else(|| "all pool workers exited".to_string());
                bail!("{}: no live pool workers ({why})", req.label);
            }
            let jid = st.next_id;
            st.next_id += 1;
            let n = req.items.len();
            st.jobs.insert(
                jid,
                JobEntry {
                    members: req.members.clone(),
                    items: req.items.clone(),
                    state: vec![ItemState::Pending; n],
                    inflight_member: vec![0; req.members.len()],
                    inflight_total: 0,
                    pending: n,
                    done: 0,
                    stopped: false,
                    fail: None,
                    finished_sent: false,
                    tx,
                },
            );
            st.order.push(jid);
            // every item may already be unclaimable (all workers failed
            // this model earlier) — fail now rather than hang
            maybe_finish(&mut st, jid);
            jid
        };
        self.shared.work.notify_all();

        let mut first_err: Option<(usize, anyhow::Error)> = None;
        let mut setup_errs: Vec<(String, anyhow::Error)> = Vec::new();
        let mut store_err: Option<anyhow::Error> = None;
        let mut halt_err: Option<anyhow::Error> = None;
        let mut workers: HashMap<usize, WorkerStats> = HashMap::new();
        let mut fresh = 0usize;
        let mut refused = 0usize;
        let blank = |w: usize| WorkerStats {
            worker: w,
            compiles: 0,
            compile_seconds: 0.0,
            cells: 0,
            retries: 0,
            hits: 0,
            disk_hits: 0,
            misses: 0,
        };
        let reason = loop {
            let Ok(msg) = rx.recv() else {
                // unreachable while the job is registered (its entry owns
                // a sender); treated as a stop for safety
                break FinishReason::Stopped(
                    "pool disconnected".to_string(),
                );
            };
            match msg {
                JobMsg::Done { item, out, stats } => {
                    let it = &req.items[item];
                    let m = &req.members[it.member];
                    let ws =
                        workers.entry(stats.worker).or_insert_with(|| {
                            blank(stats.worker)
                        });
                    ws.compiles += stats.compiles;
                    ws.compile_seconds += stats.compile_seconds;
                    ws.cells += 1;
                    ws.hits += stats.hits;
                    ws.disk_hits += stats.disk_hits;
                    ws.misses += stats.misses;
                    if req.verbose {
                        let who = if m.name.is_empty() {
                            m.model.clone()
                        } else {
                            format!("{}:{}", m.name, m.model)
                        };
                        crate::log_info!(
                            "[{} pool] {who} {} qmax={} trial={} -> \
                             metric={:.4} ({:.3} GBitOps)",
                            req.label,
                            out.schedule,
                            out.q_max,
                            out.trial,
                            out.metric,
                            out.gbitops
                        );
                    }
                    if store_err.is_none() && halt_err.is_none() {
                        let mut stored = true;
                        if let Some(sk) = sinks[it.member].as_mut() {
                            let rec_t0 = std::time::Instant::now();
                            let rec = sk.record_cell(it.cell_index, &out);
                            if trace::enabled() {
                                let d = rec_t0.elapsed().as_secs_f64();
                                trace::emit(
                                    Event::new(trace::now() - d, "record")
                                        .dur(d)
                                        .worker(stats.worker)
                                        .member(it.member)
                                        .cell(it.cell_index),
                                );
                                trace::flush();
                            }
                            match rec {
                                Ok(Recorded::Stored) => {}
                                Ok(Recorded::Refused(reason)) => {
                                    stored = false;
                                    refused += 1;
                                    if req.verbose {
                                        crate::log_info!(
                                            "[{}] note: cell {} not \
                                             recorded here: {reason}",
                                            req.label, it.cell_index
                                        );
                                    }
                                }
                                Err(e) => {
                                    stored = false;
                                    store_err = Some(e);
                                    self.stop_job(
                                        jid,
                                        "persisting a cell failed",
                                    );
                                }
                            }
                        }
                        if store_err.is_none() && stored {
                            fresh += 1;
                            let halted = match req.halt_after_cells {
                                Some(n) => {
                                    if n > 0 && fresh >= n {
                                        Some(anyhow!(
                                            "halted after {fresh} freshly \
                                             computed cell(s) \
                                             (halt_after_cells={n} crash \
                                             injection)"
                                        ))
                                    } else {
                                        None
                                    }
                                }
                                None => super::crash_injection_point().err(),
                            };
                            if let Some(e) = halted {
                                halt_err = Some(e);
                                self.stop_job(jid, "halted by crash injection");
                            }
                        }
                    }
                    slots[it.member][it.slot] = Some(*out);
                }
                JobMsg::RunErr { item, err } => {
                    if first_err.as_ref().map_or(true, |(i, _)| item < *i) {
                        first_err = Some((item, err));
                    }
                }
                JobMsg::SetupErr { model, err } => {
                    setup_errs.push((model, err));
                }
                JobMsg::Retried { worker } => {
                    workers
                        .entry(worker)
                        .or_insert_with(|| blank(worker))
                        .retries += 1;
                }
                JobMsg::Finished { reason } => break reason,
            }
        };
        // Detach: nothing is in flight for this job once Finished
        // arrives, so removal can't strand a worker.
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.remove(&jid);
            st.order.retain(|&j| j != jid);
        }
        self.shared.work.notify_all();

        let mut worker_stats: Vec<WorkerStats> =
            workers.into_values().collect();
        worker_stats.sort_by_key(|s| s.worker);
        let done = req
            .items
            .iter()
            .filter(|it| slots[it.member][it.slot].is_some())
            .count();
        if let Some((i, e)) = first_err {
            let it = &req.items[i];
            let m = &req.members[it.member];
            let who = if m.name.is_empty() {
                m.model.clone()
            } else {
                m.name.clone()
            };
            return Err(e.context(format!(
                "{}: cell {} of '{who}' failed ({done}/{} complete)",
                req.label,
                it.cell_index,
                req.items.len()
            )));
        }
        if let Some(e) = store_err {
            return Err(e.context("persisting cell artifact"));
        }
        if let Some(e) = halt_err {
            return Err(e);
        }
        match reason {
            FinishReason::Complete => {
                if let Some((model, e)) = setup_errs.first() {
                    let what = if model.is_empty() {
                        "a worker failed to initialize".to_string()
                    } else {
                        format!("a worker could not compile model '{model}'")
                    };
                    crate::log_warn!(
                        "[{}] note: {what} ({e:#}); all cells completed on \
                         the remaining workers",
                        req.label
                    );
                }
                Ok(ExecStats {
                    jobs: self.size,
                    workers: worker_stats,
                    refused,
                })
            }
            FinishReason::Drained => {
                Err(anyhow::Error::new(Drained).context(format!(
                    "{}: shutdown drained the pool ({done}/{} cells \
                     complete; recorded cells stay durable for resume)",
                    req.label,
                    req.items.len()
                )))
            }
            FinishReason::Stopped(msg) => {
                let e = match setup_errs
                    .iter()
                    .position(|(m, _)| !m.is_empty())
                {
                    Some(i) => {
                        let (model, e) = setup_errs.swap_remove(i);
                        e.context(format!("compiling model '{model}'"))
                    }
                    None => match setup_errs.into_iter().next() {
                        Some((_, e)) => e,
                        None => anyhow!("{msg}"),
                    },
                };
                Err(e.context(format!("{}: {msg}", req.label)))
            }
        }
    }

    /// Stop one job (no further claims); in-flight cells still finish.
    fn stop_job(&self, jid: u64, why: &str) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(job) = st.jobs.get_mut(&jid) {
            if !job.stopped {
                job.stopped = true;
                job.fail = Some(why.to_string());
            }
        }
        maybe_finish(&mut st, jid);
        drop(st);
        self.shared.work.notify_all();
    }

    /// Graceful drain: refuse new claims (and new jobs), let in-flight
    /// cells finish, and finish every attached job — completed ones as
    /// `Complete`, interrupted ones as [`Drained`]. Idempotent.
    pub fn shutdown(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.shutdown = true;
        maybe_finish_all(&mut st);
        drop(st);
        self.shared.work.notify_all();
    }

    /// Shut down and join every worker thread (test teardown; the daemon
    /// calls it after its executors exit).
    pub fn join(&self) {
        self.shutdown();
        let handles: Vec<_> = {
            let mut h = self.handles.lock().unwrap();
            h.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicySpec;
    use crate::schedule::group_of;
    use crate::SweepCell;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn member(name: &str, fp: &str, cap: usize) -> ExecMember {
        ExecMember {
            name: name.into(),
            model: format!("model-{fp}"),
            fingerprint: fp.into(),
            policy: PolicySpec::StaticSuite,
            steps: 8,
            cycles: 8,
            eval_every: 0,
            cap,
        }
    }

    fn items_for(members: &[ExecMember], cells_each: usize) -> Vec<ExecItem> {
        let mut items = Vec::new();
        for (mi, _) in members.iter().enumerate() {
            for c in 0..cells_each {
                items.push(ExecItem {
                    member: mi,
                    cell_index: c,
                    slot: c,
                    cell: SweepCell {
                        schedule: "CR".into(),
                        q_max: 8.0,
                        trial: c,
                    },
                });
            }
        }
        items
    }

    fn fab(member: &ExecMember, cell: &SweepCell, index: usize) -> RunOutcome {
        RunOutcome {
            model: member.model.clone(),
            schedule: cell.schedule.clone(),
            group: group_of(&cell.schedule).label().into(),
            q_max: cell.q_max,
            trial: cell.trial,
            gbitops: 1.0 + index as f64,
            metric: 0.5 + index as f64 * 0.125,
            eval_loss: 0.25,
            steps: member.steps,
            mean_q: 0.75,
            realized_cost: 0.5,
            exec_seconds: 0.01,
            history: crate::metrics::History::default(),
        }
    }

    /// Fabricated pool runner: per-runner simulated compile cache (the
    /// thing that must persist across jobs), a pool-global compile
    /// counter, optional per-cell sleep and injected failures.
    struct FabRunner {
        compiled: Vec<String>,
        compiles: Arc<AtomicUsize>,
        sleep_ms: u64,
        fail_fp: HashSet<String>,
        /// Fail `run_cell` for (fingerprint, cell_index).
        fail_cell: Option<(String, usize)>,
    }

    impl FabRunner {
        fn plain(compiles: Arc<AtomicUsize>) -> FabRunner {
            FabRunner {
                compiled: Vec::new(),
                compiles,
                sleep_ms: 0,
                fail_fp: HashSet::new(),
                fail_cell: None,
            }
        }
    }

    impl CellRunner for FabRunner {
        fn run_cell(
            &mut self,
            member: &ExecMember,
            cell: &SweepCell,
            cell_index: usize,
            _per_step_logs: bool,
        ) -> std::result::Result<RunOutcome, CellError> {
            if self.fail_fp.contains(&member.fingerprint) {
                return Err(CellError::Setup(anyhow!(
                    "injected compile failure for {}",
                    member.fingerprint
                )));
            }
            if !self.compiled.contains(&member.fingerprint) {
                self.compiled.push(member.fingerprint.clone());
                self.compiles.fetch_add(1, Ordering::SeqCst);
            }
            if self.sleep_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(
                    self.sleep_ms,
                ));
            }
            if self.fail_cell.as_ref().map_or(false, |(fp, c)| {
                *fp == member.fingerprint && *c == cell_index
            }) {
                return Err(CellError::Run(anyhow!("injected cell failure")));
            }
            Ok(fab(member, cell, cell_index))
        }

        fn compile_stats(&self) -> (usize, f64) {
            (self.compiled.len(), 0.0)
        }

        fn has_cached(&self, fingerprint: &str) -> bool {
            self.compiled.iter().any(|f| f == fingerprint)
        }
    }

    fn pool_of(
        size: usize,
        compiles: &Arc<AtomicUsize>,
        sleep_ms: u64,
    ) -> WorkerPool {
        let compiles = compiles.clone();
        WorkerPool::new(
            size,
            "test-pool",
            Arc::new(move |_| {
                let mut r = FabRunner::plain(compiles.clone());
                r.sleep_ms = sleep_ms;
                Ok(Box::new(r) as Box<dyn CellRunner>)
            }),
        )
    }

    fn run_one(
        pool: &WorkerPool,
        label: &str,
        members: Vec<ExecMember>,
        items: Vec<ExecItem>,
        halt: Option<usize>,
    ) -> (Result<ExecStats>, Vec<Vec<Option<RunOutcome>>>) {
        let cells = items
            .iter()
            .fold(vec![0usize; members.len()], |mut acc, it| {
                acc[it.member] = acc[it.member].max(it.slot + 1);
                acc
            });
        let mut slots: Vec<Vec<Option<RunOutcome>>> =
            cells.into_iter().map(|n| vec![None; n]).collect();
        let mut sinks: Vec<Option<&mut dyn CellSink>> =
            members.iter().map(|_| None).collect();
        let req = PoolRequest {
            label: label.to_string(),
            members,
            items,
            verbose: false,
            halt_after_cells: halt,
        };
        let res = pool.run_job(&req, &mut sinks, &mut slots);
        (res, slots)
    }

    #[test]
    fn pool_outlives_jobs_and_reuses_compiled_models() {
        let compiles = Arc::new(AtomicUsize::new(0));
        let pool = pool_of(2, &compiles, 0);
        let members = vec![member("a", "fpA", 4)];
        let items = items_for(&members, 6);
        let (res, slots) =
            run_one(&pool, "job1", members.clone(), items.clone(), None);
        let s1 = res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
        assert_eq!(
            s1.workers.iter().map(|w| w.cells).sum::<usize>(),
            6
        );
        let after_job1 = compiles.load(Ordering::SeqCst);
        assert!(after_job1 <= 2, "one compile per worker at most");
        // a second job over the same fingerprint costs zero compiles —
        // the cross-job warm start the pool exists for
        let (res, slots) = run_one(&pool, "job2", members, items, None);
        let s2 = res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
        assert_eq!(compiles.load(Ordering::SeqCst), after_job1);
        assert_eq!(s2.total_compiles(), 0, "{:?}", s2.workers);
        pool.join();
    }

    #[test]
    fn concurrent_jobs_share_the_pool_and_fair_share_favors_the_small_job()
    {
        let compiles = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(pool_of(2, &compiles, 25));
        let order: Arc<Mutex<Vec<&'static str>>> =
            Arc::new(Mutex::new(Vec::new()));
        std::thread::scope(|scope| {
            let p = pool.clone();
            let o = order.clone();
            scope.spawn(move || {
                let members = vec![member("big", "fpA", 4)];
                let items = items_for(&members, 16);
                let (res, _) = run_one(&p, "big", members, items, None);
                res.unwrap();
                o.lock().unwrap().push("big");
            });
            // let the big job occupy the pool first
            std::thread::sleep(std::time::Duration::from_millis(60));
            let p = pool.clone();
            let o = order.clone();
            scope.spawn(move || {
                let members = vec![member("small", "fpB", 4)];
                let items = items_for(&members, 2);
                let (res, _) = run_one(&p, "small", members, items, None);
                res.unwrap();
                o.lock().unwrap().push("small");
            });
        });
        assert_eq!(
            order.lock().unwrap().as_slice(),
            ["small", "big"],
            "the 2-cell job must finish while the 16-cell job runs"
        );
        pool.join();
    }

    #[test]
    fn per_job_stats_split_shared_worker_accounting() {
        let compiles = Arc::new(AtomicUsize::new(0));
        let pool = pool_of(1, &compiles, 0);
        let members = vec![member("a", "fpA", 4)];
        let (res, _) = run_one(
            &pool,
            "first",
            members.clone(),
            items_for(&members, 3),
            None,
        );
        let s1 = res.unwrap();
        assert_eq!(s1.total_compiles(), 1, "{:?}", s1.workers);
        // the second job reuses the cache: its own stats show 0 compiles
        // even though the worker's lifetime count is 1
        let (res, _) =
            run_one(&pool, "second", members.clone(), items_for(&members, 3), None);
        assert_eq!(res.unwrap().total_compiles(), 0);
        pool.join();
    }

    #[test]
    fn a_failed_cell_stops_only_its_own_job() {
        // one shared pool whose workers fail cell 1 of fpA; the fpB job
        // on the same pool must be untouched
        let compiles = Arc::new(AtomicUsize::new(0));
        let c = compiles.clone();
        let pool = Arc::new(WorkerPool::new(
            2,
            "mixed",
            Arc::new(move |_| {
                let mut r = FabRunner::plain(c.clone());
                r.sleep_ms = 5;
                r.fail_cell = Some(("fpA".to_string(), 1));
                Ok(Box::new(r) as Box<dyn CellRunner>)
            }),
        ));
        std::thread::scope(|scope| {
            let p = pool.clone();
            scope.spawn(move || {
                let members = vec![member("bad", "fpA", 1)];
                let items = items_for(&members, 4);
                let (res, _) = run_one(&p, "bad", members, items, None);
                let msg = format!("{:#}", res.unwrap_err());
                assert!(msg.contains("injected cell failure"), "{msg}");
                assert!(msg.contains("cell 1 of 'bad'"), "{msg}");
            });
            let p = pool.clone();
            scope.spawn(move || {
                let members = vec![member("good", "fpB", 4)];
                let items = items_for(&members, 4);
                let (res, slots) = run_one(&p, "good", members, items, None);
                res.unwrap();
                assert!(slots[0].iter().all(|o| o.is_some()));
            });
        });
        pool.join();
    }

    #[test]
    fn unclaimable_models_stop_the_job_with_the_compile_error() {
        let compiles = Arc::new(AtomicUsize::new(0));
        let c = compiles.clone();
        let pool = WorkerPool::new(
            1,
            "nofp",
            Arc::new(move |_| {
                let mut r = FabRunner::plain(c.clone());
                r.fail_fp.insert("fpA".into());
                Ok(Box::new(r) as Box<dyn CellRunner>)
            }),
        );
        let members = vec![member("a", "fpA", 4)];
        let items = items_for(&members, 2);
        let (res, _) = run_one(&pool, "nofp", members, items, None);
        let msg = format!("{:#}", res.unwrap_err());
        assert!(msg.contains("unclaimed"), "{msg}");
        assert!(msg.contains("injected compile failure"), "{msg}");
        pool.join();
    }

    #[test]
    fn shutdown_drains_with_a_downcastable_sentinel() {
        let compiles = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(pool_of(1, &compiles, 20));
        let err = std::thread::scope(|scope| {
            let p = pool.clone();
            let h = scope.spawn(move || {
                let members = vec![member("a", "fpA", 4)];
                let items = items_for(&members, 20);
                let (res, slots) = run_one(&p, "drainme", members, items, None);
                let done =
                    slots[0].iter().filter(|o| o.is_some()).count();
                (res.unwrap_err(), done)
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            pool.shutdown();
            h.join().unwrap()
        });
        let (err, done) = err;
        assert!(
            err.downcast_ref::<Drained>().is_some(),
            "expected Drained sentinel, got: {err:#}"
        );
        assert!(done < 20, "shutdown must interrupt the job");
        // new jobs are refused once draining
        let members = vec![member("b", "fpB", 4)];
        let items = items_for(&members, 1);
        let (res, _) = run_one(&pool, "late", members, items, None);
        assert!(res.unwrap_err().downcast_ref::<Drained>().is_some());
        pool.join();
    }

    #[test]
    fn halt_after_cells_stops_one_job_and_spares_the_pool() {
        let compiles = Arc::new(AtomicUsize::new(0));
        let pool = pool_of(1, &compiles, 0);
        let members = vec![member("a", "fpA", 4)];
        let (res, _) =
            run_one(&pool, "halted", members.clone(), items_for(&members, 5), Some(2));
        let msg = format!("{:#}", res.unwrap_err());
        assert!(msg.contains("halted after 2"), "{msg}");
        // the pool survives the halted job: a fresh job completes
        let (res, slots) =
            run_one(&pool, "after", members.clone(), items_for(&members, 3), None);
        res.unwrap();
        assert!(slots[0].iter().all(|o| o.is_some()));
        pool.join();
    }
}

//! Experiment coordinator — orchestrates schedule sweeps across models,
//! q_max settings, and trials; aggregates results into the paper's
//! figure/table rows.
//!
//! This is the L3 entry point every bench target drives: one
//! `SweepSpec` describes a panel of a paper figure (model × schedule
//! suite × q_max × trials — or, with an adaptive `PolicySpec`, a
//! feedback-driven precision policy per q_max × trial), `run_sweep`
//! executes it on the PJRT runtime, and `SweepReport` prints rows of
//! (schedule, group, GBitOps, metric ± std, realized mean-q/cost) plus
//! writes CSV under results/.
//!
//! Execution model: plan → execute → merge. [`plan::SweepPlan`] flattens
//! the spec into an ordered, content-hashed cell list (schedule × q_max ×
//! trial) and assigns this process its shard (`--shard I/N`, round-robin
//! by canonical index). Execution goes through the shared work-queue
//! executor in [`exec`]: cells become [`exec::ExecItem`]s and a pool of
//! `jobs` workers (each owning a PJRT client plus an LRU cache of
//! compiled executables — PJRT handles are not Sync) claims them, with
//! results funneled into index-ordered slots, so output is byte-identical
//! regardless of worker count (every cell is a fully seeded, independent
//! run; `jobs == 1` is just a one-worker pool). When a run directory is
//! given, each completed cell is persisted through [`store::RunStore`]
//! (all store writes on the collector thread) and cells with valid
//! artifacts are skipped on re-run, which makes crash/preempt resume
//! free; `cpt merge` (backed by [`store::merge_run_dirs`]) validates and
//! recombines shard directories into the single-process result. One
//! level above sweeps, [`campaign`] orchestrates several named sweeps as
//! one content-addressed tree (`cpt campaign` / `cpt status` / `cpt
//! gc`); its global scheduler feeds every member's cells to one shared
//! pool through the same executor. See rust/DESIGN-sharding.md and
//! rust/DESIGN-perf.md.

pub mod aot;
pub mod campaign;
pub mod exec;
pub mod lease;
pub mod plan;
pub mod pool;
pub mod recipes;
pub mod report;
pub mod store;

pub use campaign::{
    merge_campaign_roots, run_campaign, CampaignPlan, CampaignSpec,
    SchedulerKind,
};
pub use plan::{ClaimerId, PlannedCell, ShardId, SweepPlan};
pub use recipes::{dataset_for, recipe, report_metric, Recipe};
pub use report::SweepReport;
pub use store::{compact_run_dir, merge_run_dirs, read_manifest, RunStore};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::data::mean_std;
use crate::metrics::History;
use crate::policy::{PolicySpec, PrecisionPolicy, StaticPolicy};
use crate::runtime::{LoadedModel, Manifest};
use crate::schedule::{group_of, suite, Schedule};
use crate::trainer::{TrainConfig, Trainer};

/// One sweep = one figure panel.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub model: String,
    /// Schedule names: suite members, "STATIC", or "NONE" (no quant = q32).
    pub schedules: Vec<String>,
    pub q_maxes: Vec<f64>,
    pub trials: usize,
    /// Override the recipe's default step count (None = recipe default).
    pub steps: Option<usize>,
    /// Override the recipe's cycle count.
    pub cycles: Option<usize>,
    /// Precision policy for every cell of the sweep. `StaticSuite` (the
    /// default) replays each cell's named schedule — the legacy path;
    /// adaptive policies choose q_t from training feedback, in which case
    /// the schedule axis collapses to the policy's label (see
    /// `campaign::sweep_spec_from_section` / `cpt sweep --policy`).
    /// Result-determining: part of the spec hash when adaptive.
    pub policy: PolicySpec,
    pub eval_every: usize,
    pub verbose: bool,
    /// Worker threads for the sweep executor (1 = serial on the caller's
    /// Runtime). Defaults to `cpt::default_jobs()` (the CPT_JOBS env var).
    pub jobs: usize,
    /// Shard assignment (`I/N`): run only the cells this shard owns.
    /// None = the whole sweep (equivalent to `1/1`).
    pub shard: Option<ShardId>,
    /// Persist one artifact per completed cell into this run directory
    /// (required for multi-shard runs, useful for crash resume on any).
    pub run_dir: Option<PathBuf>,
    /// Allow reopening an existing run directory, skipping cells whose
    /// valid artifacts are already recorded.
    pub resume: bool,
    /// Cached `store::model_fingerprint` (set by `apply_env_run_dir`, or
    /// by any caller that already computed it) so the executor does not
    /// re-read every HLO artifact file. Purely an I/O cache — never part
    /// of the spec hash; computed on demand when absent.
    pub model_fingerprint: Option<String>,
}

impl SweepSpec {
    pub fn new(model: &str) -> Self {
        SweepSpec {
            model: model.to_string(),
            schedules: suite::suite_names()
                .iter()
                .map(|s| s.to_string())
                .chain(std::iter::once("STATIC".to_string()))
                .collect(),
            q_maxes: vec![6.0, 8.0],
            trials: 1,
            steps: None,
            cycles: None,
            policy: PolicySpec::StaticSuite,
            eval_every: 0,
            verbose: false,
            jobs: crate::default_jobs(),
            shard: None,
            run_dir: None,
            resume: false,
            model_fingerprint: None,
        }
    }

    /// Bench-style env wiring: if CPT_RUN_DIR is set (the bench targets
    /// have no CLI, so the env var is their `--run-dir`), persist cell
    /// artifacts under
    /// `<CPT_RUN_DIR>/<model>-<spec_hash[..8]>-<model_fingerprint[..8]>`
    /// and resume across reruns. Both hashes in the directory name mean
    /// neither a changed spec nor a regenerated `artifacts/` tree ever
    /// collides with stale artifacts (each gets a fresh directory rather
    /// than a resume failure), so blanket resume is safe — a killed
    /// figure bench continues exactly where it stopped.
    pub fn apply_env_run_dir(&mut self, manifest: &Manifest) -> Result<()> {
        if let Ok(base) = std::env::var("CPT_RUN_DIR") {
            if !base.is_empty() {
                let fp =
                    store::model_fingerprint(manifest.model(&self.model)?)?;
                self.run_dir = Some(plan::run_dir_under(
                    Path::new(&base),
                    self,
                    &fp,
                )?);
                self.resume = true;
                self.model_fingerprint = Some(fp);
            }
        }
        Ok(())
    }

    /// Announce an active run directory on stderr — bench targets call
    /// this after [`SweepSpec::apply_env_run_dir`] so a user who set
    /// CPT_RUN_DIR sees where artifacts land and how to inspect them.
    pub fn log_run_dir(&self) {
        if let Some(dir) = &self.run_dir {
            crate::log_info!(
                "[sweep] persisting cell artifacts under {0} — inspect \
                 progress with `cpt status {0}`",
                dir.display()
            );
        }
    }
}

// Strict env-var parsing lives in `util` now (the obs logger needs it
// for CPT_LOG); re-exported here so `super::env_parse` callers in
// exec/lease stay unchanged.
pub(crate) use crate::util::env_parse;

/// Crash-injection point for the resume tests: with CPT_HALT_AFTER_CELLS=N
/// set, the executor's collector aborts the run after recording N freshly
/// computed cells (a deterministic stand-in for `kill` in
/// scripts/check.sh's campaign gates — every durability property it
/// exercises is the same, because artifacts/manifests are already on disk
/// when the abort fires). Counted process-wide so a sequential campaign
/// halts after N cells across members, not per member. (In-process tests
/// use `exec::ExecRequest::halt_after_cells` instead, which counts
/// per-run and never touches env.) An unparsable value fails the run
/// loudly instead of silently disabling the injection.
fn crash_injection_point() -> Result<()> {
    static FRESH_CELLS: AtomicUsize = AtomicUsize::new(0);
    if let Some(n) = env_parse::<usize>("CPT_HALT_AFTER_CELLS")? {
        if n > 0 {
            let done = FRESH_CELLS.fetch_add(1, Ordering::SeqCst) + 1;
            if done >= n {
                anyhow::bail!(
                    "halted after {done} freshly computed cell(s) \
                     (CPT_HALT_AFTER_CELLS={n} crash injection)"
                );
            }
        }
    }
    Ok(())
}

/// One cell of a sweep: a single training run to execute.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepCell {
    pub schedule: String,
    pub q_max: f64,
    pub trial: usize,
}

/// Flatten a spec into its ordered cell list — the canonical execution
/// (and result) order for both the serial and the parallel executor.
pub fn sweep_cells(spec: &SweepSpec) -> Vec<SweepCell> {
    let mut cells = Vec::with_capacity(
        spec.q_maxes.len() * spec.schedules.len() * spec.trials,
    );
    for &q_max in &spec.q_maxes {
        for sched in &spec.schedules {
            for trial in 0..spec.trials {
                cells.push(SweepCell {
                    schedule: sched.clone(),
                    q_max,
                    trial,
                });
            }
        }
    }
    cells
}

/// Wall-clock accounting for one sweep execution.
#[derive(Clone, Copy, Debug)]
pub struct SweepTiming {
    pub wall_seconds: f64,
    pub jobs: usize,
    /// Cells this process was responsible for (the shard's share).
    pub cells: usize,
    /// Cells skipped because a valid artifact already existed.
    pub resumed: usize,
}

/// Result of one training run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub model: String,
    pub schedule: String,
    pub group: String,
    pub q_max: f64,
    pub trial: usize,
    pub gbitops: f64,
    /// figure-of-merit (accuracy / mAP-lite / perplexity)
    pub metric: f64,
    pub eval_loss: f64,
    pub steps: usize,
    /// Realized mean q_t / q_max of the executed trace (exact — adaptive
    /// policies make it data-dependent, so it is recorded per run).
    pub mean_q: f64,
    /// Realized relative training cost vs static q_max (the
    /// `schedule::cost` trace formula).
    pub realized_cost: f64,
    pub exec_seconds: f64,
    pub history: History,
}

/// Aggregated over trials.
#[derive(Clone, Debug)]
pub struct AggRow {
    pub model: String,
    pub schedule: String,
    pub group: String,
    pub q_max: f64,
    pub gbitops: f64,
    pub metric_mean: f64,
    pub metric_std: f64,
    pub trials: usize,
    /// Mean realized q_t / q_max over trials (trace-exact, so adaptive
    /// trials may differ — this is their mean).
    pub mean_q: f64,
    /// Mean realized relative cost over trials.
    pub realized_cost: f64,
    /// Mean per-cell executable wall-clock (seconds) over trials.
    pub exec_seconds_mean: f64,
}

/// Build the schedule object for a named sweep entry.
pub fn make_schedule(
    name: &str,
    q_min: f64,
    q_max: f64,
    total: usize,
    n: usize,
) -> Result<Schedule> {
    match name {
        "STATIC" => Ok(Schedule::static_q(q_max)),
        "NONE" => Ok(Schedule::static_q(32.0)),
        _ => suite::by_name(name, q_min, q_max, total, n),
    }
}

/// Run one training run for (model, schedule, q_max, trial) on the
/// legacy schedule path (`PolicySpec::StaticSuite`).
#[allow(clippy::too_many_arguments)]
pub fn run_one(
    model: &LoadedModel,
    spec_name: &str,
    sched_name: &str,
    q_max: f64,
    trial: usize,
    steps: usize,
    cycles: usize,
    eval_every: usize,
    verbose: bool,
) -> Result<RunOutcome> {
    run_one_with_policy(
        model,
        spec_name,
        &PolicySpec::StaticSuite,
        sched_name,
        q_max,
        trial,
        steps,
        cycles,
        eval_every,
        verbose,
    )
}

/// Run one training run under a precision policy. With `StaticSuite`,
/// `sched_name` selects the suite schedule exactly as before (the
/// schedule is wrapped in a `StaticPolicy`, bit-identical emission);
/// with an adaptive policy the schedule axis is inert — `sched_name` is
/// only the cell's display label (conventionally the policy label) and
/// q_t comes from the feedback loop.
#[allow(clippy::too_many_arguments)]
pub fn run_one_with_policy(
    model: &LoadedModel,
    spec_name: &str,
    policy_spec: &PolicySpec,
    sched_name: &str,
    q_max: f64,
    trial: usize,
    steps: usize,
    cycles: usize,
    eval_every: usize,
    verbose: bool,
) -> Result<RunOutcome> {
    let rec = recipe(spec_name)?;
    let policy: Box<dyn PrecisionPolicy> = if policy_spec.is_adaptive() {
        policy_spec.build_adaptive(rec.q_min, q_max, steps)?
    } else {
        Box::new(StaticPolicy::new(make_schedule(
            sched_name, rec.q_min, q_max, steps, cycles,
        )?))
    };
    let mut data = dataset_for(spec_name, 1000 + trial as u64)?;
    let cfg = TrainConfig {
        total_steps: steps,
        // q_bwd is pinned to q_max (paper §3.1) for schedules and
        // policies alike; the NONE baseline runs unquantized throughout
        q_bwd: if sched_name == "NONE" { 32.0 } else { q_max as f32 },
        eval_every,
        seed: 7 * (trial as i32 + 1),
        log_every: 1,
        verbose,
    };
    let lr = rec.lr_schedule(steps);
    let mut trainer =
        Trainer::with_policy(model, data.as_mut(), policy, lr, cfg);
    let hist = trainer.run()?;
    let raw_metric = hist.final_eval_metric().unwrap_or(f32::NAN);
    Ok(RunOutcome {
        model: spec_name.to_string(),
        schedule: sched_name.to_string(),
        group: group_of(sched_name).label().to_string(),
        q_max,
        trial,
        gbitops: hist.gbitops,
        metric: report_metric(spec_name, raw_metric) as f64,
        eval_loss: hist.final_eval_loss().unwrap_or(f32::NAN) as f64,
        steps,
        mean_q: hist.mean_q,
        realized_cost: hist.realized_cost,
        exec_seconds: hist.exec_seconds,
        history: hist,
    })
}

/// Execute a full sweep spec; see `run_sweep_timed` for the wall-clock
/// variant.
pub fn run_sweep(
    manifest: &Manifest,
    spec: &SweepSpec,
) -> Result<Vec<RunOutcome>> {
    run_sweep_timed(manifest, spec).map(|(outs, _)| outs)
}

/// Execute a sweep spec's owned shard, returning outcomes in canonical
/// cell order plus wall-clock timing.
///
/// The spec is first compiled into a [`SweepPlan`] (stable cell order +
/// content hash). With `spec.run_dir` set, a [`RunStore`] is opened and
/// cells whose valid artifacts already exist are loaded instead of
/// re-trained; every freshly computed cell is persisted before the sweep
/// moves on, so a crash loses at most the in-flight cells. `spec.jobs >
/// 1` selects the parallel work-queue executor; outcomes are bit-identical
/// to serial execution (each cell is independently seeded), only
/// wall-clock changes. The executor owns its PJRT client(s) — one for the
/// serial path, one per worker in parallel mode — so callers never build
/// an idle one.
pub fn run_sweep_timed(
    manifest: &Manifest,
    spec: &SweepSpec,
) -> Result<(Vec<RunOutcome>, SweepTiming)> {
    let t0 = Instant::now();
    let plan = SweepPlan::build(spec)?;
    if plan.shard.count > 1 && spec.run_dir.is_none() {
        // enforced here, not just in the CLI: a multi-shard run with no
        // store would silently return a partial sweep that aggregates
        // into a full-looking (and wrong) figure panel
        anyhow::bail!(
            "sharded sweep ({}) needs a run directory: the shard's cells \
             must be persisted for `cpt merge` to combine them",
            plan.shard
        );
    }
    // Fingerprint the compiled model when a store needs it (resume/merge
    // must detect a regenerated artifacts/ tree the spec hash cannot
    // see), honoring a caller-supplied cache to avoid re-reading the HLO
    // files. The executor reuses the same fingerprint as its executable-
    // cache key; a store-less sweep falls back to a name-derived key
    // (within one process, model name <-> spec is fixed by the manifest).
    let fingerprint = match (&spec.model_fingerprint, &spec.run_dir) {
        (Some(fp), _) => fp.clone(),
        (None, Some(_)) => {
            store::model_fingerprint(manifest.model(&spec.model)?)?
        }
        (None, None) => format!("model:{}", spec.model),
    };
    let mut store = match &spec.run_dir {
        Some(dir) => {
            Some(RunStore::open(dir, &plan, &fingerprint, spec.resume)?)
        }
        None => None,
    };
    let owned = plan.owned();
    let mut slots: Vec<Option<RunOutcome>> = vec![None; owned.len()];
    let mut todo: Vec<usize> = Vec::new();
    let mut resumed = 0usize;
    for (pos, pc) in owned.iter().enumerate() {
        // one read per artifact: validation failures drop the entry and
        // fall through to recomputation
        match store.as_mut().and_then(|st| st.take_valid_outcome(pc.index)) {
            Some(out) => {
                slots[pos] = Some(out);
                resumed += 1;
            }
            None => todo.push(pos),
        }
    }
    if spec.verbose && resumed > 0 {
        if let Some(st) = &store {
            crate::log_info!(
                "[sweep] resumed {resumed}/{} cells from {}",
                owned.len(),
                st.dir().display()
            );
        }
    }
    let jobs = spec.jobs.max(1).min(todo.len().max(1));
    if !todo.is_empty() {
        let model_spec = manifest.model(&spec.model)?.clone();
        model_spec.validate()?; // fail fast, before spawning any workers
        let member = exec::ExecMember {
            name: String::new(),
            model: spec.model.clone(),
            fingerprint: fingerprint.clone(),
            policy: spec.policy.clone(),
            steps: plan.steps,
            cycles: plan.cycles,
            eval_every: spec.eval_every,
            cap: jobs,
        };
        let items: Vec<exec::ExecItem> = todo
            .iter()
            .map(|&pos| exec::ExecItem {
                member: 0,
                cell_index: owned[pos].index,
                slot: pos,
                cell: owned[pos].cell.clone(),
            })
            .collect();
        let mut specs = HashMap::new();
        specs.insert(spec.model.clone(), model_spec);
        let specs = Arc::new(exec::SpecRegistry::from_map(specs));
        let members = [member];
        let req = exec::ExecRequest {
            label: format!("sweep {}", spec.model),
            members: &members,
            items: &items,
            jobs,
            verbose: spec.verbose,
            halt_after_cells: None,
            source: None,
        };
        let mut stores: [Option<&mut dyn exec::CellSink>; 1] =
            [store.as_mut().map(|s| s as &mut dyn exec::CellSink)];
        let mut slot_groups = [std::mem::take(&mut slots)];
        let cache_cap = exec::exec_cache_cap()?;
        let aot_store = aot::store_for_run()?.map(Arc::new);
        let res = exec::run_items(&req, &mut stores, &mut slot_groups, |_| {
            exec::PjrtCellRunner::new(
                specs.clone(),
                cache_cap,
                aot_store.clone(),
            )
        });
        slots = std::mem::take(&mut slot_groups[0]);
        res?;
    }
    let timing = SweepTiming {
        wall_seconds: t0.elapsed().as_secs_f64(),
        jobs,
        cells: owned.len(),
        resumed,
    };
    Ok((slots.into_iter().flatten().collect(), timing))
}

/// Aggregate outcomes over trials. Single pass: grouped via a HashMap
/// keyed on (model, schedule, q_max bits); output rows keep first-seen
/// order, matching the serial cell order.
pub fn aggregate(outs: &[RunOutcome]) -> Vec<AggRow> {
    struct Acc {
        model: String,
        schedule: String,
        group: String,
        q_max: f64,
        metrics: Vec<f64>,
        gbitops_sum: f64,
        mean_q_sum: f64,
        realized_cost_sum: f64,
        exec_seconds_sum: f64,
    }
    let mut index: HashMap<(&str, &str, u64), usize> = HashMap::new();
    let mut accs: Vec<Acc> = Vec::new();
    for o in outs {
        let key = (o.model.as_str(), o.schedule.as_str(), o.q_max.to_bits());
        let i = match index.get(&key) {
            Some(&i) => i,
            None => {
                accs.push(Acc {
                    model: o.model.clone(),
                    schedule: o.schedule.clone(),
                    group: o.group.clone(),
                    q_max: o.q_max,
                    metrics: Vec::new(),
                    gbitops_sum: 0.0,
                    mean_q_sum: 0.0,
                    realized_cost_sum: 0.0,
                    exec_seconds_sum: 0.0,
                });
                index.insert(key, accs.len() - 1);
                accs.len() - 1
            }
        };
        let a = &mut accs[i];
        a.metrics.push(o.metric);
        a.gbitops_sum += o.gbitops;
        a.mean_q_sum += o.mean_q;
        a.realized_cost_sum += o.realized_cost;
        a.exec_seconds_sum += o.exec_seconds;
    }
    accs.into_iter()
        .map(|a| {
            let n = a.metrics.len();
            let (m, s) = mean_std(&a.metrics);
            AggRow {
                model: a.model,
                schedule: a.schedule,
                group: a.group,
                q_max: a.q_max,
                gbitops: a.gbitops_sum / n as f64,
                metric_mean: m,
                metric_std: s,
                trials: n,
                mean_q: a.mean_q_sum / n as f64,
                realized_cost: a.realized_cost_sum / n as f64,
                exec_seconds_mean: a.exec_seconds_sum / n as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(sched: &str, q: f64, trial: usize, metric: f64) -> RunOutcome {
        RunOutcome {
            model: "m".into(),
            schedule: sched.into(),
            group: group_of(sched).label().into(),
            q_max: q,
            trial,
            gbitops: 1.0 + trial as f64,
            metric,
            eval_loss: 0.0,
            steps: 10,
            mean_q: 0.5 + trial as f64 * 0.25,
            realized_cost: 0.4 + trial as f64 * 0.2,
            exec_seconds: 0.5 + trial as f64,
            history: crate::metrics::History::default(),
        }
    }

    #[test]
    fn aggregate_means_over_trials() {
        let outs = vec![
            outcome("CR", 8.0, 0, 0.8),
            outcome("CR", 8.0, 1, 0.9),
            outcome("CR", 6.0, 0, 0.5),
            outcome("RR", 8.0, 0, 0.7),
        ];
        let rows = aggregate(&outs);
        assert_eq!(rows.len(), 3);
        let cr8 = rows
            .iter()
            .find(|r| r.schedule == "CR" && r.q_max == 8.0)
            .unwrap();
        assert!((cr8.metric_mean - 0.85).abs() < 1e-12);
        assert_eq!(cr8.trials, 2);
        assert!((cr8.gbitops - 1.5).abs() < 1e-12);
        assert!((cr8.mean_q - 0.625).abs() < 1e-12);
        assert!((cr8.realized_cost - 0.5).abs() < 1e-12);
        assert!((cr8.exec_seconds_mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_keeps_first_seen_order() {
        let outs = vec![
            outcome("RR", 6.0, 0, 0.1),
            outcome("CR", 8.0, 0, 0.2),
            outcome("RR", 6.0, 1, 0.3),
            outcome("STATIC", 8.0, 0, 0.4),
        ];
        let rows = aggregate(&outs);
        let order: Vec<(String, f64)> = rows
            .iter()
            .map(|r| (r.schedule.clone(), r.q_max))
            .collect();
        assert_eq!(
            order,
            vec![
                ("RR".to_string(), 6.0),
                ("CR".to_string(), 8.0),
                ("STATIC".to_string(), 8.0)
            ]
        );
    }

    #[test]
    fn aggregate_groups_large_input_linearly() {
        // smoke the HashMap path on a sweep-shaped input: 11 schedules ×
        // 2 q_maxes × 5 trials
        let mut outs = Vec::new();
        for q in [6.0, 8.0] {
            for s in 0..11 {
                for t in 0..5 {
                    outs.push(outcome(&format!("S{s}"), q, t, 0.5));
                }
            }
        }
        let rows = aggregate(&outs);
        assert_eq!(rows.len(), 22);
        assert!(rows.iter().all(|r| r.trials == 5));
    }

    #[test]
    fn make_schedule_handles_baselines() {
        let s = make_schedule("STATIC", 3.0, 8.0, 100, 8).unwrap();
        assert_eq!(s.q_at(50), 8);
        let n = make_schedule("NONE", 3.0, 8.0, 100, 8).unwrap();
        assert_eq!(n.q_at(50), 32);
        let c = make_schedule("CR", 3.0, 8.0, 100, 8).unwrap();
        assert!(c.q_at(0) < 8);
        assert!(make_schedule("BOGUS", 3.0, 8.0, 100, 8).is_err());
    }

    #[test]
    fn sweep_spec_defaults_cover_suite_plus_static() {
        let spec = SweepSpec::new("mlp");
        assert_eq!(spec.schedules.len(), 11);
        assert!(spec.schedules.contains(&"STATIC".to_string()));
        assert_eq!(spec.q_maxes, vec![6.0, 8.0]);
        assert!(spec.jobs >= 1);
        // sharding/persistence are opt-in
        assert_eq!(spec.shard, None);
        assert!(spec.run_dir.is_none());
        assert!(!spec.resume);
    }

    #[test]
    fn sweep_cells_enumerate_in_serial_loop_order() {
        let mut spec = SweepSpec::new("mlp");
        spec.schedules = vec!["CR".into(), "RR".into()];
        spec.q_maxes = vec![6.0, 8.0];
        spec.trials = 2;
        let cells = sweep_cells(&spec);
        assert_eq!(cells.len(), 8);
        // q_max outermost, then schedule, then trial — the historical
        // serial nesting
        assert_eq!(cells[0], SweepCell { schedule: "CR".into(), q_max: 6.0, trial: 0 });
        assert_eq!(cells[1], SweepCell { schedule: "CR".into(), q_max: 6.0, trial: 1 });
        assert_eq!(cells[2], SweepCell { schedule: "RR".into(), q_max: 6.0, trial: 0 });
        assert_eq!(cells[4], SweepCell { schedule: "CR".into(), q_max: 8.0, trial: 0 });
    }
}

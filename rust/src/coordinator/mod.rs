//! Experiment coordinator — orchestrates schedule sweeps across models,
//! q_max settings, and trials; aggregates results into the paper's
//! figure/table rows.
//!
//! This is the L3 entry point every bench target drives: one
//! `SweepSpec` describes a panel of a paper figure (model × schedule
//! suite × q_max × trials), `run_sweep` executes it on the PJRT runtime,
//! and `SweepReport` prints rows of (schedule, group, GBitOps, metric ±
//! std) plus writes CSV under results/.

pub mod recipes;
pub mod report;

pub use recipes::{dataset_for, recipe, report_metric, Recipe};
pub use report::SweepReport;

use anyhow::Result;

use crate::data::mean_std;
use crate::metrics::History;
use crate::runtime::{LoadedModel, Manifest, Runtime};
use crate::schedule::{group_of, suite, Schedule};
use crate::trainer::{TrainConfig, Trainer};

/// One sweep = one figure panel.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub model: String,
    /// Schedule names: suite members, "STATIC", or "NONE" (no quant = q32).
    pub schedules: Vec<String>,
    pub q_maxes: Vec<f64>,
    pub trials: usize,
    /// Override the recipe's default step count (None = recipe default).
    pub steps: Option<usize>,
    /// Override the recipe's cycle count.
    pub cycles: Option<usize>,
    pub eval_every: usize,
    pub verbose: bool,
}

impl SweepSpec {
    pub fn new(model: &str) -> Self {
        SweepSpec {
            model: model.to_string(),
            schedules: suite::suite_names()
                .iter()
                .map(|s| s.to_string())
                .chain(std::iter::once("STATIC".to_string()))
                .collect(),
            q_maxes: vec![6.0, 8.0],
            trials: 1,
            steps: None,
            cycles: None,
            eval_every: 0,
            verbose: false,
        }
    }
}

/// Result of one training run.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub model: String,
    pub schedule: String,
    pub group: String,
    pub q_max: f64,
    pub trial: usize,
    pub gbitops: f64,
    /// figure-of-merit (accuracy / mAP-lite / perplexity)
    pub metric: f64,
    pub eval_loss: f64,
    pub steps: usize,
    pub exec_seconds: f64,
    pub history: History,
}

/// Aggregated over trials.
#[derive(Clone, Debug)]
pub struct AggRow {
    pub model: String,
    pub schedule: String,
    pub group: String,
    pub q_max: f64,
    pub gbitops: f64,
    pub metric_mean: f64,
    pub metric_std: f64,
    pub trials: usize,
}

/// Build the schedule object for a named sweep entry.
pub fn make_schedule(
    name: &str,
    q_min: f64,
    q_max: f64,
    total: usize,
    n: usize,
) -> Result<Schedule> {
    match name {
        "STATIC" => Ok(Schedule::static_q(q_max)),
        "NONE" => Ok(Schedule::static_q(32.0)),
        _ => suite::by_name(name, q_min, q_max, total, n),
    }
}

/// Run one training run for (model, schedule, q_max, trial).
pub fn run_one(
    model: &LoadedModel,
    spec_name: &str,
    sched_name: &str,
    q_max: f64,
    trial: usize,
    steps: usize,
    cycles: usize,
    eval_every: usize,
    verbose: bool,
) -> Result<RunOutcome> {
    let rec = recipe(spec_name)?;
    let schedule = make_schedule(sched_name, rec.q_min, q_max, steps, cycles)?;
    let mut data = dataset_for(spec_name, 1000 + trial as u64)?;
    let cfg = TrainConfig {
        total_steps: steps,
        q_bwd: if sched_name == "NONE" { 32.0 } else { q_max as f32 },
        eval_every,
        seed: 7 * (trial as i32 + 1),
        log_every: 1,
        verbose,
    };
    let lr = rec.lr_schedule(steps);
    let mut trainer = Trainer::new(model, data.as_mut(), schedule, lr, cfg);
    let hist = trainer.run()?;
    let raw_metric = hist.final_eval_metric().unwrap_or(f32::NAN);
    Ok(RunOutcome {
        model: spec_name.to_string(),
        schedule: sched_name.to_string(),
        group: group_of(sched_name).label().to_string(),
        q_max,
        trial,
        gbitops: hist.gbitops,
        metric: report_metric(spec_name, raw_metric) as f64,
        eval_loss: hist.final_eval_loss().unwrap_or(f32::NAN) as f64,
        steps,
        exec_seconds: hist.exec_seconds,
        history: hist,
    })
}

/// Execute a full sweep spec. Loads the model once and reuses the
/// compiled executables across every schedule/trial (compilation is the
/// dominant fixed cost on this testbed).
pub fn run_sweep(
    rt: &Runtime,
    manifest: &Manifest,
    spec: &SweepSpec,
) -> Result<Vec<RunOutcome>> {
    let rec = recipe(&spec.model)?;
    let steps = spec.steps.unwrap_or(rec.steps);
    let cycles = spec.cycles.unwrap_or(rec.cycles);
    let model = rt.load_model(manifest.model(&spec.model)?)?;

    let mut outs = Vec::new();
    for &q_max in &spec.q_maxes {
        for sched in &spec.schedules {
            for trial in 0..spec.trials {
                let out = run_one(
                    &model, &spec.model, sched, q_max, trial, steps, cycles,
                    spec.eval_every, spec.verbose,
                )?;
                if spec.verbose {
                    eprintln!(
                        "[sweep] {} {} qmax={} trial={} -> metric={:.4} ({:.3} GBitOps)",
                        spec.model, sched, q_max, trial, out.metric, out.gbitops
                    );
                }
                outs.push(out);
            }
        }
    }
    Ok(outs)
}

/// Aggregate outcomes over trials.
pub fn aggregate(outs: &[RunOutcome]) -> Vec<AggRow> {
    let mut rows: Vec<AggRow> = Vec::new();
    for o in outs {
        if rows.iter().any(|r| {
            r.model == o.model && r.schedule == o.schedule && r.q_max == o.q_max
        }) {
            continue;
        }
        let group: Vec<&RunOutcome> = outs
            .iter()
            .filter(|x| {
                x.model == o.model
                    && x.schedule == o.schedule
                    && x.q_max == o.q_max
            })
            .collect();
        let metrics: Vec<f64> = group.iter().map(|x| x.metric).collect();
        let (m, s) = mean_std(&metrics);
        rows.push(AggRow {
            model: o.model.clone(),
            schedule: o.schedule.clone(),
            group: o.group.clone(),
            q_max: o.q_max,
            gbitops: group.iter().map(|x| x.gbitops).sum::<f64>()
                / group.len() as f64,
            metric_mean: m,
            metric_std: s,
            trials: group.len(),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(sched: &str, q: f64, trial: usize, metric: f64) -> RunOutcome {
        RunOutcome {
            model: "m".into(),
            schedule: sched.into(),
            group: group_of(sched).label().into(),
            q_max: q,
            trial,
            gbitops: 1.0 + trial as f64,
            metric,
            eval_loss: 0.0,
            steps: 10,
            exec_seconds: 0.0,
            history: crate::metrics::History::default(),
        }
    }

    #[test]
    fn aggregate_means_over_trials() {
        let outs = vec![
            outcome("CR", 8.0, 0, 0.8),
            outcome("CR", 8.0, 1, 0.9),
            outcome("CR", 6.0, 0, 0.5),
            outcome("RR", 8.0, 0, 0.7),
        ];
        let rows = aggregate(&outs);
        assert_eq!(rows.len(), 3);
        let cr8 = rows
            .iter()
            .find(|r| r.schedule == "CR" && r.q_max == 8.0)
            .unwrap();
        assert!((cr8.metric_mean - 0.85).abs() < 1e-12);
        assert_eq!(cr8.trials, 2);
        assert!((cr8.gbitops - 1.5).abs() < 1e-12);
    }

    #[test]
    fn make_schedule_handles_baselines() {
        let s = make_schedule("STATIC", 3.0, 8.0, 100, 8).unwrap();
        assert_eq!(s.q_at(50), 8);
        let n = make_schedule("NONE", 3.0, 8.0, 100, 8).unwrap();
        assert_eq!(n.q_at(50), 32);
        let c = make_schedule("CR", 3.0, 8.0, 100, 8).unwrap();
        assert!(c.q_at(0) < 8);
        assert!(make_schedule("BOGUS", 3.0, 8.0, 100, 8).is_err());
    }

    #[test]
    fn sweep_spec_defaults_cover_suite_plus_static() {
        let spec = SweepSpec::new("mlp");
        assert_eq!(spec.schedules.len(), 11);
        assert!(spec.schedules.contains(&"STATIC".to_string()));
        assert_eq!(spec.q_maxes, vec![6.0, 8.0]);
    }
}

//! Persistent content-addressed AOT executable cache.
//!
//! The per-worker executable LRU (rust/DESIGN-perf.md §6) dies with its
//! worker thread, so every new `cpt` process — a resumed shard, a claimer
//! that stole a lease, a re-run campaign, a second machine on a shared
//! run dir — pays full cold XLA compiles again. This module is the level
//! below that LRU: an on-disk store of serialized executables keyed by
//! model fingerprint + cpt code version + backend platform + payload
//! codec, shared safely between concurrent workers and processes.
//!
//! Layout (manifest-plus-payload, one directory per entry):
//!
//! ```text
//! <cache-dir>/
//!   aot-cache.json            marker: identifies the dir + schema version
//!   <entry-id>/               entry-id = FNV-1a 64 of the full cache key
//!     aot-manifest.json       commit point (util::publish_exclusive)
//!     <tag>.<checksum>.bin    one payload per compiled entry point
//!     last-used               recency stamp (mtime feeds LRU eviction)
//! ```
//!
//! Publication order is the crash-safety argument: payload files are
//! written first via `util::write_atomic` (checksum-bearing names, so
//! racing publishers of identical content collide harmlessly), and the
//! manifest is committed last via `util::publish_exclusive` — among any
//! number of concurrent publishers across processes, exactly one wins,
//! and an entry is visible only when complete. Losers delete their own
//! unreferenced payload files.
//!
//! `load` validates everything against the caller's key — manifest kind,
//! schema version, cpt version, platform, codec, fingerprint, per-payload
//! length and checksum — and any failure is a plain miss, never an error
//! and never stale bytes. One consequence: because `publish_exclusive`
//! cannot replace an existing manifest, a damaged entry poisons its key
//! (every load misses, every republish loses) until `gc` removes it —
//! `gc` is the heal path, not just the space reclaimer.
//!
//! The cache is an execution knob: it never enters any spec or campaign
//! hash and never fences resume/merge, so results are byte-identical
//! with the cache enabled, disabled, or corrupted mid-run.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::time::SystemTime;

use anyhow::{bail, ensure, Context, Result};

use super::store::{GcStats, RunStore};
use crate::util::hash::{fnv1a64_hex, Fnv1a64};
use crate::util::json::{num, obj, s, Json};
use crate::util::{publish_exclusive, write_atomic};

/// Bump when the entry layout changes; older entries become misses.
const AOT_SCHEMA_VERSION: usize = 1;
const MARKER_FILE: &str = "aot-cache.json";
const MARKER_KIND: &str = "cpt-aot-cache";
const ENTRY_MANIFEST: &str = "aot-manifest.json";
const ENTRY_KIND: &str = "cpt-aot-entry";
const LAST_USED: &str = "last-used";

/// Payload codec for PJRT executable bytes. Part of the cache key, so a
/// future serialization format coexists with old entries instead of
/// misreading them.
pub const CODEC_PJRT: &str = "pjrt-exe-v1";

/// The full invalidation fence for one cached executable set. Any
/// component changing — model content, cpt build, backend platform,
/// payload format — addresses a different entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AotKey {
    /// `store::model_fingerprint` of the spec (metadata + HLO bytes).
    pub fingerprint: String,
    /// The cpt build that produced the bytes (`RunStore::code_version`).
    pub cpt_version: String,
    /// PJRT platform name (e.g. "cpu") — executables are target-specific.
    pub platform: String,
    /// Payload serialization format, e.g. [`CODEC_PJRT`].
    pub codec: String,
}

impl AotKey {
    /// Key for the current cpt build.
    pub fn new(fingerprint: &str, platform: &str, codec: &str) -> AotKey {
        AotKey {
            fingerprint: fingerprint.to_string(),
            cpt_version: RunStore::code_version().to_string(),
            platform: platform.to_string(),
            codec: codec.to_string(),
        }
    }

    /// Content address of this key: the entry directory name. Collisions
    /// are harmless — `load` re-checks every key component against the
    /// manifest, so a colliding entry is a miss, not a wrong answer.
    pub fn entry_id(&self) -> String {
        let mut h = Fnv1a64::new();
        for part in [
            "cpt-aot-v1",
            self.fingerprint.as_str(),
            self.cpt_version.as_str(),
            self.platform.as_str(),
            self.codec.as_str(),
        ] {
            h.update(&(part.len() as u64).to_le_bytes());
            h.update(part.as_bytes());
        }
        h.finish_hex()
    }
}

/// One manifest payload reference.
struct PayloadRef {
    tag: String,
    file: String,
    bytes: usize,
    checksum: String,
}

/// Parsed + structurally validated entry manifest.
struct EntryManifest {
    cpt_version: String,
    platform: String,
    codec: String,
    model: String,
    fingerprint: String,
    payloads: Vec<PayloadRef>,
}

/// Handle on a cache directory. Cheap to open per worker/process; all
/// cross-writer coordination happens through the filesystem primitives.
pub struct AotStore {
    dir: PathBuf,
}

impl AotStore {
    /// Open (creating if needed) a cache directory. The marker file is
    /// informational provenance — it makes `cpt gc` able to tell a cache
    /// dir from a run dir — and is published once, tolerantly: a damaged
    /// marker never blocks the cache.
    pub fn open(dir: &Path) -> Result<AotStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create aot cache dir {}", dir.display()))?;
        let marker = obj(vec![
            ("kind", s(MARKER_KIND)),
            ("schema_version", num(AOT_SCHEMA_VERSION as f64)),
            ("created_by_cpt", s(RunStore::code_version())),
            ("created_by_pid", num(std::process::id() as f64)),
            ("created_unix", num(unix_now())),
        ]);
        publish_exclusive(
            dir.join(MARKER_FILE),
            marker.to_string_pretty().as_bytes(),
        )?;
        Ok(AotStore { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look up `key`. Returns the validated `(tag, bytes)` payloads, or
    /// `None` on any miss — absent, damaged, or built by a different
    /// cpt/platform/codec. Never an error: the caller's fallback is a
    /// plain compile. A hit refreshes the entry's recency stamp.
    pub fn load(&self, key: &AotKey) -> Option<Vec<(String, Vec<u8>)>> {
        let payloads = self.load_checked(key).ok()?;
        let _ = write_atomic(
            self.dir.join(key.entry_id()).join(LAST_USED),
            b"",
        );
        Some(payloads)
    }

    fn load_checked(&self, key: &AotKey) -> Result<Vec<(String, Vec<u8>)>> {
        let edir = self.dir.join(key.entry_id());
        let m = read_entry_manifest(&edir)?;
        ensure!(
            m.fingerprint == key.fingerprint,
            "fingerprint mismatch: entry has {}, key wants {}",
            m.fingerprint,
            key.fingerprint
        );
        ensure!(
            m.cpt_version == key.cpt_version,
            "built by cpt {} (this key wants {})",
            m.cpt_version,
            key.cpt_version
        );
        ensure!(
            m.platform == key.platform,
            "built for platform '{}' (this key wants '{}')",
            m.platform,
            key.platform
        );
        ensure!(
            m.codec == key.codec,
            "payload codec '{}' (this key wants '{}')",
            m.codec,
            key.codec
        );
        read_payloads(&edir, &m)
    }

    /// Publish the compiled payloads for `key`. Returns `true` if this
    /// caller committed the entry, `false` if a racing publisher (or an
    /// earlier run) already did — in which case this caller's staged
    /// payload files are cleaned up where identifiable.
    pub fn publish(
        &self,
        key: &AotKey,
        model: &str,
        payloads: &[(String, Vec<u8>)],
    ) -> Result<bool> {
        ensure!(!payloads.is_empty(), "aot publish: empty payload set");
        let edir = self.dir.join(key.entry_id());
        let manifest_path = edir.join(ENTRY_MANIFEST);
        if manifest_path.exists() {
            return Ok(false);
        }
        let mut refs = Vec::with_capacity(payloads.len());
        let mut written = Vec::with_capacity(payloads.len());
        for (tag, bytes) in payloads {
            ensure!(
                !tag.is_empty()
                    && tag.bytes().all(|b| {
                        b.is_ascii_alphanumeric() || b == b'_' || b == b'-'
                    }),
                "aot publish: invalid payload tag {tag:?}"
            );
            let ck = fnv1a64_hex(bytes);
            // checksum-bearing name: racing publishers of the same
            // content write the same file, so the later write_atomic
            // just replaces identical bytes
            let file = format!("{tag}.{ck}.bin");
            write_atomic(edir.join(&file), bytes)?;
            written.push(file.clone());
            refs.push(obj(vec![
                ("tag", s(tag)),
                ("file", s(&file)),
                ("bytes", num(bytes.len() as f64)),
                ("checksum", s(&ck)),
            ]));
        }
        let doc = obj(vec![
            ("kind", s(ENTRY_KIND)),
            ("schema_version", num(AOT_SCHEMA_VERSION as f64)),
            ("cpt_version", s(&key.cpt_version)),
            ("platform", s(&key.platform)),
            ("codec", s(&key.codec)),
            ("model", s(model)),
            ("model_fingerprint", s(&key.fingerprint)),
            ("created_by_pid", num(std::process::id() as f64)),
            ("created_unix", num(unix_now())),
            ("payloads", Json::Arr(refs)),
        ]);
        let won = publish_exclusive(
            &manifest_path,
            doc.to_string_pretty().as_bytes(),
        )?;
        if won {
            let _ = write_atomic(edir.join(LAST_USED), b"");
        } else if let Ok(winner) = read_entry_manifest(&edir) {
            // a racing publisher committed first — drop our payload
            // files the winning manifest does not reference
            let keep: HashSet<&str> =
                winner.payloads.iter().map(|p| p.file.as_str()).collect();
            for f in &written {
                if !keep.contains(f.as_str()) {
                    std::fs::remove_file(edir.join(f)).ok();
                }
            }
        }
        Ok(won)
    }

    /// Inventory for `cpt cache status`: every entry with its size and,
    /// where an entry cannot serve this build, the reason.
    pub fn status(&self) -> Result<CacheStatus> {
        let mut entries = Vec::new();
        for edir in entry_dirs(&self.dir)? {
            let id = edir
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            let bytes = dir_size(&edir)?;
            match read_entry_manifest(&edir)
                .and_then(|m| read_payloads(&edir, &m).map(|_| m))
            {
                Ok(m) => {
                    let problem = if m.cpt_version != RunStore::code_version()
                    {
                        Some(format!(
                            "built by cpt {} (this build is {})",
                            m.cpt_version,
                            RunStore::code_version()
                        ))
                    } else {
                        None
                    };
                    entries.push(CacheEntryInfo {
                        id,
                        model: m.model,
                        platform: m.platform,
                        cpt_version: m.cpt_version,
                        payloads: m.payloads.len(),
                        bytes,
                        problem,
                    });
                }
                Err(err) => entries.push(CacheEntryInfo {
                    id,
                    model: "?".into(),
                    platform: "?".into(),
                    cpt_version: "?".into(),
                    payloads: 0,
                    bytes,
                    problem: Some(format!("damaged: {err:#}")),
                }),
            }
        }
        entries.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(CacheStatus { total_bytes: dir_size(&self.dir)?, entries })
    }

    /// `cpt gc` / `cpt cache gc` over a cache dir: sweep orphaned `.tmp`
    /// staging files, remove damaged entries (healing their poisoned
    /// keys — see the module docs), then evict least-recently-used valid
    /// entries until the total payload size fits under `cap` bytes.
    /// Like every gc here, only call on quiescent trees: a live writer's
    /// staging file or freshly-used entry is indistinguishable from an
    /// orphan or a cold one.
    pub fn gc(&self, cap: Option<u64>) -> Result<GcStats> {
        let mut stats = GcStats {
            bytes_before: dir_size(&self.dir)?,
            ..GcStats::default()
        };
        stats.orphaned_tmp = super::store::sweep_orphaned_tmp(&self.dir)?;
        let mut live: Vec<(PathBuf, SystemTime, u64)> = Vec::new();
        for edir in entry_dirs(&self.dir)? {
            match read_entry_manifest(&edir)
                .and_then(|m| read_payloads(&edir, &m).map(|_| m))
            {
                Ok(m) => {
                    remove_unreferenced(&edir, &m);
                    stats.cells += 1;
                    let sz = dir_size(&edir)?;
                    live.push((edir, recency(&live_stamp(&edir)), sz));
                }
                Err(err) => {
                    crate::log_warn!(
                        "[gc] note: aot entry {} damaged ({err:#}); removed",
                        edir.display()
                    );
                    std::fs::remove_dir_all(&edir).with_context(|| {
                        format!("remove {}", edir.display())
                    })?;
                    stats.evicted += 1;
                }
            }
        }
        if let Some(cap) = cap {
            live.sort_by_key(|(_, used, _)| *used);
            let mut total: u64 = live.iter().map(|(_, _, sz)| *sz).sum();
            for (edir, _, sz) in &live {
                if total <= cap {
                    break;
                }
                std::fs::remove_dir_all(edir).with_context(|| {
                    format!("remove {}", edir.display())
                })?;
                total -= sz;
                stats.evicted += 1;
                stats.cells -= 1;
            }
        }
        stats.bytes_after = dir_size(&self.dir)?;
        Ok(stats)
    }
}

/// One row of `cpt cache status`.
pub struct CacheEntryInfo {
    pub id: String,
    pub model: String,
    pub platform: String,
    pub cpt_version: String,
    pub payloads: usize,
    pub bytes: u64,
    /// Why this build would not (or could not) load the entry; `None`
    /// for a servable entry.
    pub problem: Option<String>,
}

pub struct CacheStatus {
    pub entries: Vec<CacheEntryInfo>,
    pub total_bytes: u64,
}

/// Whether `dir` is an AOT cache dir (so `cpt gc` can route it here
/// instead of treating it as a run dir).
pub fn is_cache_dir(dir: &Path) -> bool {
    dir.join(MARKER_FILE).is_file()
}

/// `CPT_AOT_CACHE`: cache directory. Strict-parsed like every env knob —
/// unset is `None`, an unusable value fails loudly.
pub fn cache_dir_from_env() -> Result<Option<PathBuf>> {
    super::env_parse::<PathBuf>("CPT_AOT_CACHE")
}

/// `CPT_AOT_CACHE_CAP`: byte budget for `gc` eviction. Unset means no
/// cap; an unparsable value fails loudly.
pub fn cache_cap_from_env() -> Result<Option<u64>> {
    super::env_parse::<u64>("CPT_AOT_CACHE_CAP")
}

/// The store the executors should run with: `None` when `CPT_AOT_CACHE`
/// is unset, and also — with a one-time note — when the backend cannot
/// serialize executables at all (the capability probe), so a configured
/// cache degrades to plain compiles instead of failing.
pub fn store_for_run() -> Result<Option<AotStore>> {
    let Some(dir) = cache_dir_from_env()? else {
        return Ok(None);
    };
    if let Err(reason) = crate::runtime::exec_serialization_support() {
        static NOTE: std::sync::Once = std::sync::Once::new();
        NOTE.call_once(|| {
            crate::log_warn!(
                "[aot] note: CPT_AOT_CACHE is set but this backend cannot \
                 serialize executables ({reason}); falling back to plain \
                 compiles"
            );
        });
        return Ok(None);
    }
    AotStore::open(&dir).map(Some)
}

// ---- internals -----------------------------------------------------------

fn unix_now() -> f64 {
    SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0)
}

fn read_entry_manifest(edir: &Path) -> Result<EntryManifest> {
    let path = edir.join(ENTRY_MANIFEST);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(&text)
        .with_context(|| format!("parse {}", path.display()))?;
    let kind = v.get("kind")?.as_str()?;
    ensure!(kind == ENTRY_KIND, "not an aot entry manifest (kind '{kind}')");
    let schema = v.get("schema_version")?.as_usize()?;
    ensure!(
        schema == AOT_SCHEMA_VERSION,
        "schema version {schema} (this build reads {AOT_SCHEMA_VERSION})"
    );
    let mut payloads = Vec::new();
    for p in v.get("payloads")?.as_arr()? {
        let file = p.get("file")?.as_str()?.to_string();
        // manifest data must never escape the entry dir
        ensure!(
            !file.is_empty()
                && !file.contains('/')
                && !file.contains('\\')
                && file != "."
                && file != "..",
            "unsafe payload file name {file:?}"
        );
        payloads.push(PayloadRef {
            tag: p.get("tag")?.as_str()?.to_string(),
            file,
            bytes: p.get("bytes")?.as_usize()?,
            checksum: p.get("checksum")?.as_str()?.to_string(),
        });
    }
    ensure!(!payloads.is_empty(), "entry manifest lists no payloads");
    Ok(EntryManifest {
        cpt_version: v.get("cpt_version")?.as_str()?.to_string(),
        platform: v.get("platform")?.as_str()?.to_string(),
        codec: v.get("codec")?.as_str()?.to_string(),
        model: v.get("model")?.as_str()?.to_string(),
        fingerprint: v.get("model_fingerprint")?.as_str()?.to_string(),
        payloads,
    })
}

/// Read and verify every payload (length + checksum) — the stale-bytes
/// fence. Any discrepancy is an error, which callers treat as a miss.
fn read_payloads(
    edir: &Path,
    m: &EntryManifest,
) -> Result<Vec<(String, Vec<u8>)>> {
    let mut out = Vec::with_capacity(m.payloads.len());
    for p in &m.payloads {
        let path = edir.join(&p.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("payload '{}' unreadable", p.tag))?;
        ensure!(
            bytes.len() == p.bytes,
            "payload '{}' truncated: {} of {} bytes",
            p.tag,
            bytes.len(),
            p.bytes
        );
        ensure!(
            fnv1a64_hex(&bytes) == p.checksum,
            "payload '{}' fails its checksum",
            p.tag
        );
        out.push((p.tag.clone(), bytes));
    }
    Ok(out)
}

/// Drop files in a valid entry dir that neither the manifest nor the
/// store itself references — residue of a losing publisher that could
/// not read the winner's manifest at the time.
fn remove_unreferenced(edir: &Path, m: &EntryManifest) {
    let Ok(entries) = std::fs::read_dir(edir) else { return };
    let keep: HashSet<&str> =
        m.payloads.iter().map(|p| p.file.as_str()).collect();
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        if name != ENTRY_MANIFEST
            && name != LAST_USED
            && !keep.contains(name)
        {
            std::fs::remove_file(e.path()).ok();
        }
    }
}

fn entry_dirs(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("read dir {}", dir.display()))?;
    for e in entries {
        let e = e.with_context(|| format!("read dir {}", dir.display()))?;
        if e.file_type()?.is_dir() {
            out.push(e.path());
        }
    }
    out.sort();
    Ok(out)
}

/// The file whose mtime carries an entry's recency: `last-used` when
/// present (touched on every hit), else the manifest itself.
fn live_stamp(edir: &Path) -> PathBuf {
    let stamp = edir.join(LAST_USED);
    if stamp.is_file() {
        stamp
    } else {
        edir.join(ENTRY_MANIFEST)
    }
}

fn recency(path: &Path) -> SystemTime {
    std::fs::metadata(path)
        .and_then(|m| m.modified())
        .unwrap_or(SystemTime::UNIX_EPOCH)
}

fn dir_size(dir: &Path) -> Result<u64> {
    if !dir.exists() {
        return Ok(0);
    }
    let mut total = 0u64;
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = std::fs::read_dir(&d)
            .with_context(|| format!("read dir {}", d.display()))?;
        for e in entries {
            let e = e.with_context(|| format!("read dir {}", d.display()))?;
            if e.file_type()?.is_dir() {
                stack.push(e.path());
            } else {
                total += e.metadata()?.len();
            }
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cpt_aot_{name}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn key(fp: &str) -> AotKey {
        AotKey::new(fp, "cpu", CODEC_PJRT)
    }

    fn payloads(seed: u8) -> Vec<(String, Vec<u8>)> {
        vec![
            ("init".into(), vec![seed; 64]),
            ("train_step".into(), (0..96).map(|i| i ^ seed).collect()),
        ]
    }

    /// Overwrite one field of an entry's manifest on disk — simulates an
    /// entry left behind by a different build/platform (the manifest is
    /// already published, so this is a direct rewrite, as corruption
    /// would be).
    fn rewrite_manifest_field(store: &AotStore, k: &AotKey, field: &str, v: Json) {
        let path = store.dir().join(k.entry_id()).join(ENTRY_MANIFEST);
        let mut doc =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        if let Json::Obj(m) = &mut doc {
            m.insert(field.into(), v);
        }
        std::fs::write(&path, doc.to_string_pretty()).unwrap();
    }

    fn payload_files(store: &AotStore, k: &AotKey) -> Vec<PathBuf> {
        let edir = store.dir().join(k.entry_id());
        let mut out: Vec<_> = std::fs::read_dir(&edir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().is_some_and(|x| x == "bin"))
            .collect();
        out.sort();
        out
    }

    #[test]
    fn publish_then_load_round_trips() {
        let dir = tmp("round_trip");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(7)).unwrap());
        assert_eq!(store.load(&k).unwrap(), payloads(7));
        // a different fingerprint is a clean miss
        assert!(store.load(&key("fp-other")).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn second_publish_loses_and_first_content_stands() {
        let dir = tmp("second_pub");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(1)).unwrap());
        assert!(!store.publish(&k, "mlp", &payloads(2)).unwrap());
        assert_eq!(store.load(&k).unwrap(), payloads(1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_is_a_miss_and_gc_heals_the_key() {
        let dir = tmp("truncated");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(3)).unwrap());
        let victim = &payload_files(&store, &k)[0];
        let bytes = std::fs::read(victim).unwrap();
        std::fs::write(victim, &bytes[..bytes.len() / 2]).unwrap();
        assert!(store.load(&k).is_none(), "truncated payload served");
        // the key is poisoned (manifest exists) until gc removes it...
        assert!(!store.publish(&k, "mlp", &payloads(3)).unwrap());
        let stats = store.gc(None).unwrap();
        assert_eq!(stats.evicted, 1);
        // ...after which a recompile can publish and serve again
        assert!(store.publish(&k, "mlp", &payloads(3)).unwrap());
        assert_eq!(store.load(&k).unwrap(), payloads(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flipped_payload_byte_fails_checksum() {
        let dir = tmp("flipped");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(4)).unwrap());
        let victim = &payload_files(&store, &k)[0];
        let mut bytes = std::fs::read(victim).unwrap();
        bytes[0] ^= 0xff; // same length, different content
        std::fs::write(victim, &bytes).unwrap();
        assert!(store.load(&k).is_none(), "corrupt payload served");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_schema_version_is_a_miss() {
        let dir = tmp("schema");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(5)).unwrap());
        rewrite_manifest_field(&store, &k, "schema_version", num(999.0));
        assert!(store.load(&k).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_cpt_version_is_a_miss() {
        let dir = tmp("cpt_version");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(6)).unwrap());
        rewrite_manifest_field(&store, &k, "cpt_version", s("0.0.0-other"));
        assert!(store.load(&k).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_platform_is_a_miss() {
        let dir = tmp("platform");
        let store = AotStore::open(&dir).unwrap();
        let k = key("fp-alpha");
        assert!(store.publish(&k, "mlp", &payloads(8)).unwrap());
        rewrite_manifest_field(&store, &k, "platform", s("tpu"));
        assert!(store.load(&k).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_publishers_admit_exactly_one_winner() {
        let dir = tmp("race");
        AotStore::open(&dir).unwrap();
        let k = key("fp-race");
        let wins: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8u8)
                .map(|i| {
                    let dir = dir.clone();
                    let k = k.clone();
                    scope.spawn(move || {
                        // each thread models its own process: fresh handle
                        let store = AotStore::open(&dir).unwrap();
                        store.publish(&k, "mlp", &payloads(i)).unwrap()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|&won| won)
                .count()
        });
        assert_eq!(wins, 1, "exactly one publisher must win");
        let store = AotStore::open(&dir).unwrap();
        let loaded = store.load(&k).expect("entry must be servable");
        assert_eq!(loaded.len(), 2, "complete payload set");
        // the winner's set is internally consistent: both payloads come
        // from the same seed
        let seed = loaded[0].1[0];
        assert_eq!(loaded, payloads(seed), "torn entry: mixed publishers");
        // losers cleaned up: entry holds only manifest + stamp + 2 payloads
        let edir = dir.join(k.entry_id());
        let mut names: Vec<_> = std::fs::read_dir(&edir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        names.sort();
        assert_eq!(names.len(), 4, "loser residue: {names:?}");
        assert!(!names.iter().any(|n| n.ends_with(".tmp")), "{names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_sweeps_tmp_and_evicts_lru_over_cap() {
        let dir = tmp("gc");
        let store = AotStore::open(&dir).unwrap();
        let (k1, k2, k3) = (key("fp-1"), key("fp-2"), key("fp-3"));
        for (k, seed) in [(&k1, 1u8), (&k2, 2), (&k3, 3)] {
            assert!(store.publish(k, "mlp", &payloads(seed)).unwrap());
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        // touch k1 so it is the most recently used despite oldest publish
        assert!(store.load(&k1).is_some());
        std::fs::write(dir.join("stale.123-0.tmp"), b"orphan").unwrap();
        // cap below two entries' payloads: evict k2 and k3, keep k1
        let one_entry = dir_size(&dir.join(k1.entry_id())).unwrap();
        let stats = store.gc(Some(one_entry + 16)).unwrap();
        assert_eq!(stats.orphaned_tmp, 1);
        assert_eq!(stats.evicted, 2);
        assert_eq!(stats.cells, 1);
        assert!(stats.bytes_after < stats.bytes_before);
        assert!(store.load(&k1).is_some(), "most-recent entry evicted");
        assert!(store.load(&k2).is_none());
        assert!(store.load(&k3).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn env_cap_strict_parses() {
        // sole test touching this env var, so no parallel-test races
        std::env::set_var("CPT_AOT_CACHE_CAP", "not-a-number");
        assert!(cache_cap_from_env().is_err(), "garbage cap must fail loudly");
        std::env::set_var("CPT_AOT_CACHE_CAP", "4096");
        assert_eq!(cache_cap_from_env().unwrap(), Some(4096));
        std::env::remove_var("CPT_AOT_CACHE_CAP");
        assert_eq!(cache_cap_from_env().unwrap(), None);
    }

    #[test]
    fn gc_on_empty_cache_is_clean() {
        let dir = tmp("empty");
        let store = AotStore::open(&dir).unwrap();
        assert!(is_cache_dir(&dir), "marker must identify the dir");
        let stats = store.gc(Some(0)).unwrap();
        assert_eq!(
            (stats.cells, stats.evicted, stats.orphaned_tmp),
            (0, 0, 0)
        );
        assert!(store.status().unwrap().entries.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Lease-based dynamic cell claiming: N cooperating `cpt` processes
//! ("claimers") divide one sweep or campaign dynamically through the
//! shared run directory, so the work finishes at the speed of the
//! surviving nodes — no static `--shard` split, no babysitting dead or
//! stalled workers.
//!
//! Layout added under each member run dir (and, for campaigns, a
//! process-liveness dir under the root):
//!
//! ```text
//! <member-dir>/
//!   claim/
//!     cells/00003.json           # commit entry: cell 3 is done (who,
//!                                #   artifact file, checksum, seconds)
//!     leases/00003.g1.json       # lease, generation 1 (claimer, deadline)
//!     leases/00003.g2.json       # generation 2: g1 expired and was stolen
//!   00003-CR-q6-t0.alice.json    # claimer-suffixed cell artifact
//! <root>/claim/workers/alice.json  # per-claimer liveness heartbeat
//! ```
//!
//! Protocol invariants:
//!
//! * **A lease file is the lock.** `{index:05}.g{generation}.json` is
//!   created with [`publish_exclusive`] (hard-link create-exclusive), so
//!   exactly one claimer can hold any generation. The *current* lease is
//!   the highest generation on file; lease files are never deleted, so
//!   there is no remove/recreate race window.
//! * **Heartbeats extend, steals supersede.** A live claimer rewrites its
//!   current-generation lease (atomic rename) with a fresh deadline every
//!   lease/4 seconds. Once the deadline passes, any claimer may publish
//!   generation+1 — the steal. The previous holder is *fenced*, not
//!   killed: if it wakes up it discovers the higher generation and
//!   refuses to commit.
//! * **The commit entry is the single commit point.** A finished cell is
//!   recorded by hard-linking `claim/cells/{index:05}.json` — again
//!   create-exclusive, so a cell can never be committed twice. The
//!   artifact is written first, under a claimer-suffixed name so two
//!   racing writers can never tear each other's bytes; the loser deletes
//!   its own artifact. A claimer checks its lease is still current
//!   *before* writing anything, and the entry link is atomic, so a cell
//!   stolen mid-run ends with exactly one entry and one referenced
//!   artifact.
//! * **Finalize rebuilds the ordinary manifest.** When every cell has a
//!   commit entry, each finishing claimer rewrites `run-manifest.json`
//!   (shard 1/1) from the entries — identical inputs, identical bytes,
//!   so the last-writer race is benign — and loads all outcomes
//!   checksum-verified. Every claimer that finishes reports the complete
//!   result, and downstream `cpt status` / `cpt gc` / `cpt merge` / CSV
//!   reports see a perfectly normal run directory.
//!
//! Fault injection for tests and `scripts/check.sh`: CPT_HALT_AFTER_CELLS
//! kills a claimer after N fresh cells (the shared crash knob), and
//! CPT_STALL_AFTER_CELLS/CPT_STALL_SECS hangs one — heartbeats stop, its
//! leases expire, a peer steals them, and its late commits are refused.
//! The [`Clock`] trait makes lease expiry unit-testable without sleeping.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{bail, Context, Result};

use super::aot;
use super::campaign::{
    self, CampaignPlan, CampaignRunOpts, CampaignRunResult, MemberOutcome,
    SchedulerKind, SchedulerStats,
};
use super::exec::{
    self, CellRunner, CellSink, ExecItem, ExecMember, ExecRequest, ExecStats,
    ItemSource, Recorded, Refill,
};
use super::plan::{ClaimerId, ShardId, SweepPlan};
use super::store::{self, CellEntry, ManifestSummary, RunStore};
use super::{RunOutcome, SweepCell, SweepSpec, SweepTiming};
use crate::obs::metrics;
use crate::obs::trace::{self, Event};
use crate::runtime::{Manifest, ModelSpec};
use crate::util::hash::fnv1a64_hex;
use crate::util::json::{num, obj, s, Json};
use crate::util::{publish_exclusive, write_atomic};

/// Coordination subdirectory under a member run dir (and the campaign
/// root, for the workers dir). The name is reserved by
/// [`ClaimerId::parse`] so it can never collide with a member name.
pub const CLAIM_DIR: &str = "claim";
const CELLS_DIR: &str = "cells";
const LEASES_DIR: &str = "leases";
const WORKERS_DIR: &str = "workers";
const LEASE_KIND: &str = "cpt-lease";
const CELL_ENTRY_KIND: &str = "cpt-claim-cell";
const WORKER_KIND: &str = "cpt-claim-worker";

// ---- clock --------------------------------------------------------------

/// Wall-clock source for lease deadlines. Injectable so expiry and
/// stealing are unit-testable without real sleeps; production uses
/// [`SystemClock`]. Deadlines are absolute UNIX seconds, comparable
/// across machines that share a filesystem (NFS-style fleets), with the
/// usual caveat that lease durations must dominate clock skew.
pub trait Clock: Send + Sync {
    fn now(&self) -> f64;
}

/// UNIX-epoch seconds from the system clock.
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> f64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    }
}

/// Manually advanced clock for tests (stores f64 bits atomically so the
/// heartbeat thread and the test body can share it).
pub struct TestClock(AtomicU64);

impl TestClock {
    pub fn new(t: f64) -> TestClock {
        TestClock(AtomicU64::new(t.to_bits()))
    }

    pub fn set(&self, t: f64) {
        self.0.store(t.to_bits(), Ordering::SeqCst);
    }

    pub fn advance(&self, dt: f64) {
        let mut cur = self.0.load(Ordering::SeqCst);
        loop {
            let next = (f64::from_bits(cur) + dt).to_bits();
            match self.0.compare_exchange(
                cur,
                next,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }
}

impl Clock for TestClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::SeqCst))
    }
}

// ---- configuration ------------------------------------------------------

/// Default poll interval for a given lease duration: a quarter of the
/// lease (so three heartbeats can be missed before expiry), clamped to
/// something humane.
fn default_poll(lease_secs: f64) -> f64 {
    (lease_secs / 4.0).clamp(0.1, 15.0)
}

/// Knobs for one claim session.
#[derive(Clone)]
pub struct ClaimConfig {
    /// This process's name on the claim board (lease records, liveness
    /// file, artifact suffix).
    pub claimer: ClaimerId,
    /// Lease duration: a claimer that misses heartbeats for this long is
    /// presumed dead and its cells become stealable.
    pub lease_secs: f64,
    /// How long to wait between claim-board polls when every uncommitted
    /// cell is actively leased elsewhere.
    pub poll_secs: f64,
    /// Deterministic hung-worker injection: after this many freshly
    /// committed cells, stop heartbeating and sleep `stall_secs` — a
    /// stand-in for a wedged process that holds leases but makes no
    /// progress (CPT_STALL_AFTER_CELLS).
    pub stall_after_cells: Option<usize>,
    pub stall_secs: f64,
    /// Run the background heartbeat thread (tests that drive the clock by
    /// hand turn it off so a lease can expire on cue).
    pub auto_heartbeat: bool,
    pub clock: Arc<dyn Clock>,
}

impl ClaimConfig {
    pub fn new(claimer: ClaimerId) -> ClaimConfig {
        ClaimConfig {
            claimer,
            lease_secs: 60.0,
            poll_secs: default_poll(60.0),
            stall_after_cells: None,
            stall_secs: 5.0,
            auto_heartbeat: true,
            clock: Arc::new(SystemClock),
        }
    }

    /// Build a config from the environment knobs, strictly: an unparsable
    /// or out-of-range value aborts the run instead of silently falling
    /// back (same contract as CPT_HALT_AFTER_CELLS).
    pub fn from_env(claimer: ClaimerId) -> Result<ClaimConfig> {
        let mut cfg = ClaimConfig::new(claimer);
        if let Some(v) = super::env_parse::<f64>("CPT_LEASE_SECS")? {
            if !v.is_finite() || v <= 0.0 {
                bail!("CPT_LEASE_SECS must be a positive number of seconds");
            }
            cfg.lease_secs = v;
            cfg.poll_secs = default_poll(v);
        }
        if let Some(v) = super::env_parse::<f64>("CPT_CLAIM_POLL_SECS")? {
            if !v.is_finite() || v <= 0.0 {
                bail!(
                    "CPT_CLAIM_POLL_SECS must be a positive number of seconds"
                );
            }
            cfg.poll_secs = v;
        }
        if let Some(n) = super::env_parse::<usize>("CPT_STALL_AFTER_CELLS")? {
            if n == 0 {
                bail!(
                    "CPT_STALL_AFTER_CELLS must be >= 1 (unset it to disable \
                     stall injection)"
                );
            }
            cfg.stall_after_cells = Some(n);
        }
        if let Some(v) = super::env_parse::<f64>("CPT_STALL_SECS")? {
            if !v.is_finite() || v < 0.0 {
                bail!("CPT_STALL_SECS must be a non-negative number of seconds");
            }
            cfg.stall_secs = v;
        }
        Ok(cfg)
    }
}

// ---- on-disk records ----------------------------------------------------

#[derive(Clone, Debug)]
struct LeaseRecord {
    claimer: String,
    generation: usize,
    /// Absolute clock seconds; past it the lease is steal-eligible.
    deadline: f64,
}

fn lease_file_name(index: usize, generation: usize) -> String {
    format!("{index:05}.g{generation}.json")
}

/// Parse `NNNNN.g<gen>.json`; `None` for anything else (in particular the
/// `*.tmp` staging files of in-flight atomic writes).
fn parse_lease_name(name: &str) -> Option<(usize, usize)> {
    let stem = name.strip_suffix(".json")?;
    let (index, generation) = stem.split_once(".g")?;
    Some((index.parse().ok()?, generation.parse().ok()?))
}

fn encode_lease(claimer: &str, generation: usize, deadline: f64) -> String {
    obj(vec![
        ("kind", s(LEASE_KIND)),
        ("claimer", s(claimer)),
        ("generation", num(generation as f64)),
        ("deadline", num(deadline)),
    ])
    .to_string_pretty()
}

fn read_lease(path: &Path) -> Result<LeaseRecord> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&src)
        .with_context(|| format!("parse {}", path.display()))?;
    if j.get("kind")?.as_str()? != LEASE_KIND {
        bail!("{}: not a cpt lease record", path.display());
    }
    Ok(LeaseRecord {
        claimer: j.get("claimer")?.as_str()?.to_string(),
        generation: j.get("generation")?.as_usize()?,
        deadline: j.get("deadline")?.as_f64()?,
    })
}

/// The highest-generation lease on file for `index`, if any. Generations
/// start at 1 and lease files are never deleted, so the maximum is the
/// authoritative current lease.
fn current_lease(leases_dir: &Path, index: usize) -> Result<Option<LeaseRecord>> {
    let rd = match std::fs::read_dir(leases_dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(anyhow::Error::from(e)
                .context(format!("read dir {}", leases_dir.display())))
        }
    };
    let mut best_gen = 0usize;
    let mut best_path: Option<PathBuf> = None;
    for e in rd {
        let e = e
            .with_context(|| format!("read dir {}", leases_dir.display()))?;
        let name = e.file_name();
        let Some((idx, generation)) =
            parse_lease_name(&name.to_string_lossy())
        else {
            continue;
        };
        if idx != index || generation <= best_gen {
            continue;
        }
        best_gen = generation;
        best_path = Some(e.path());
    }
    let Some(path) = best_path else { return Ok(None) };
    let rec = read_lease(&path)?;
    if rec.generation != best_gen {
        bail!(
            "{}: lease generation disagrees with its file name",
            path.display()
        );
    }
    Ok(Some(rec))
}

fn cell_entry_file(index: usize) -> String {
    format!("{index:05}.json")
}

fn encode_cell_entry(index: usize, claimer: &str, e: &CellEntry) -> String {
    let mut fields = vec![
        ("kind", s(CELL_ENTRY_KIND)),
        ("index", num(index as f64)),
        ("claimer", s(claimer)),
        ("file", s(&e.file)),
        ("checksum", s(&e.checksum)),
        ("seconds", num(e.seconds)),
    ];
    // optional keys mirror the manifest schema, so entries seeded from a
    // pre-policy manifest round-trip without fabricating zeros
    if let Some(mq) = e.mean_q {
        fields.push(("mean_q", num(mq)));
    }
    if let Some(rc) = e.realized_cost {
        fields.push(("realized_cost", num(rc)));
    }
    obj(fields).to_string_pretty()
}

/// The manifest-shaped entry of one commit-entry file. The `claimer` key
/// is on-disk provenance only; nothing in the protocol depends on it.
fn read_cell_entry(path: &Path, want_index: usize) -> Result<CellEntry> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&src)
        .with_context(|| format!("parse {}", path.display()))?;
    if j.get("kind")?.as_str()? != CELL_ENTRY_KIND {
        bail!("{}: not a cpt claim commit entry", path.display());
    }
    if j.get("index")?.as_usize()? != want_index {
        bail!("{}: entry index disagrees with its file name", path.display());
    }
    j.get("claimer")?.as_str()?; // provenance must at least be well-formed
    Ok(CellEntry {
        file: j.get("file")?.as_str()?.to_string(),
        checksum: j.get("checksum")?.as_str()?.to_string(),
        seconds: j.get("seconds")?.as_f64()?,
        mean_q: j.opt("mean_q").map(|v| v.as_f64()).transpose()?,
        realized_cost: j.opt("realized_cost").map(|v| v.as_f64()).transpose()?,
    })
}

/// All commit entries of one member, by cell index.
fn read_committed(cells_dir: &Path) -> Result<BTreeMap<usize, CellEntry>> {
    let mut out = BTreeMap::new();
    let rd = match std::fs::read_dir(cells_dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(anyhow::Error::from(e)
                .context(format!("read dir {}", cells_dir.display())))
        }
    };
    for e in rd {
        let e =
            e.with_context(|| format!("read dir {}", cells_dir.display()))?;
        let name = e.file_name();
        let name = name.to_string_lossy();
        let Some(stem) = name.strip_suffix(".json") else { continue };
        let Ok(index) = stem.parse::<usize>() else { continue };
        out.insert(index, read_cell_entry(&e.path(), index)?);
    }
    Ok(out)
}

fn encode_worker(
    claimer: &str,
    lease_secs: f64,
    started: f64,
    last_seen: f64,
) -> String {
    obj(vec![
        ("kind", s(WORKER_KIND)),
        ("claimer", s(claimer)),
        ("lease_secs", num(lease_secs)),
        ("started", num(started)),
        ("last_seen", num(last_seen)),
    ])
    .to_string_pretty()
}

// ---- claim session state ------------------------------------------------

/// One member of a claim session: the executor-facing description plus
/// everything the claim board needs (run dir, spec hash, full canonical
/// cell list — claim mode is always whole-plan, shard 1/1).
pub struct ClaimMember {
    pub exec: ExecMember,
    pub dir: PathBuf,
    pub spec_hash: String,
    pub cells: Vec<SweepCell>,
}

impl ClaimMember {
    fn cells_dir(&self) -> PathBuf {
        self.dir.join(CLAIM_DIR).join(CELLS_DIR)
    }

    fn leases_dir(&self) -> PathBuf {
        self.dir.join(CLAIM_DIR).join(LEASES_DIR)
    }
}

fn member_label(m: &ClaimMember) -> &str {
    if m.exec.name.is_empty() {
        &m.exec.model
    } else {
        &m.exec.name
    }
}

/// Mutable session bookkeeping, behind one mutex (touched briefly by the
/// refill path, the collector's record path, and `model_failed`).
struct ClaimInner {
    /// Per member: cell indices with a commit entry on disk (refreshed
    /// from the board every refill).
    committed: Vec<HashSet<usize>>,
    /// Items handed to the executor and not yet settled by the sink.
    enqueued: HashSet<(usize, usize)>,
    /// `(member, cell)` -> lease generation this process holds.
    held: HashMap<(usize, usize), usize>,
    /// Model fingerprint -> workers of this pool that permanently gave
    /// up compiling it.
    failures: HashMap<String, usize>,
    stolen: usize,
    committed_here: usize,
}

struct ClaimState {
    cfg: ClaimConfig,
    label: String,
    verbose: bool,
    jobs: usize,
    members: Vec<ClaimMember>,
    workers_dir: PathBuf,
    started: f64,
    inner: Mutex<ClaimInner>,
    /// Stall injection in progress: heartbeats and refills go dark so the
    /// leases can expire and a peer can steal them.
    suspended: AtomicBool,
    /// Freshly committed cells (drives the stall-injection trigger).
    fresh: AtomicUsize,
}

impl ClaimState {
    fn worker_file(&self) -> PathBuf {
        self.workers_dir.join(format!("{}.json", self.cfg.claimer))
    }

    fn touch_worker(&self) -> Result<()> {
        let now = self.cfg.clock.now();
        write_atomic(
            self.worker_file(),
            encode_worker(
                self.cfg.claimer.as_str(),
                self.cfg.lease_secs,
                self.started,
                now,
            )
            .as_bytes(),
        )
        .context("write claimer liveness file")
    }

    /// Extend every held lease to `now + lease_secs` and refresh the
    /// liveness file. Called from the heartbeat thread and at the top of
    /// every refill (so a slow poll loop cannot let its own leases
    /// lapse). Rewriting a lease we have meanwhile lost is harmless: the
    /// thief holds a higher generation, which stays current.
    fn extend_held(&self) -> Result<()> {
        if self.suspended.load(Ordering::SeqCst) {
            return Ok(());
        }
        let held: Vec<((usize, usize), usize)> = {
            let inner = self.inner.lock().unwrap();
            inner.held.iter().map(|(&k, &g)| (k, g)).collect()
        };
        let deadline = self.cfg.clock.now() + self.cfg.lease_secs;
        for ((mi, ci), generation) in held {
            let path = self.members[mi]
                .leases_dir()
                .join(lease_file_name(ci, generation));
            write_atomic(
                &path,
                encode_lease(self.cfg.claimer.as_str(), generation, deadline)
                    .as_bytes(),
            )
            .with_context(|| format!("heartbeat lease for cell {ci}"))?;
        }
        self.touch_worker()
    }
}

/// Background heartbeat: beat every lease/4 seconds, sleeping in short
/// slices so the stop flag is observed promptly when the run ends.
fn heartbeat_loop(state: &ClaimState, stop: &AtomicBool) {
    let period = Duration::from_secs_f64((state.cfg.lease_secs / 4.0).max(0.05));
    let slice = period.min(Duration::from_millis(25));
    let mut next = Instant::now() + period;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(slice);
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if Instant::now() < next {
            continue;
        }
        next = Instant::now() + period;
        match state.extend_held() {
            Ok(()) => {
                metrics::global().inc("lease.heartbeats", 1);
                if trace::enabled() {
                    trace::emit(Event::new(trace::now(), "lease_heartbeat"));
                    trace::flush(); // this thread has no cell boundary
                }
            }
            Err(e) => {
                crate::log_warn!(
                    "[{}] note: heartbeat failed: {e:#}",
                    state.label
                );
            }
        }
    }
}

// ---- the item source (claiming) -----------------------------------------

struct ClaimSource<'a> {
    state: &'a ClaimState,
}

impl ItemSource for ClaimSource<'_> {
    fn refill(&self) -> Result<Refill> {
        let st = self.state;
        if st.suspended.load(Ordering::SeqCst) {
            // stall injection: make no progress and extend nothing
            return Ok(Refill::Wait(Duration::from_secs_f64(st.cfg.poll_secs)));
        }
        st.extend_held()?;
        let now = st.cfg.clock.now();
        let me = st.cfg.claimer.as_str();
        // claim at most a small multiple of the pool size per round, so
        // one claimer does not hoard leases it will sit on for minutes
        let budget = (st.jobs * 2).max(2);
        let mut items: Vec<ExecItem> = Vec::new();
        let mut uncommitted = 0usize;
        let mut claimable_later = 0usize;
        let mut inner = st.inner.lock().unwrap();
        let inner = &mut *inner;
        for (mi, member) in st.members.iter().enumerate() {
            // refresh this member's committed set from the board (peers
            // commit concurrently), releasing our bookkeeping for cells
            // that are now settled
            for &ci in read_committed(&member.cells_dir())?.keys() {
                if inner.committed[mi].insert(ci) {
                    inner.held.remove(&(mi, ci));
                    inner.enqueued.remove(&(mi, ci));
                }
            }
            let dead = inner
                .failures
                .get(member.exec.fingerprint.as_str())
                .is_some_and(|&n| n >= st.jobs);
            for ci in 0..member.cells.len() {
                if inner.committed[mi].contains(&ci) {
                    continue;
                }
                uncommitted += 1;
                if dead {
                    // no worker in this process can run it; progress only
                    // counts if a peer holds a live lease on it
                    if current_lease(&member.leases_dir(), ci)?
                        .is_some_and(|l| l.deadline > now && l.claimer != me)
                    {
                        claimable_later += 1;
                    }
                    continue;
                }
                claimable_later += 1;
                if inner.enqueued.contains(&(mi, ci))
                    || items.len() >= budget
                {
                    continue;
                }
                let lease = current_lease(&member.leases_dir(), ci)?;
                let next_gen = match &lease {
                    Some(l) if l.deadline > now => continue, // live elsewhere
                    Some(l) => l.generation + 1,
                    None => 1,
                };
                let path =
                    member.leases_dir().join(lease_file_name(ci, next_gen));
                let bytes =
                    encode_lease(me, next_gen, now + st.cfg.lease_secs);
                if !publish_exclusive(&path, bytes.as_bytes())? {
                    continue; // a peer won this generation first
                }
                match &lease {
                    Some(l) => {
                        inner.stolen += 1;
                        metrics::global().inc("lease.stolen", 1);
                        if trace::enabled() {
                            trace::emit(
                                Event::new(now, "lease_steal")
                                    .member(mi)
                                    .cell(ci)
                                    .tag_str("from", &l.claimer)
                                    .tag_num(
                                        "generation",
                                        next_gen as f64,
                                    ),
                            );
                        }
                        crate::log_debug!(
                            "[{}] claimer '{me}' stole cell {ci} of '{}' \
                             from '{}' (lease generation {} expired)",
                            st.label,
                            member_label(member),
                            l.claimer,
                            l.generation
                        );
                    }
                    None => {
                        metrics::global().inc("lease.acquired", 1);
                        if trace::enabled() {
                            trace::emit(
                                Event::new(now, "lease_acquire")
                                    .member(mi)
                                    .cell(ci)
                                    .tag_num(
                                        "generation",
                                        next_gen as f64,
                                    ),
                            );
                        }
                        crate::log_debug!(
                            "[{}] claimer '{me}' acquired cell {ci} of '{}' \
                             (generation {next_gen})",
                            st.label,
                            member_label(member)
                        );
                    }
                }
                inner.held.insert((mi, ci), next_gen);
                inner.enqueued.insert((mi, ci));
                items.push(ExecItem {
                    member: mi,
                    cell_index: ci,
                    slot: ci,
                    cell: member.cells[ci].clone(),
                });
            }
        }
        if uncommitted == 0 {
            return Ok(Refill::Exhausted);
        }
        if !items.is_empty() {
            if st.verbose {
                crate::log_info!(
                    "[{}] claimer '{me}' claimed {} cell(s) \
                     ({uncommitted} uncommitted overall)",
                    st.label,
                    items.len()
                );
            }
            return Ok(Refill::Items(items));
        }
        if claimable_later == 0 {
            bail!(
                "{uncommitted} cell(s) remain uncommitted but every one \
                 needs a model no worker in this process can compile, and \
                 no other claimer holds a live lease on them"
            );
        }
        Ok(Refill::Wait(Duration::from_secs_f64(st.cfg.poll_secs)))
    }

    fn model_failed(&self, fingerprint: &str) {
        let st = self.state;
        let mine: Vec<((usize, usize), usize)> = {
            let mut inner = st.inner.lock().unwrap();
            let n = inner.failures.entry(fingerprint.to_string()).or_insert(0);
            *n += 1;
            if *n < st.jobs {
                return;
            }
            let mine: Vec<((usize, usize), usize)> = inner
                .held
                .iter()
                .filter(|(k, _)| {
                    st.members[k.0].exec.fingerprint == fingerprint
                })
                .map(|(&k, &g)| (k, g))
                .collect();
            for (k, _) in &mine {
                inner.held.remove(k);
            }
            mine
        };
        // every worker gave up on this model: expire the leases we hold
        // on its cells so peers that *can* compile it take over now,
        // not a lease period from now
        let expired = st.cfg.clock.now() - 1.0;
        for ((mi, ci), generation) in &mine {
            let path = st.members[*mi]
                .leases_dir()
                .join(lease_file_name(*ci, *generation));
            let bytes =
                encode_lease(st.cfg.claimer.as_str(), *generation, expired);
            if let Err(e) = write_atomic(&path, bytes.as_bytes()) {
                crate::log_warn!(
                    "[{}] note: failed to release lease for cell {ci}: {e:#}",
                    st.label
                );
            }
        }
        crate::log_warn!(
            "[{}] note: no worker in this process can compile \
             '{fingerprint}'; released {} lease(s) for other claimers",
            st.label,
            mine.len()
        );
    }
}

// ---- the cell sink (fenced commit) --------------------------------------

/// Account one refused commit: metrics counter, trace event, and a
/// debug line (refusals are normal in claim mode — a peer got there
/// first — so they stay out of the default log level).
fn lease_refuse(st: &ClaimState, member: usize, cell: usize, why: &str) {
    metrics::global().inc("lease.refused", 1);
    if trace::enabled() {
        trace::emit(
            Event::new(st.cfg.clock.now(), "lease_refuse")
                .member(member)
                .cell(cell)
                .tag_str("why", why),
        );
    }
    crate::log_debug!(
        "[{}] claimer '{}' refused commit of cell {cell} ({why})",
        st.label,
        st.cfg.claimer
    );
}

struct ClaimSink<'a> {
    state: &'a ClaimState,
    member: usize,
}

impl CellSink for ClaimSink<'_> {
    fn record_cell(&mut self, index: usize, out: &RunOutcome) -> Result<Recorded> {
        let st = self.state;
        let member = &st.members[self.member];
        let key = (self.member, index);
        let my_gen = {
            let mut inner = st.inner.lock().unwrap();
            inner.enqueued.remove(&key);
            inner.held.get(&key).copied()
        };
        let Some(my_gen) = my_gen else {
            // settled while in flight (a peer committed it and a refill
            // observed that) — nothing of ours to write
            lease_refuse(st, self.member, index, "no_lease");
            return Ok(Recorded::Refused("no lease held for this cell".into()));
        };
        // Fencing: commit only under the *current* lease. If a higher
        // generation exists, we stalled past our deadline and were stolen
        // from — the cell belongs to the thief, write nothing.
        let current = current_lease(&member.leases_dir(), index)?;
        let lost = match &current {
            Some(l) => {
                l.generation != my_gen || l.claimer != st.cfg.claimer.as_str()
            }
            None => true, // can't happen (leases are never deleted), but fail safe
        };
        if lost {
            st.inner.lock().unwrap().held.remove(&key);
            let who = current
                .map(|l| {
                    format!("'{}' (lease generation {})", l.claimer, l.generation)
                })
                .unwrap_or_else(|| "an unknown claimer".into());
            lease_refuse(st, self.member, index, "lease_lost");
            return Ok(Recorded::Refused(format!("lease lost to {who}")));
        }
        // Artifact first, claimer-suffixed so racing writers can never
        // tear each other's bytes; then the commit entry — the hard link
        // is the one atomic commit point.
        let file = format!(
            "{index:05}-{}-q{}-t{}.{}.json",
            out.schedule, out.q_max, out.trial, st.cfg.claimer
        );
        let bytes = store::encode_cell_artifact(&member.spec_hash, index, out);
        write_atomic(member.dir.join(&file), bytes.as_bytes())
            .with_context(|| format!("write artifact for cell {index}"))?;
        let entry = CellEntry {
            file: file.clone(),
            checksum: fnv1a64_hex(bytes.as_bytes()),
            seconds: out.exec_seconds,
            mean_q: Some(out.mean_q),
            realized_cost: Some(out.realized_cost),
        };
        let doc = encode_cell_entry(index, st.cfg.claimer.as_str(), &entry);
        let won = publish_exclusive(
            member.cells_dir().join(cell_entry_file(index)),
            doc.as_bytes(),
        )?;
        {
            let mut inner = st.inner.lock().unwrap();
            inner.committed[self.member].insert(index);
            inner.held.remove(&key);
            if won {
                inner.committed_here += 1;
            }
        }
        if !won {
            // a peer stole our expired lease, finished, and committed in
            // the window since the fence check; its entry is the cell —
            // delete our unreferenced artifact
            std::fs::remove_file(member.dir.join(&file)).ok();
            lease_refuse(st, self.member, index, "commit_race");
            return Ok(Recorded::Refused(
                "committed by another claimer first".into(),
            ));
        }
        metrics::global().inc("lease.committed", 1);
        if let Some(n) = st.cfg.stall_after_cells {
            if st.fresh.fetch_add(1, Ordering::SeqCst) + 1 == n
                && st.cfg.stall_secs > 0.0
            {
                // deterministic hung worker: go dark (no heartbeats, no
                // claims) long enough for our leases to expire and be
                // stolen, then wake up and discover the theft
                st.suspended.store(true, Ordering::SeqCst);
                crate::log_info!(
                    "[{}] claimer '{}' stalling {:.1}s after {n} committed \
                     cell(s) (CPT_STALL_AFTER_CELLS injection)",
                    st.label, st.cfg.claimer, st.cfg.stall_secs
                );
                std::thread::sleep(Duration::from_secs_f64(st.cfg.stall_secs));
                st.suspended.store(false, Ordering::SeqCst);
            }
        }
        Ok(Recorded::Stored)
    }
}

// ---- seeding, finalizing ------------------------------------------------

/// Import a prior (static-mode) run's recorded cells as commit entries,
/// so a claim session over a directory that already holds progress keeps
/// it instead of recomputing — and so the finalizer's rebuilt manifest
/// can never lose cells the old manifest had. Invalid artifacts are
/// skipped (recomputed), exactly like the resume path.
fn seed_from_manifest(member: &ClaimMember, me: &ClaimerId) -> Result<()> {
    if !member.dir.join(store::MANIFEST_FILE).exists() {
        return Ok(());
    }
    let ms = store::read_manifest(&member.dir)?;
    if ms.spec_hash != member.spec_hash {
        // defensive: the wrapper's RunStore::open fence already refused
        bail!(
            "run dir {} belongs to a different sweep spec (manifest {}, \
             requested {})",
            member.dir.display(),
            ms.spec_hash,
            member.spec_hash
        );
    }
    let cells_dir = member.cells_dir();
    for (&index, e) in &ms.cells {
        let entry_path = cells_dir.join(cell_entry_file(index));
        if entry_path.exists() {
            continue;
        }
        // validate before seeding: a corrupt artifact must be recomputed,
        // not laundered into a commit entry
        if let Err(err) = store::load_artifact(
            &member.dir.join(&e.file),
            &e.checksum,
            &ms.spec_hash,
            index,
        ) {
            crate::log_warn!(
                "[lease] note: cell {index} artifact invalid ({err:#}); it \
                 will be recomputed"
            );
            continue;
        }
        let doc = encode_cell_entry(index, me.as_str(), e);
        publish_exclusive(&entry_path, doc.as_bytes())?;
    }
    Ok(())
}

/// Rebuild the member's ordinary `run-manifest.json` (shard 1/1) from the
/// commit entries and load every outcome checksum-verified. All finishing
/// claimers derive identical manifests from identical entries, so the
/// last-writer race is benign. When an existing manifest references the
/// same artifact file as an entry, its checksum wins — `cpt gc` rewrites
/// artifacts and manifest checksums without touching commit entries, so
/// the manifest is the fresher truth for compacted cells.
fn finalize_member(member: &ClaimMember) -> Result<Vec<RunOutcome>> {
    let committed = read_committed(&member.cells_dir())?;
    let total = member.cells.len();
    if committed.len() != total {
        bail!(
            "member '{}' has {}/{} cells committed; cannot finalize",
            member_label(member),
            committed.len(),
            total
        );
    }
    let prior: BTreeMap<usize, CellEntry> =
        if member.dir.join(store::MANIFEST_FILE).exists() {
            store::read_manifest(&member.dir)
                .map(|m| m.cells)
                .unwrap_or_default()
        } else {
            BTreeMap::new()
        };
    let mut cells: BTreeMap<usize, CellEntry> = BTreeMap::new();
    let mut outs = Vec::with_capacity(total);
    for (index, ce) in &committed {
        if *index >= total {
            bail!(
                "member '{}': commit entry for out-of-range cell {index} \
                 (plan has {total})",
                member_label(member)
            );
        }
        let entry = match prior.get(index) {
            Some(pe) if pe.file == ce.file => pe.clone(),
            _ => ce.clone(),
        };
        outs.push(store::load_artifact(
            &member.dir.join(&entry.file),
            &entry.checksum,
            &member.spec_hash,
            *index,
        )?);
        cells.insert(*index, entry);
    }
    store::write_manifest_file(
        &member.dir,
        &ManifestSummary {
            cpt_version: RunStore::code_version().to_string(),
            spec_hash: member.spec_hash.clone(),
            model_fingerprint: member.exec.fingerprint.clone(),
            model: member.exec.model.clone(),
            shard: ShardId::single(),
            total_cells: total,
            cells,
        },
    )?;
    Ok(outs)
}

// ---- the claim session --------------------------------------------------

/// Accounting for one claim session.
#[derive(Clone, Debug)]
pub struct ClaimRunStats {
    pub exec: ExecStats,
    /// Cells per member already committed (by anyone) when this session
    /// started.
    pub resumed_per_member: Vec<usize>,
    /// Cells this claimer committed.
    pub committed_here: usize,
    /// Expired leases this claimer took over.
    pub stolen: usize,
}

impl ClaimRunStats {
    pub fn resumed(&self) -> usize {
        self.resumed_per_member.iter().sum()
    }
}

/// Run one claim session over `members`: claim cells lease-by-lease, run
/// them on a `jobs`-worker pool, commit results to the shared board, and
/// — once every cell of every member is committed by someone — finalize
/// the manifests and return the complete outcomes in canonical order.
/// Every claimer that returns `Ok` reports the full result, including
/// cells computed by its peers.
pub fn run_claim<R, F>(
    label: &str,
    members: Vec<ClaimMember>,
    workers_dir: &Path,
    jobs: usize,
    verbose: bool,
    cfg: &ClaimConfig,
    halt_after_cells: Option<usize>,
    make_worker: F,
) -> Result<(Vec<Vec<RunOutcome>>, ClaimRunStats)>
where
    R: CellRunner,
    F: Fn(usize) -> Result<R> + Sync,
{
    let jobs = jobs.max(1);
    std::fs::create_dir_all(workers_dir)
        .with_context(|| format!("create {}", workers_dir.display()))?;
    for m in &members {
        std::fs::create_dir_all(m.cells_dir())
            .with_context(|| format!("create {}", m.cells_dir().display()))?;
        std::fs::create_dir_all(m.leases_dir())
            .with_context(|| format!("create {}", m.leases_dir().display()))?;
        seed_from_manifest(m, &cfg.claimer)?;
    }
    let mut committed: Vec<HashSet<usize>> = Vec::with_capacity(members.len());
    let mut resumed_per_member = Vec::with_capacity(members.len());
    for m in &members {
        let have: HashSet<usize> =
            read_committed(&m.cells_dir())?.keys().copied().collect();
        resumed_per_member.push(have.len());
        committed.push(have);
    }
    if verbose && resumed_per_member.iter().sum::<usize>() > 0 {
        crate::log_info!(
            "[{label}] {} cell(s) already committed on the claim board",
            resumed_per_member.iter().sum::<usize>()
        );
    }
    let state = ClaimState {
        cfg: cfg.clone(),
        label: label.to_string(),
        verbose,
        jobs,
        members,
        workers_dir: workers_dir.to_path_buf(),
        started: cfg.clock.now(),
        inner: Mutex::new(ClaimInner {
            committed,
            enqueued: HashSet::new(),
            held: HashMap::new(),
            failures: HashMap::new(),
            stolen: 0,
            committed_here: 0,
        }),
        suspended: AtomicBool::new(false),
        fresh: AtomicUsize::new(0),
    };
    state.touch_worker()?;

    let exec_members: Vec<ExecMember> =
        state.members.iter().map(|m| m.exec.clone()).collect();
    let mut slots: Vec<Vec<Option<RunOutcome>>> = state
        .members
        .iter()
        .map(|m| vec![None; m.cells.len()])
        .collect();
    let source = ClaimSource { state: &state };
    let mut sinks: Vec<ClaimSink<'_>> = (0..state.members.len())
        .map(|mi| ClaimSink { state: &state, member: mi })
        .collect();
    let req = ExecRequest {
        label: label.to_string(),
        members: &exec_members,
        items: &[],
        jobs,
        verbose,
        halt_after_cells,
        source: Some(&source),
    };
    let stop = AtomicBool::new(false);
    let exec_stats = std::thread::scope(|scope| {
        if cfg.auto_heartbeat {
            let state_ref = &state;
            let stop_ref = &stop;
            scope.spawn(move || heartbeat_loop(state_ref, stop_ref));
        }
        let mut sink_refs: Vec<Option<&mut dyn CellSink>> = sinks
            .iter_mut()
            .map(|s| Some(s as &mut dyn CellSink))
            .collect();
        let r = exec::run_items(&req, &mut sink_refs, &mut slots, make_worker);
        // the heartbeat must stop whether the run succeeded or failed,
        // or the scope would never join
        stop.store(true, Ordering::SeqCst);
        r
    })?;

    // The source only reports Exhausted when zero cells are uncommitted,
    // so reaching here with holes should be impossible — but the manifest
    // is about to be rebuilt from the entries, so re-verify from disk
    // rather than finalize a short manifest.
    let mut missing = 0usize;
    for m in &state.members {
        missing +=
            m.cells.len() - read_committed(&m.cells_dir())?.len().min(m.cells.len());
    }
    if missing > 0 {
        bail!("claim session ended with {missing} cell(s) uncommitted");
    }
    let mut outs = Vec::with_capacity(state.members.len());
    for m in &state.members {
        outs.push(finalize_member(m)?);
    }
    state.touch_worker().ok();
    let inner = state.inner.into_inner().unwrap();
    Ok((
        outs,
        ClaimRunStats {
            exec: exec_stats,
            resumed_per_member,
            committed_here: inner.committed_here,
            stolen: inner.stolen,
        },
    ))
}

// ---- production wrappers ------------------------------------------------

/// `cpt sweep --claim`: one member over the spec's full (unsharded) cell
/// list, coordinated through `--run-dir`. Returns the complete outcomes
/// in canonical order plus timing and claim accounting.
pub fn run_claim_sweep(
    manifest: &Manifest,
    spec: &SweepSpec,
    cfg: &ClaimConfig,
) -> Result<(Vec<RunOutcome>, SweepTiming, ClaimRunStats)> {
    let t0 = Instant::now();
    let plan = SweepPlan::build(spec)?;
    if plan.shard.count > 1 {
        bail!(
            "--claim replaces --shard: claimers share one run directory and \
             divide cells dynamically"
        );
    }
    let Some(dir) = &spec.run_dir else {
        bail!(
            "--claim needs --run-dir: claimers coordinate through the shared \
             run directory"
        );
    };
    let fingerprint = match &spec.model_fingerprint {
        Some(fp) => fp.clone(),
        None => store::model_fingerprint(manifest.model(&spec.model)?)?,
    };
    let model_spec = manifest.model(&spec.model)?.clone();
    model_spec.validate()?; // fail fast, before touching the board
    // Apply the store fences (spec hash, model fingerprint, cpt version)
    // and initialize a fresh dir's manifest; resume is implied — claim
    // mode is inherently many processes opening one directory.
    drop(RunStore::open(dir, &plan, &fingerprint, true)?);
    let jobs = spec.jobs.max(1);
    let member = ClaimMember {
        exec: ExecMember {
            name: String::new(),
            model: spec.model.clone(),
            fingerprint,
            policy: spec.policy.clone(),
            steps: plan.steps,
            cycles: plan.cycles,
            eval_every: spec.eval_every,
            cap: jobs,
        },
        dir: dir.clone(),
        spec_hash: plan.spec_hash.clone(),
        cells: plan.cells.clone(),
    };
    let mut specs = HashMap::new();
    specs.insert(spec.model.clone(), model_spec);
    let specs = Arc::new(exec::SpecRegistry::from_map(specs));
    let cache_cap = exec::exec_cache_cap()?;
    let aot = aot::store_for_run()?.map(Arc::new);
    let workers_dir = dir.join(CLAIM_DIR).join(WORKERS_DIR);
    let (mut outs, stats) = run_claim(
        &format!("sweep {}", spec.model),
        vec![member],
        &workers_dir,
        jobs,
        spec.verbose,
        cfg,
        None,
        |_| exec::PjrtCellRunner::new(specs.clone(), cache_cap, aot.clone()),
    )?;
    let outcomes = outs.pop().unwrap();
    let timing = SweepTiming {
        wall_seconds: t0.elapsed().as_secs_f64(),
        jobs,
        cells: outcomes.len(),
        resumed: stats.resumed(),
    };
    Ok((outcomes, timing, stats))
}

/// `cpt campaign --claim`: every member's full cell list on the shared
/// claim board, one worker pool claiming across member boundaries.
pub fn run_claim_campaign(
    manifest: &Manifest,
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
    cfg: &ClaimConfig,
) -> Result<(CampaignRunResult, ClaimRunStats)> {
    let t0 = Instant::now();
    if opts.shard.count > 1 {
        bail!(
            "--claim replaces --shard: claimers share one campaign root and \
             divide cells dynamically"
        );
    }
    if opts.scheduler == SchedulerKind::Sequential {
        bail!(
            "--claim requires the global scheduler (claimed cells cross \
             member boundaries)"
        );
    }
    let mut specs: HashMap<String, ModelSpec> = HashMap::new();
    let mut fingerprints: HashMap<String, String> = HashMap::new();
    for m in &plan.members {
        if !specs.contains_key(&m.spec.model) {
            let ms = manifest.model(&m.spec.model)?.clone();
            ms.validate()?; // fail fast, before touching the board
            fingerprints
                .insert(m.spec.model.clone(), store::model_fingerprint(&ms)?);
            specs.insert(m.spec.model.clone(), ms);
        }
    }
    // resume is implied (see run_claim_sweep); the hash/version fences
    // still reject a root that belongs to a different campaign
    campaign::open_campaign_root(&opts.root, plan, ShardId::single(), true)?;
    let jobs = opts.jobs.max(1);
    let mut members = Vec::with_capacity(plan.members.len());
    for m in &plan.members {
        let fp = &fingerprints[&m.spec.model];
        let mut spec = m.spec.clone();
        spec.shard = Some(ShardId::single());
        let mplan = SweepPlan::build(&spec)
            .with_context(|| format!("campaign member '{}'", m.name))?;
        let mdir = opts.root.join(&m.name);
        drop(
            RunStore::open(&mdir, &mplan, fp, true)
                .with_context(|| format!("campaign member '{}'", m.name))?,
        );
        members.push(ClaimMember {
            exec: ExecMember {
                name: m.name.clone(),
                model: m.spec.model.clone(),
                fingerprint: fp.clone(),
                policy: m.spec.policy.clone(),
                steps: mplan.steps,
                cycles: mplan.cycles,
                eval_every: m.spec.eval_every,
                cap: campaign::member_cap(m.jobs, jobs),
            },
            dir: mdir,
            spec_hash: mplan.spec_hash.clone(),
            cells: mplan.cells.clone(),
        });
    }
    let specs = Arc::new(exec::SpecRegistry::from_map(specs));
    let cache_cap = exec::exec_cache_cap()?;
    let aot = aot::store_for_run()?.map(Arc::new);
    let workers_dir = opts.root.join(CLAIM_DIR).join(WORKERS_DIR);
    let (outs, stats) = run_claim(
        &format!("campaign {}", plan.name),
        members,
        &workers_dir,
        jobs,
        opts.verbose,
        cfg,
        None,
        |_| exec::PjrtCellRunner::new(specs.clone(), cache_cap, aot.clone()),
    )?;
    // every finishing claimer records its own pool's accounting — a
    // benign last-writer-wins, like the manifest rebuild itself
    let sched = SchedulerStats {
        jobs: stats.exec.jobs,
        workers: stats.exec.workers.clone(),
    };
    campaign::record_scheduler_stats(&opts.root, &sched)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut members_out = Vec::with_capacity(plan.members.len());
    for ((m, mouts), res) in plan
        .members
        .iter()
        .zip(outs)
        .zip(stats.resumed_per_member.iter().copied())
    {
        let cells = mouts.len();
        members_out.push(MemberOutcome {
            name: m.name.clone(),
            model: m.spec.model.clone(),
            outcomes: mouts,
            timing: SweepTiming {
                wall_seconds: wall,
                jobs: stats.exec.jobs,
                cells,
                resumed: res,
            },
        });
    }
    Ok((
        CampaignRunResult {
            members: members_out,
            wall_seconds: wall,
            scheduler: Some(sched),
        },
        stats,
    ))
}

// ---- status views -------------------------------------------------------

/// One uncommitted cell's current lease, as `cpt status` shows it.
#[derive(Clone, Debug)]
pub struct LeaseView {
    pub cell: usize,
    pub claimer: String,
    pub generation: usize,
    /// Seconds until the deadline; negative = expired (steal-eligible).
    pub remaining: f64,
}

/// Claim-board summary for one member run dir.
#[derive(Clone, Debug)]
pub struct ClaimBoardStatus {
    /// Cells with a commit entry.
    pub committed: usize,
    /// Live leases on uncommitted cells.
    pub active: Vec<LeaseView>,
    /// Expired leases on uncommitted cells (their holders look dead).
    pub expired: Vec<LeaseView>,
}

/// Read the claim board of a member run dir; `None` when the dir has
/// never been claimed over. `now` is the caller's clock reading.
pub fn claim_board_status(
    member_dir: &Path,
    now: f64,
) -> Result<Option<ClaimBoardStatus>> {
    let claim = member_dir.join(CLAIM_DIR);
    let cells_dir = claim.join(CELLS_DIR);
    let leases_dir = claim.join(LEASES_DIR);
    if !cells_dir.exists() && !leases_dir.exists() {
        return Ok(None);
    }
    let committed = read_committed(&cells_dir)?;
    // highest generation per cell, one directory pass
    let mut best: BTreeMap<usize, (usize, PathBuf)> = BTreeMap::new();
    if let Ok(rd) = std::fs::read_dir(&leases_dir) {
        for e in rd {
            let e = e.with_context(|| {
                format!("read dir {}", leases_dir.display())
            })?;
            let name = e.file_name();
            let Some((index, generation)) =
                parse_lease_name(&name.to_string_lossy())
            else {
                continue;
            };
            if generation == 0 {
                continue; // generations start at 1; never a real lease
            }
            let slot = best.entry(index).or_insert((0, PathBuf::new()));
            if generation > slot.0 {
                *slot = (generation, e.path());
            }
        }
    }
    let mut active = Vec::new();
    let mut expired = Vec::new();
    for (cell, (_, path)) in best {
        if committed.contains_key(&cell) {
            continue;
        }
        let l = read_lease(&path)?;
        let view = LeaseView {
            cell,
            claimer: l.claimer,
            generation: l.generation,
            remaining: l.deadline - now,
        };
        if view.remaining > 0.0 {
            active.push(view);
        } else {
            expired.push(view);
        }
    }
    Ok(Some(ClaimBoardStatus {
        committed: committed.len(),
        active,
        expired,
    }))
}

/// One claimer's liveness, as `cpt status` shows it.
#[derive(Clone, Debug)]
pub struct WorkerView {
    pub claimer: String,
    pub lease_secs: f64,
    /// Seconds since the claimer last heartbeat its liveness file.
    pub since_last_seen: f64,
}

impl WorkerView {
    /// Heuristic: a claimer silent for more than two lease periods is
    /// presumed dead (one period is normal between beats under load).
    pub fn looks_alive(&self) -> bool {
        self.since_last_seen < 2.0 * self.lease_secs
    }
}

/// Every claimer that ever joined this root (campaign root or sweep run
/// dir), sorted by name. `now` is the caller's clock reading.
pub fn claim_workers(root: &Path, now: f64) -> Result<Vec<WorkerView>> {
    let dir = root.join(CLAIM_DIR).join(WORKERS_DIR);
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(&dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(anyhow::Error::from(e)
                .context(format!("read dir {}", dir.display())))
        }
    };
    for e in rd {
        let e = e.with_context(|| format!("read dir {}", dir.display()))?;
        let name = e.file_name();
        if !name.to_string_lossy().ends_with(".json") {
            continue; // *.tmp staging residue
        }
        let path = e.path();
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&src)
            .with_context(|| format!("parse {}", path.display()))?;
        if j.get("kind")?.as_str()? != WORKER_KIND {
            bail!("{}: not a cpt claimer liveness record", path.display());
        }
        out.push(WorkerView {
            claimer: j.get("claimer")?.as_str()?.to_string(),
            lease_secs: j.get("lease_secs")?.as_f64()?,
            since_last_seen: now - j.get("last_seen")?.as_f64()?,
        });
    }
    out.sort_by(|a, b| a.claimer.cmp(&b.claimer));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_clock_sets_and_advances() {
        let c = TestClock::new(100.0);
        assert_eq!(c.now(), 100.0);
        c.advance(2.5);
        assert_eq!(c.now(), 102.5);
        c.set(50.0);
        assert_eq!(c.now(), 50.0);
    }

    #[test]
    fn lease_names_round_trip_and_reject_staging_files() {
        assert_eq!(lease_file_name(3, 2), "00003.g2.json");
        assert_eq!(parse_lease_name("00003.g2.json"), Some((3, 2)));
        assert_eq!(parse_lease_name("00003.g12.json"), Some((3, 12)));
        // staging residue and foreign files never parse as leases
        assert_eq!(parse_lease_name("00003.g2.json.123.7.tmp"), None);
        assert_eq!(parse_lease_name("00003.json"), None);
        assert_eq!(parse_lease_name("run-manifest.json"), None);
        assert_eq!(parse_lease_name("00003.gx.json"), None);
    }

    #[test]
    fn lease_records_round_trip_through_json() {
        let doc = encode_lease("alice", 3, 1234.5);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), LEASE_KIND);
        assert_eq!(j.get("claimer").unwrap().as_str().unwrap(), "alice");
        assert_eq!(j.get("generation").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("deadline").unwrap().as_f64().unwrap(), 1234.5);
    }

    #[test]
    fn commit_entries_round_trip_optional_trace_keys() {
        let full = CellEntry {
            file: "00001-CR-q6-t0.alice.json".into(),
            checksum: "abc".into(),
            seconds: 1.5,
            mean_q: Some(0.75),
            realized_cost: Some(0.5),
        };
        let doc = encode_cell_entry(1, "alice", &full);
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("mean_q").unwrap().as_f64().unwrap(), 0.75);
        // seeded from a pre-policy manifest: optional keys stay absent
        let bare = CellEntry { mean_q: None, realized_cost: None, ..full };
        let doc = encode_cell_entry(1, "alice", &bare);
        let j = Json::parse(&doc).unwrap();
        assert!(j.opt("mean_q").is_none());
        assert!(j.opt("realized_cost").is_none());
        assert_eq!(j.get("claimer").unwrap().as_str().unwrap(), "alice");
    }

    #[test]
    fn default_poll_tracks_the_lease_with_clamps() {
        assert_eq!(default_poll(60.0), 15.0);
        assert_eq!(default_poll(4.0), 1.0);
        assert_eq!(default_poll(0.1), 0.1); // clamped low
        assert_eq!(default_poll(600.0), 15.0); // clamped high
    }

    #[test]
    fn claim_config_defaults_are_sane() {
        let cfg = ClaimConfig::new(ClaimerId::parse("alice").unwrap());
        assert_eq!(cfg.lease_secs, 60.0);
        assert_eq!(cfg.poll_secs, 15.0);
        assert!(cfg.stall_after_cells.is_none());
        assert!(cfg.auto_heartbeat);
    }
}

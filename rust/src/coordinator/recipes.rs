//! Per-model training recipes — the paper's hyperparameter settings (§4),
//! scaled to this testbed (DESIGN.md §4), in one place so every bench and
//! example trains identically.

use anyhow::{bail, Result};

use crate::data::{
    blobs::BlobDataset, detection::DetectionDataset,
    entailment::EntailmentDataset, graphs::GraphDataset, images::ImageDataset,
    text::LmDataset, Dataset,
};
use crate::trainer::LrSchedule;

/// Static recipe for one model.
#[derive(Clone, Debug)]
pub struct Recipe {
    /// q_min from the precision range test (paper table of settings).
    pub q_min: f64,
    /// default cycle count n (paper: 8, or 2 for short fine-tunes).
    pub cycles: usize,
    /// default training length on this testbed.
    pub steps: usize,
    pub base_lr: f32,
    pub lr_kind: LrKind,
    /// whether a larger eval metric is better (accuracy/mAP) or smaller
    /// (token CE -> perplexity).
    pub higher_is_better: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LrKind {
    /// ×0.1 at 50%/75% (paper CIFAR/ImageNet recipe)
    StepDecay,
    /// cosine annealing (paper OGBN recipe)
    Cosine,
    /// constant (paper PascalVOC recipe)
    Constant,
    /// linear decay ×0.1 over the run (paper XNLI recipe)
    Linear,
    /// divide by 5 on plateau (paper Penn Treebank recipe)
    Plateau,
}

/// Every model with a recipe (and a dataset) — the single source the
/// tests, `cpt` error messages, and campaign validation iterate.
pub fn model_names() -> &'static [&'static str] {
    &[
        "mlp",
        "cnn_tiny",
        "cnn_deep",
        "detector",
        "gcn_qagg",
        "gcn_fpagg",
        "sage_qagg",
        "sage_fpagg",
        "lstm_lm",
        "transformer_lm",
        "transformer_cls",
    ]
}

/// Recipe lookup. q_min values follow the paper's range-test results for
/// the corresponding domain (CIFAR 3, ImageNet 4, VOC 5, OGBN 3, LM 5).
pub fn recipe(model: &str) -> Result<Recipe> {
    Ok(match model {
        "mlp" => Recipe {
            q_min: 3.0,
            cycles: 8,
            steps: 128,
            base_lr: 0.05,
            lr_kind: LrKind::Constant,
            higher_is_better: true,
        },
        "cnn_tiny" => Recipe {
            q_min: 3.0,
            cycles: 8,
            steps: 320,
            base_lr: 0.05,
            lr_kind: LrKind::StepDecay,
            higher_is_better: true,
        },
        "cnn_deep" => Recipe {
            q_min: 4.0,
            cycles: 8,
            steps: 320,
            base_lr: 0.05,
            lr_kind: LrKind::StepDecay,
            higher_is_better: true,
        },
        "detector" => Recipe {
            q_min: 5.0,
            cycles: 8,
            steps: 256,
            base_lr: 1e-3,
            lr_kind: LrKind::Constant,
            higher_is_better: true,
        },
        "gcn_qagg" | "gcn_fpagg" => Recipe {
            q_min: 3.0,
            cycles: 8,
            steps: 240,
            base_lr: 1e-2,
            lr_kind: LrKind::Cosine,
            higher_is_better: true,
        },
        "sage_qagg" | "sage_fpagg" => Recipe {
            q_min: 3.0,
            cycles: 8,
            steps: 240,
            base_lr: 1e-2,
            lr_kind: LrKind::Cosine,
            higher_is_better: true,
        },
        "lstm_lm" => Recipe {
            q_min: 5.0,
            cycles: 2,
            steps: 240,
            base_lr: 4.0,
            lr_kind: LrKind::Plateau,
            higher_is_better: false,
        },
        "transformer_lm" => Recipe {
            q_min: 5.0,
            cycles: 2,
            steps: 300,
            base_lr: 1e-3,
            lr_kind: LrKind::Cosine,
            higher_is_better: false,
        },
        "transformer_cls" => Recipe {
            q_min: 5.0,
            cycles: 2,
            steps: 240,
            base_lr: 5e-4,
            lr_kind: LrKind::Linear,
            higher_is_better: true,
        },
        other => bail!(
            "no recipe for model '{other}' (known: {})",
            model_names().join(", ")
        ),
    })
}

impl Recipe {
    pub fn lr_schedule(&self, total_steps: usize) -> LrSchedule {
        match self.lr_kind {
            LrKind::StepDecay => {
                LrSchedule::paper_step_decay(self.base_lr, total_steps)
            }
            LrKind::Cosine => LrSchedule::cosine(self.base_lr, total_steps),
            LrKind::Constant => LrSchedule::Constant { lr: self.base_lr },
            LrKind::Linear => LrSchedule::LinearDecay {
                base: self.base_lr,
                total: total_steps,
                end_factor: 0.1,
            },
            LrKind::Plateau => LrSchedule::plateau(self.base_lr, 0.2, 3),
        }
    }
}

/// Construct the synthetic dataset matching a model's manifest shapes.
pub fn dataset_for(model: &str, seed: u64) -> Result<Box<dyn Dataset>> {
    Ok(match model {
        "mlp" => Box::new(BlobDataset::new(seed, 32, 4, 32)),
        "cnn_tiny" => Box::new(ImageDataset::new(seed, 16, 10, 32)),
        "cnn_deep" => Box::new(ImageDataset::new(seed, 16, 20, 32)),
        "detector" => Box::new(DetectionDataset::new(seed, 16, 4, 4, 16)),
        "gcn_qagg" | "gcn_fpagg" => {
            Box::new(GraphDataset::new(seed, 512, None))
        }
        "sage_qagg" | "sage_fpagg" => {
            Box::new(GraphDataset::new(seed, 512, Some(8)))
        }
        "lstm_lm" => Box::new(LmDataset::new(seed, 64, 32, 16)),
        "transformer_lm" => Box::new(LmDataset::new(seed, 64, 32, 16)),
        "transformer_cls" => Box::new(EntailmentDataset::new(seed, 32, 16)),
        other => bail!(
            "no dataset for model '{other}' (known: {})",
            model_names().join(", ")
        ),
    })
}

/// Convert a raw eval metric into the figure-of-merit the paper reports
/// (perplexity for LMs, metric as-is otherwise).
pub fn report_metric(model: &str, raw: f32) -> f32 {
    match model {
        "lstm_lm" | "transformer_lm" => raw.exp(), // token CE -> perplexity
        _ => raw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_have_recipe_and_dataset() {
        assert_eq!(model_names().len(), 11);
        for &m in model_names() {
            recipe(m).unwrap_or_else(|e| panic!("{m}: {e}"));
            dataset_for(m, 1).unwrap_or_else(|e| panic!("{m}: {e}"));
        }
        let err = recipe("nope").unwrap_err();
        assert!(err.to_string().contains("known: mlp"), "{err:#}");
    }

    #[test]
    fn fine_tune_models_use_short_cycles() {
        // paper §4.4: n ∈ {1, 2} for 2-epoch fine-tuning
        assert_eq!(recipe("transformer_cls").unwrap().cycles, 2);
        assert_eq!(recipe("lstm_lm").unwrap().cycles, 2);
        assert_eq!(recipe("cnn_tiny").unwrap().cycles, 8);
    }

    #[test]
    fn perplexity_conversion() {
        assert!((report_metric("lstm_lm", 0.0) - 1.0).abs() < 1e-6);
        assert_eq!(report_metric("cnn_tiny", 0.7), 0.7);
    }
}

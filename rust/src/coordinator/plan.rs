//! Deterministic sweep planning and sharding.
//!
//! A [`SweepPlan`] is the canonical, content-addressed description of the
//! work in one sweep: the ordered cell list (identical to the historical
//! serial nesting in [`super::sweep_cells`]), a spec hash over everything
//! that determines results, and a shard assignment. Two processes that
//! build a plan from the same spec agree bit-for-bit on cell order, cell
//! indices, and the hash — that agreement is what makes shard artifacts
//! mergeable and crash resume safe (see rust/DESIGN-sharding.md).
//!
//! Partitioning is round-robin by canonical cell index: shard `i/N` owns
//! every cell whose index ≡ i-1 (mod N). This is trivially deterministic,
//! disjoint, covering, and balanced to within one cell, and it spreads
//! the expensive q_max/schedule combinations across shards instead of
//! giving one machine all of them.

use std::fmt;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::recipes::recipe;
use super::{sweep_cells, SweepCell, SweepSpec};
use crate::util::hash::fnv1a64_hex;

/// One shard of a partitioned sweep, parsed from `I/N` (1-based).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardId {
    pub index: usize,
    pub count: usize,
}

impl ShardId {
    /// The trivial partition: one shard owning every cell.
    pub fn single() -> ShardId {
        ShardId { index: 1, count: 1 }
    }

    /// Parse `"I/N"` (e.g. `"2/4"`); both 1-based, `1 <= I <= N`.
    pub fn parse(s: &str) -> Result<ShardId> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("shard '{s}' is not of the form I/N"))?;
        let index: usize = i
            .trim()
            .parse()
            .with_context(|| format!("bad shard index in '{s}'"))?;
        let count: usize = n
            .trim()
            .parse()
            .with_context(|| format!("bad shard count in '{s}'"))?;
        if count == 0 || index == 0 || index > count {
            bail!("shard '{s}' out of range (need 1 <= I <= N, N >= 1)");
        }
        Ok(ShardId { index, count })
    }

    /// Does this shard own the cell at canonical index `cell_index`?
    pub fn owns(&self, cell_index: usize) -> bool {
        cell_index % self.count == self.index - 1
    }

    /// How many of the `total_cells` canonical indices this shard owns
    /// (closed form of counting `owns(i)` over `0..total_cells`). This
    /// is the "planned" figure `cpt status` reports for a shard dir.
    pub fn owned_count(&self, total_cells: usize) -> usize {
        total_cells / self.count
            + usize::from(self.index - 1 < total_cells % self.count)
    }
}

impl fmt::Display for ShardId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// The identity of one claimer in a `--claim` run (see
/// `coordinator::lease`). The name is embedded in lease records, the
/// liveness file name, and claimer-suffixed artifact file names, so it
/// must be a safe path component.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClaimerId(String);

impl ClaimerId {
    /// Parse and validate a claimer name: 1–64 chars of
    /// `[A-Za-z0-9._-]`, not starting with a dot or dash (no hidden
    /// files, no flag-lookalikes), and not a name the claim layout
    /// reserves for itself.
    pub fn parse(name: &str) -> Result<ClaimerId> {
        if name.is_empty() || name.len() > 64 {
            bail!("claimer name must be 1-64 characters, got '{name}'");
        }
        if name.starts_with('.') || name.starts_with('-') {
            bail!("claimer name '{name}' may not start with '.' or '-'");
        }
        if !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
        {
            bail!(
                "claimer name '{name}' may only contain letters, digits, \
                 '.', '_', and '-'"
            );
        }
        if name == "claim" || name == "tmp" {
            bail!("claimer name '{name}' is reserved");
        }
        Ok(ClaimerId(name.to_string()))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for ClaimerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// A cell tagged with its canonical index in the full plan.
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedCell {
    pub index: usize,
    pub cell: SweepCell,
}

/// The deterministic execution plan for one sweep spec.
#[derive(Clone, Debug)]
pub struct SweepPlan {
    pub model: String,
    /// Resolved step count (spec override or recipe default).
    pub steps: usize,
    /// Resolved cycle count.
    pub cycles: usize,
    /// Content hash over everything that determines results (16 hex
    /// chars). Execution knobs — jobs, verbose, shard, run_dir, resume —
    /// are deliberately excluded: they change how the sweep runs, never
    /// what it computes.
    pub spec_hash: String,
    /// Full canonical cell list (all shards).
    pub cells: Vec<SweepCell>,
    pub shard: ShardId,
}

impl SweepPlan {
    /// Build the plan: resolve recipe defaults, enumerate cells in the
    /// canonical order, and hash the result-determining spec fields.
    pub fn build(spec: &SweepSpec) -> Result<SweepPlan> {
        let rec = recipe(&spec.model)?;
        let steps = spec.steps.unwrap_or(rec.steps);
        let cycles = spec.cycles.unwrap_or(rec.cycles);
        let cells = sweep_cells(spec);
        let shard = spec.shard.unwrap_or_else(ShardId::single);

        // Canonical description string; any change to it is a format
        // break, so it carries its own version tag.
        let mut desc = String::new();
        let _ = write!(
            desc,
            "cpt-sweep-v1;model={};steps={steps};cycles={cycles};trials={};eval_every={}",
            spec.model, spec.trials, spec.eval_every
        );
        desc.push_str(";schedules=");
        desc.push_str(&spec.schedules.join(","));
        desc.push_str(";q_maxes=");
        for (i, q) in spec.q_maxes.iter().enumerate() {
            if i > 0 {
                desc.push(',');
            }
            let _ = write!(desc, "{q}");
        }
        // The precision policy is result-determining, so every parameter
        // reaches the hash — via its canonical encoding, so two spellings
        // of the same policy ("loss_plateau" vs its fully-keyed form)
        // hash identically. The default (StaticSuite) is omitted: a sweep
        // that never mentions policies must keep its pre-policy hash
        // (same results, and append-only format evolution).
        if spec.policy.is_adaptive() {
            desc.push_str(";policy=");
            desc.push_str(&spec.policy.canonical());
        }
        let spec_hash = fnv1a64_hex(desc.as_bytes());

        Ok(SweepPlan {
            model: spec.model.clone(),
            steps,
            cycles,
            spec_hash,
            cells,
            shard,
        })
    }

    pub fn total_cells(&self) -> usize {
        self.cells.len()
    }

    /// The cells this plan's shard owns, in canonical order.
    pub fn owned(&self) -> Vec<PlannedCell> {
        self.cells
            .iter()
            .enumerate()
            .filter(|(i, _)| self.shard.owns(*i))
            .map(|(index, cell)| PlannedCell { index, cell: cell.clone() })
            .collect()
    }
}

/// Derive a per-spec run directory under `base`:
/// `<base>/<model>-<spec_hash[..8]>-<model_fingerprint[..8]>`. Because
/// both hashes are in the name, neither a changed spec nor a regenerated
/// `artifacts/` tree ever collides with stale artifacts — each lands in
/// its own fresh directory instead of tripping the store's mismatch
/// fences — which is what makes blanket resume (e.g. via the CPT_RUN_DIR
/// env var in benches) safe.
pub fn run_dir_under(
    base: &Path,
    spec: &SweepSpec,
    model_fingerprint: &str,
) -> Result<PathBuf> {
    let plan = SweepPlan::build(spec)?;
    let fp8 = &model_fingerprint[..model_fingerprint.len().min(8)];
    Ok(base.join(format!("{}-{}-{}", spec.model, &plan.spec_hash[..8], fp8)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::propcheck;

    fn spec() -> SweepSpec {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "RR".into(), "STATIC".into()];
        s.q_maxes = vec![6.0, 8.0];
        s.trials = 2;
        s
    }

    #[test]
    fn shard_id_parses_and_validates() {
        assert_eq!(ShardId::parse("1/1").unwrap(), ShardId::single());
        assert_eq!(
            ShardId::parse(" 2/4 ").unwrap(),
            ShardId { index: 2, count: 4 }
        );
        assert_eq!(ShardId::parse("3/4").unwrap().to_string(), "3/4");
        for bad in ["0/2", "3/2", "1/0", "x/2", "1", "1/2/3", ""] {
            assert!(ShardId::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn claimer_id_validates_path_safety() {
        for ok in ["a", "node-3", "w_1", "host.example", "A9"] {
            assert_eq!(ClaimerId::parse(ok).unwrap().as_str(), ok);
        }
        for bad in
            ["", ".hidden", "-flag", "a/b", "a b", "claim", "tmp", "é"]
        {
            assert!(ClaimerId::parse(bad).is_err(), "accepted '{bad}'");
        }
        assert!(ClaimerId::parse(&"x".repeat(65)).is_err());
        assert!(ClaimerId::parse(&"x".repeat(64)).is_ok());
    }

    #[test]
    fn plan_resolves_recipe_defaults() {
        let s = SweepSpec::new("mlp");
        let p = SweepPlan::build(&s).unwrap();
        let rec = recipe("mlp").unwrap();
        assert_eq!(p.steps, rec.steps);
        assert_eq!(p.cycles, rec.cycles);
        let mut s2 = SweepSpec::new("mlp");
        s2.steps = Some(17);
        assert_eq!(SweepPlan::build(&s2).unwrap().steps, 17);
    }

    #[test]
    fn shards_are_disjoint_cover_the_plan_and_are_stable() {
        propcheck(100, |rng| {
            let mut s = SweepSpec::new("mlp");
            s.schedules = (0..1 + rng.below(5) as usize)
                .map(|i| format!("S{i}"))
                .collect();
            s.q_maxes = (0..1 + rng.below(3) as usize)
                .map(|i| 4.0 + i as f64)
                .collect();
            s.trials = 1 + rng.below(4) as usize;
            let count = 1 + rng.below(7) as usize;

            let total = SweepPlan::build(&s).unwrap().total_cells();
            let mut seen = vec![0usize; total];
            for index in 1..=count {
                s.shard = Some(ShardId { index, count });
                let p1 = SweepPlan::build(&s).unwrap();
                let p2 = SweepPlan::build(&s).unwrap();
                // stable: two builds agree exactly
                prop_assert!(
                    p1.spec_hash == p2.spec_hash,
                    "hash unstable"
                );
                prop_assert!(p1.owned() == p2.owned(), "owned unstable");
                for pc in p1.owned() {
                    prop_assert!(
                        pc.index < total,
                        "index {} out of range",
                        pc.index
                    );
                    seen[pc.index] += 1;
                    prop_assert!(
                        p1.cells[pc.index] == pc.cell,
                        "cell mismatch at {}",
                        pc.index
                    );
                }
            }
            // disjoint + covering: each cell owned exactly once
            prop_assert!(
                seen.iter().all(|&n| n == 1),
                "partition not exact: {seen:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn owned_count_matches_enumeration() {
        propcheck(200, |rng| {
            let total = rng.below(50) as usize;
            let count = 1 + rng.below(8) as usize;
            let index = 1 + rng.below(count as u32) as usize;
            let sh = ShardId { index, count };
            let brute = (0..total).filter(|&i| sh.owns(i)).count();
            prop_assert!(
                sh.owned_count(total) == brute,
                "{sh} over {total}: {} != {brute}",
                sh.owned_count(total)
            );
            Ok(())
        });
    }

    #[test]
    fn shard_sizes_balanced_within_one() {
        let mut s = spec(); // 12 cells
        let mut sizes = Vec::new();
        for index in 1..=5 {
            s.shard = Some(ShardId { index, count: 5 });
            sizes.push(SweepPlan::build(&s).unwrap().owned().len());
        }
        assert_eq!(sizes.iter().sum::<usize>(), 12);
        let (min, max) =
            (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn spec_hash_tracks_result_determining_fields_only() {
        let base = SweepPlan::build(&spec()).unwrap().spec_hash;

        // execution knobs do NOT change the hash
        let mut s = spec();
        s.jobs = 7;
        s.verbose = true;
        s.shard = Some(ShardId { index: 2, count: 3 });
        s.run_dir = Some("/tmp/x".into());
        s.resume = true;
        s.model_fingerprint = Some("cafe".into());
        assert_eq!(SweepPlan::build(&s).unwrap().spec_hash, base);

        // every result-determining field DOES change it
        let mut s = spec();
        s.model = "cnn_tiny".into();
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.schedules.pop();
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.q_maxes = vec![6.0];
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.trials = 3;
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.steps = Some(9999);
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.cycles = Some(3);
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
        let mut s = spec();
        s.eval_every = 5;
        assert_ne!(SweepPlan::build(&s).unwrap().spec_hash, base);
    }

    #[test]
    fn spec_hash_moves_iff_a_policy_field_changes() {
        use crate::policy::PolicySpec;
        let hash = |p: PolicySpec| {
            let mut s = spec();
            s.policy = p;
            SweepPlan::build(&s).unwrap().spec_hash
        };
        let base = SweepPlan::build(&spec()).unwrap().spec_hash;
        // the explicit default spells the same sweep: hash unchanged —
        // a pre-policy run dir is exactly resumable by a static-policy
        // spec (and vice versa)
        assert_eq!(hash(PolicySpec::StaticSuite), base);
        // an adaptive policy always moves the hash off the static one
        let plateau = PolicySpec::parse("loss_plateau").unwrap();
        let plateau_hash = hash(plateau.clone());
        assert_ne!(plateau_hash, base);
        assert_ne!(hash(PolicySpec::parse("cost_governor").unwrap()), base);
        // two spellings of one policy agree; the fully-keyed canonical
        // form is the same spec as the bare default
        let respelled = PolicySpec::parse(&plateau.canonical()).unwrap();
        assert_eq!(hash(respelled), plateau_hash);
        // ...and every parameter is result-determining
        propcheck(60, |rng| {
            let mut p = PolicySpec::parse("loss_plateau").unwrap();
            if let PolicySpec::LossPlateau {
                ema, patience, min_delta, q_step, cooldown,
            } = &mut p
            {
                match rng.below(5) {
                    0 => *ema = 0.25,
                    1 => *patience += 1 + rng.below(3) as usize,
                    2 => *min_delta += 0.005,
                    3 => *q_step += 1.0,
                    _ => *cooldown += 1,
                }
            }
            prop_assert!(
                hash(p.clone()) != plateau_hash,
                "changed policy field kept the hash ({p:?})"
            );
            // and hashing is stable for equal specs
            prop_assert!(hash(p.clone()) == hash(p), "hash unstable");
            Ok(())
        });
        let g = |t: f64| hash(PolicySpec::CostGovernor { target: t });
        assert_ne!(g(0.6), g(0.7));
        assert_eq!(g(0.6), g(0.6));
    }

    #[test]
    fn run_dir_under_embeds_model_spec_hash_and_fingerprint() {
        let s = spec();
        let fp = "0123456789abcdef";
        let d = run_dir_under(Path::new("/runs"), &s, fp).unwrap();
        let name = d.file_name().unwrap().to_str().unwrap().to_string();
        assert!(name.starts_with("mlp-"), "{name}");
        assert!(name.ends_with("-01234567"), "{name}");
        assert_eq!(name.len(), "mlp-".len() + 8 + 1 + 8);
        // same spec+model -> same dir
        assert_eq!(run_dir_under(Path::new("/runs"), &s, fp).unwrap(), d);
        // different spec -> different dir
        let mut s2 = spec();
        s2.trials = 9;
        assert_ne!(run_dir_under(Path::new("/runs"), &s2, fp).unwrap(), d);
        // regenerated model -> different dir (fresh start, not a hard
        // fingerprint-mismatch failure on resume)
        assert_ne!(
            run_dir_under(Path::new("/runs"), &s, "fedcba9876543210").unwrap(),
            d
        );
    }
}

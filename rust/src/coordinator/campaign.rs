//! Campaign orchestration: several sweeps as one content-addressed tree.
//!
//! A figure campaign (every panel of Fig 3/6/7) is more than one sweep:
//! a [`CampaignSpec`] — parsed from a TOML file with a `[campaign]`
//! header and one `[[campaign.sweep]]` table per member — compiles into
//! a [`CampaignPlan`], an ordered list of named member sweeps plus a
//! campaign-level FNV hash derived from the member spec hashes. `cpt
//! campaign` executes the plan through the global scheduler by default
//! ([`run_campaign_global`]): the plan flattens to the canonical
//! `(member, cell)` item list and one shared worker pool claims cells
//! across member boundaries via [`super::exec`], each worker caching
//! compiled executables by model fingerprint (`--scheduler sequential`
//! keeps the member-after-member baseline). Either way there is one
//! [`super::store::RunStore`] directory per member, nested under a
//! campaign root governed by a `campaign-manifest.json`, and results
//! are byte-identical between the schedulers.
//!
//! Layout of a campaign root (one per shard, exactly like sweep dirs):
//!
//! ```text
//! <campaign-root>/
//!   campaign-manifest.json     # campaign hash, shard id, member table
//!   <member-name>/             # a normal sweep run dir (run-manifest.json
//!   <member-name>/             #   + cell artifacts) for that member
//! ```
//!
//! The same fences as the sweep store apply one level up: a root can
//! only be resumed by the same campaign (hash), shard, and cpt version
//! that created it, and [`merge_campaign_roots`] refuses roots or member
//! directories whose hashes disagree. Member order is canonical (sorted
//! by name) no matter how the TOML file orders its tables, so two
//! processes always agree on the campaign hash and on execution order.
//!
//! [`status`] answers `cpt status DIR` for both sweep run dirs and
//! campaign roots, straight from the manifests; [`gc`] answers `cpt gc`
//! by compacting every member's artifacts (see
//! [`super::store::compact_run_dir`]).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::aot;
use super::exec::{self, ExecItem, ExecMember, WorkerStats};
use super::plan::{PlannedCell, ShardId, SweepPlan};
use super::pool;
use super::store::{
    self, compact_run_dir, merge_run_dirs, GcStats, ManifestSummary, RunStore,
};
use super::{run_sweep_timed, RunOutcome, SweepSpec, SweepTiming};
use crate::config::toml::{Section, TomlDoc};
use crate::policy::PolicySpec;
use crate::runtime::{Manifest, ModelSpec};
use crate::util::hash::Fnv1a64;
use crate::util::json::{num, obj, s, Json};

pub const CAMPAIGN_MANIFEST_FILE: &str = "campaign-manifest.json";
const CAMPAIGN_KIND: &str = "cpt-campaign";
const CAMPAIGN_SCHEMA_VERSION: usize = 1;

/// One named member sweep of a campaign.
#[derive(Clone, Debug)]
pub struct CampaignMember {
    pub name: String,
    pub spec: SweepSpec,
    /// Per-member concurrency cap (`jobs = N` in the member table): the
    /// global scheduler never runs more than N of this member's cells at
    /// once (e.g. `jobs = 1` for a memory-hungry model), and the
    /// sequential path caps the member's own pool the same way. An
    /// execution knob — never part of any hash. None = no member cap.
    pub jobs: Option<usize>,
}

/// A campaign as described by its TOML file (member order as authored;
/// [`CampaignPlan::build`] canonicalizes it).
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub name: String,
    /// Default campaign root from `[campaign] run_dir` (the CLI flag
    /// overrides it).
    pub run_dir: Option<PathBuf>,
    pub members: Vec<CampaignMember>,
}

impl CampaignSpec {
    /// Parse a campaign file: `[campaign]` (name, optional run_dir) plus
    /// one `[[campaign.sweep]]` table per member. Member names default
    /// to the member's model and must be unique — they become directory
    /// names under the campaign root and key the merged report.
    pub fn from_toml(doc: &TomlDoc) -> Result<CampaignSpec> {
        // reject unknown structure first, symmetrically with the
        // unknown-key checks below — a misspelled [[campaign.sweep]]
        // header would otherwise silently drop a whole member
        for name in doc.sections.keys() {
            if !name.is_empty() && name != "campaign" {
                bail!(
                    "unknown section [{name}] in campaign file (known: \
                     [campaign], [[campaign.sweep]])"
                );
            }
        }
        if let Some(root) = doc.section("") {
            if let Some(k) = root.keys().next() {
                bail!(
                    "unexpected top-level key '{k}' in campaign file (all \
                     keys live under [campaign] or [[campaign.sweep]])"
                );
            }
        }
        for t in doc.tables.keys() {
            if t != "campaign.sweep" {
                bail!(
                    "unknown table [[{t}]] in campaign file (did you mean \
                     [[campaign.sweep]]?)"
                );
            }
        }
        let sec = doc
            .section("campaign")
            .context("campaign file needs a [campaign] section")?;
        for k in sec.keys() {
            if !["name", "run_dir"].contains(&k.as_str()) {
                bail!("unknown [campaign] key '{k}' (known: name, run_dir)");
            }
        }
        let name = sec
            .get("name")
            .context("[campaign] needs name")?
            .as_str()?
            .to_string();
        let run_dir = sec
            .get("run_dir")
            .map(|v| Ok::<_, anyhow::Error>(PathBuf::from(v.as_str()?)))
            .transpose()?;
        let tables = doc.table("campaign.sweep");
        if tables.is_empty() {
            bail!(
                "campaign '{name}' has no [[campaign.sweep]] members — \
                 each member is one sweep (one figure panel)"
            );
        }
        let mut members = Vec::new();
        for (i, t) in tables.iter().enumerate() {
            let spec =
                sweep_spec_from_section(t, SweepSectionKind::CampaignMember)
                    .with_context(|| format!("[[campaign.sweep]] #{}", i + 1))?;
            let member_name = match t.get("name") {
                Some(v) => v.as_str()?.to_string(),
                None => spec.model.clone(),
            };
            // member-level concurrency cap, read here (not into the
            // spec) because it bounds the member within the shared pool
            let jobs = match t.get("jobs") {
                Some(v) => {
                    let j = v.as_usize().with_context(|| {
                        format!("[[campaign.sweep]] '{member_name}' jobs")
                    })?;
                    if j == 0 {
                        bail!(
                            "[[campaign.sweep]] '{member_name}': jobs must \
                             be >= 1"
                        );
                    }
                    Some(j)
                }
                None => None,
            };
            members.push(CampaignMember { name: member_name, spec, jobs });
        }
        Ok(CampaignSpec { name, run_dir, members })
    }
}

/// Which kind of TOML section [`sweep_spec_from_section`] is reading —
/// each accepts exactly the keys that are meaningful there, so a key
/// that would be silently inert is rejected instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepSectionKind {
    /// `[sweep]` in a preset file: execution knobs
    /// (shard/run_dir/resume/jobs/verbose) allowed; `name` is not (the
    /// preset's root `title` labels the run).
    Preset,
    /// `[[campaign.sweep]]` member: `name` and `jobs` (the member's
    /// in-flight cap within the global pool) allowed; the remaining
    /// execution knobs are campaign-level flags, never member keys.
    CampaignMember,
}

/// Build a `SweepSpec` from a TOML section — the shared reader for
/// `[sweep]` preset sections and `[[campaign.sweep]]` member tables.
/// Unknown (or contextually inert) keys are rejected: they are silent
/// result changes otherwise.
pub fn sweep_spec_from_section(
    sec: &Section,
    kind: SweepSectionKind,
) -> Result<SweepSpec> {
    const RESULT_KEYS: &[&str] = &[
        "model", "schedules", "q_maxes", "trials", "steps", "cycles",
        "eval_every", "policy",
    ];
    const EXEC_KEYS: &[&str] = &["shard", "run_dir", "resume", "jobs", "verbose"];
    let allow_exec_keys = kind == SweepSectionKind::Preset;
    for k in sec.keys() {
        let known = RESULT_KEYS.contains(&k.as_str())
            || (allow_exec_keys && EXEC_KEYS.contains(&k.as_str()))
            || (kind == SweepSectionKind::CampaignMember
                && (k == "name" || k == "jobs"));
        if !known {
            bail!(
                "unknown sweep key '{k}' (known: {}{})",
                RESULT_KEYS.join(", "),
                match kind {
                    SweepSectionKind::Preset =>
                        format!("; exec: {}", EXEC_KEYS.join(", ")),
                    SweepSectionKind::CampaignMember =>
                        "; name, jobs".to_string(),
                }
            );
        }
    }
    let model = sec.get("model").context("sweep needs model")?.as_str()?;
    let mut spec = SweepSpec::new(model);
    if let Some(v) = sec.get("schedules") {
        spec.schedules = v
            .as_list()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
    }
    if let Some(v) = sec.get("q_maxes") {
        spec.q_maxes =
            v.as_list()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?;
    }
    if let Some(v) = sec.get("trials") {
        spec.trials = v.as_usize()?;
    }
    if let Some(v) = sec.get("steps") {
        spec.steps = Some(v.as_usize()?);
    }
    if let Some(v) = sec.get("cycles") {
        spec.cycles = Some(v.as_usize()?);
    }
    if let Some(v) = sec.get("eval_every") {
        spec.eval_every = v.as_usize()?;
    }
    if let Some(v) = sec.get("policy") {
        // the compact syntax ("loss_plateau:patience=3"); preset files
        // may use a [sweep.policy] table instead (cmd_preset applies it)
        let pol = PolicySpec::parse(v.as_str()?)
            .context("sweep 'policy' key")?;
        set_policy(&mut spec, pol, sec.get("schedules").is_some())?;
    }
    if allow_exec_keys {
        if let Some(v) = sec.get("shard") {
            spec.shard = Some(ShardId::parse(v.as_str()?)?);
        }
        if let Some(v) = sec.get("run_dir") {
            spec.run_dir = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = sec.get("resume") {
            spec.resume = v.as_bool()?;
        }
        if let Some(v) = sec.get("jobs") {
            spec.jobs = v.as_usize()?;
        }
        if let Some(v) = sec.get("verbose") {
            spec.verbose = v.as_bool()?;
        }
    }
    Ok(spec)
}

/// Install a precision policy on a sweep spec — the single place the
/// policy/schedule-axis interaction is decided, shared by the TOML
/// readers and every `--policy` flag. An adaptive policy drives `q_t`
/// itself, so the schedule axis collapses to the policy's label (one
/// cell per q_max × trial); an explicitly authored schedules list is
/// rejected rather than silently turned into duplicate cells. Installing
/// `static` over an already-adaptive spec is rejected too (the original
/// schedule list is gone).
pub fn set_policy(
    spec: &mut SweepSpec,
    policy: PolicySpec,
    schedules_explicit: bool,
) -> Result<()> {
    if policy.is_adaptive() {
        if schedules_explicit {
            bail!(
                "policy '{}' drives q_t itself; drop the schedules list \
                 (every listed schedule would run the identical adaptive \
                 cell)",
                policy.canonical()
            );
        }
        spec.schedules = vec![policy.label().to_string()];
    } else if spec.policy.is_adaptive() {
        bail!(
            "cannot override adaptive policy '{}' with 'static': the \
             sweep's schedule axis was already collapsed to the policy \
             label",
            spec.policy.canonical()
        );
    }
    spec.policy = policy;
    Ok(())
}

/// Campaign and member names both become filesystem path components
/// (the default CSV dir, member run dirs) and CSV keys, so they share a
/// path-safe alphabet.
fn validate_path_component(what: &str, name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 64 {
        bail!("{what} '{name}' must be 1..=64 characters");
    }
    if name.starts_with('.') {
        bail!("{what} '{name}' may not start with '.'");
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-'))
    {
        bail!(
            "{what} '{name}' may only contain [A-Za-z0-9._-] (it becomes \
             a directory name)"
        );
    }
    Ok(())
}

fn validate_member_name(name: &str) -> Result<()> {
    validate_path_component("campaign member name", name)?;
    if name == CAMPAIGN_MANIFEST_FILE || name == store::MANIFEST_FILE {
        bail!("campaign member name '{name}' collides with a manifest file");
    }
    if name == "campaign" {
        // the member CSV would be <csv-dir>/campaign.csv — the file the
        // campaign-level report itself writes
        bail!(
            "campaign member name 'campaign' is reserved (it would \
             collide with the campaign.csv report)"
        );
    }
    Ok(())
}

/// One member of a compiled campaign plan.
#[derive(Clone, Debug)]
pub struct MemberPlan {
    pub name: String,
    pub spec: SweepSpec,
    /// The member's own sweep plan (unsharded; execution applies the
    /// campaign shard). Carries the member spec hash and cell count.
    pub plan: SweepPlan,
    /// Per-member in-flight cap (see [`CampaignMember::jobs`]).
    pub jobs: Option<usize>,
}

/// The deterministic execution plan for a campaign: members in canonical
/// (name-sorted) order plus the campaign content hash.
#[derive(Clone, Debug)]
pub struct CampaignPlan {
    pub name: String,
    /// FNV-1a 64 over the canonical member list — each member's name and
    /// sweep spec hash. Execution knobs never reach it (the member spec
    /// hashes already exclude them), so it changes iff a
    /// result-determining field of some member changes, a member is
    /// added/removed, or a member is renamed (names key the report).
    pub campaign_hash: String,
    pub members: Vec<MemberPlan>,
}

impl CampaignPlan {
    pub fn build(spec: &CampaignSpec) -> Result<CampaignPlan> {
        // the campaign name lands in the default CSV path, so it gets
        // the same path-safe alphabet as member names
        validate_path_component("campaign name", &spec.name)?;
        if spec.members.is_empty() {
            bail!("campaign '{}' has no member sweeps", spec.name);
        }
        let mut members = Vec::with_capacity(spec.members.len());
        for m in &spec.members {
            validate_member_name(&m.name)
                .with_context(|| format!("campaign '{}'", spec.name))?;
            let plan = SweepPlan::build(&m.spec)
                .with_context(|| format!("campaign member '{}'", m.name))?;
            members.push(MemberPlan {
                name: m.name.clone(),
                spec: m.spec.clone(),
                plan,
                jobs: m.jobs,
            });
        }
        // canonical order: sorted by member name, independent of the
        // order the TOML file lists its tables
        members.sort_by(|a, b| a.name.cmp(&b.name));
        for w in members.windows(2) {
            if w[0].name == w[1].name {
                bail!(
                    "duplicate campaign member name '{}' (names key the \
                     report and the run-dir layout, so they must be unique)",
                    w[0].name
                );
            }
        }
        let mut h = Fnv1a64::new();
        h.update(b"cpt-campaign-v1");
        for m in &members {
            h.update(b";sweep=");
            h.update(m.name.as_bytes());
            h.update(b":");
            h.update(m.plan.spec_hash.as_bytes());
        }
        Ok(CampaignPlan {
            name: spec.name.clone(),
            campaign_hash: h.finish_hex(),
            members,
        })
    }

    /// Cells across all members (all shards).
    pub fn total_cells(&self) -> usize {
        self.members.iter().map(|m| m.plan.total_cells()).sum()
    }

    /// Flatten the plan into the canonical `(member, cell)` work-item
    /// list for `shard`: members in canonical (name-sorted) order, each
    /// member's owned cells by canonical index. This is the order the
    /// global scheduler enqueues — deterministic for any two processes
    /// that agree on the campaign (propcheck-tested), with the member
    /// index doubling as the store/slot route, so an item can never be
    /// recorded across a member boundary.
    pub fn flatten_owned(&self, shard: ShardId) -> Vec<(usize, PlannedCell)> {
        let mut items = Vec::new();
        for (mi, m) in self.members.iter().enumerate() {
            for (i, cell) in m.plan.cells.iter().enumerate() {
                if shard.owns(i) {
                    items.push((
                        mi,
                        PlannedCell { index: i, cell: cell.clone() },
                    ));
                }
            }
        }
        items
    }
}

/// Manifest record for one campaign member.
#[derive(Clone, Debug, PartialEq)]
pub struct MemberEntry {
    /// Directory name under the campaign root (== member name).
    pub dir: String,
    pub model: String,
    pub spec_hash: String,
    pub total_cells: usize,
}

/// Per-worker compile accounting for the last completed global-scheduler
/// run of a campaign root, recorded into the manifest and surfaced by
/// `cpt status`. Purely informational — never part of any fence.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerStats {
    /// Workers the pool actually spawned.
    pub jobs: usize,
    pub workers: Vec<WorkerStats>,
}

impl SchedulerStats {
    pub fn total_compiles(&self) -> usize {
        self.workers.iter().map(|w| w.compiles).sum()
    }

    pub fn total_compile_seconds(&self) -> f64 {
        self.workers.iter().map(|w| w.compile_seconds).sum()
    }

    pub fn total_hits(&self) -> usize {
        self.workers.iter().map(|w| w.hits).sum()
    }

    pub fn total_disk_hits(&self) -> usize {
        self.workers.iter().map(|w| w.disk_hits).sum()
    }

    pub fn total_misses(&self) -> usize {
        self.workers.iter().map(|w| w.misses).sum()
    }
}

/// Parsed, validated view of a `campaign-manifest.json`.
#[derive(Clone, Debug)]
pub struct CampaignManifest {
    pub cpt_version: String,
    pub name: String,
    pub campaign_hash: String,
    pub shard: ShardId,
    /// Member name -> entry; BTreeMap order is the canonical order.
    pub members: BTreeMap<String, MemberEntry>,
    /// Worker-pool accounting from the last completed global-scheduler
    /// run (absent until one completes, and on sequential-only roots).
    pub scheduler: Option<SchedulerStats>,
}

impl CampaignManifest {
    /// A member's run dir must hold exactly the sweep this campaign
    /// manifest recorded — shared fence for every operation that walks
    /// the tree (status, gc); merge applies it per root as well.
    fn check_member_dir(
        &self,
        name: &str,
        e: &MemberEntry,
        ms: &ManifestSummary,
        mdir: &Path,
    ) -> Result<()> {
        if ms.spec_hash != e.spec_hash
            || ms.shard != self.shard
            || ms.total_cells != e.total_cells
            || ms.cpt_version != self.cpt_version
        {
            bail!(
                "campaign member '{name}' run dir {} disagrees with the \
                 campaign manifest (spec hash, shard, cell count, or cpt \
                 version)",
                mdir.display()
            );
        }
        Ok(())
    }
}

fn write_campaign_manifest(root: &Path, cm: &CampaignManifest) -> Result<()> {
    let mut members = BTreeMap::new();
    for (name, e) in &cm.members {
        members.insert(
            name.clone(),
            obj(vec![
                ("dir", s(&e.dir)),
                ("model", s(&e.model)),
                ("spec_hash", s(&e.spec_hash)),
                ("total_cells", num(e.total_cells as f64)),
            ]),
        );
    }
    let mut fields = vec![
        ("kind", s(CAMPAIGN_KIND)),
        ("version", num(CAMPAIGN_SCHEMA_VERSION as f64)),
        ("cpt_version", s(&cm.cpt_version)),
        ("name", s(&cm.name)),
        ("campaign_hash", s(&cm.campaign_hash)),
        ("shard_index", num(cm.shard.index as f64)),
        ("shard_count", num(cm.shard.count as f64)),
        ("members", Json::Obj(members)),
    ];
    if let Some(sc) = &cm.scheduler {
        let workers = Json::Arr(
            sc.workers
                .iter()
                .map(|w| {
                    obj(vec![
                        ("worker", num(w.worker as f64)),
                        ("compiles", num(w.compiles as f64)),
                        ("compile_seconds", num(w.compile_seconds)),
                        ("cells", num(w.cells as f64)),
                        ("retries", num(w.retries as f64)),
                        ("hits", num(w.hits as f64)),
                        ("disk_hits", num(w.disk_hits as f64)),
                        ("misses", num(w.misses as f64)),
                    ])
                })
                .collect(),
        );
        fields.push((
            "scheduler",
            obj(vec![("jobs", num(sc.jobs as f64)), ("workers", workers)]),
        ));
    }
    let doc = obj(fields);
    doc.write_atomic(root.join(CAMPAIGN_MANIFEST_FILE)).with_context(|| {
        format!("write campaign manifest in {}", root.display())
    })
}

/// Load and validate the `campaign-manifest.json` governing `root`.
pub fn read_campaign_manifest(root: &Path) -> Result<CampaignManifest> {
    let path = root.join(CAMPAIGN_MANIFEST_FILE);
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&src)
        .with_context(|| format!("parse {}", path.display()))?;
    if j.get("kind")?.as_str()? != CAMPAIGN_KIND {
        bail!("{}: not a cpt campaign manifest", path.display());
    }
    let version = j.get("version")?.as_usize()?;
    if version != CAMPAIGN_SCHEMA_VERSION {
        bail!(
            "{}: campaign schema version {version} (this build reads \
             version {CAMPAIGN_SCHEMA_VERSION})",
            path.display()
        );
    }
    let shard = ShardId {
        index: j.get("shard_index")?.as_usize()?,
        count: j.get("shard_count")?.as_usize()?,
    };
    if shard.count == 0 || shard.index == 0 || shard.index > shard.count {
        bail!(
            "shard {}/{} out of range in {}",
            shard.index,
            shard.count,
            path.display()
        );
    }
    let mut members = BTreeMap::new();
    for (name, e) in j.get("members")?.as_obj()? {
        validate_member_name(name)
            .with_context(|| format!("in {}", path.display()))?;
        let dir = e.get("dir")?.as_str()?.to_string();
        if dir != *name {
            // the writer always nests a member under its own name;
            // anything else is a hand-edited manifest, and following it
            // would let status/gc/merge touch paths outside the root
            bail!(
                "{}: member '{name}' points at dir '{dir}' (must equal \
                 the member name)",
                path.display()
            );
        }
        members.insert(
            name.clone(),
            MemberEntry {
                dir,
                model: e.get("model")?.as_str()?.to_string(),
                spec_hash: e.get("spec_hash")?.as_str()?.to_string(),
                total_cells: e.get("total_cells")?.as_usize()?,
            },
        );
    }
    if members.is_empty() {
        bail!("{}: campaign manifest lists no members", path.display());
    }
    let name = j.get("name")?.as_str()?.to_string();
    // the name feeds the default CSV path (results/campaign_<name>), so
    // a hand-edited manifest gets the same path-safety fence as the
    // plan-side validation in CampaignPlan::build
    validate_path_component("campaign name", &name)
        .with_context(|| format!("in {}", path.display()))?;
    let scheduler = match j.opt("scheduler") {
        Some(sj) => {
            let mut workers = Vec::new();
            for w in sj.get("workers")?.as_arr()? {
                workers.push(WorkerStats {
                    worker: w.get("worker")?.as_usize()?,
                    compiles: w.get("compiles")?.as_usize()?,
                    compile_seconds: w.get("compile_seconds")?.as_f64()?,
                    cells: w.get("cells")?.as_usize()?,
                    // absent in manifests written before 0.7.0
                    retries: match w.opt("retries") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    // absent in manifests written before 0.8.0
                    hits: match w.opt("hits") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    disk_hits: match w.opt("disk_hits") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                    misses: match w.opt("misses") {
                        Some(v) => v.as_usize()?,
                        None => 0,
                    },
                });
            }
            Some(SchedulerStats { jobs: sj.get("jobs")?.as_usize()?, workers })
        }
        None => None,
    };
    Ok(CampaignManifest {
        cpt_version: j.get("cpt_version")?.as_str()?.to_string(),
        name,
        campaign_hash: j.get("campaign_hash")?.as_str()?.to_string(),
        shard,
        members,
        scheduler,
    })
}

fn manifest_from_plan(plan: &CampaignPlan, shard: ShardId) -> CampaignManifest {
    CampaignManifest {
        cpt_version: RunStore::code_version().to_string(),
        name: plan.name.clone(),
        campaign_hash: plan.campaign_hash.clone(),
        shard,
        scheduler: None,
        members: plan
            .members
            .iter()
            .map(|m| {
                (
                    m.name.clone(),
                    MemberEntry {
                        dir: m.name.clone(),
                        model: m.spec.model.clone(),
                        spec_hash: m.plan.spec_hash.clone(),
                        total_cells: m.plan.total_cells(),
                    },
                )
            })
            .collect(),
    }
}

/// Initialize or reopen a campaign root for `plan` + `shard`, applying
/// the same fences as `RunStore::open` one level up. Public so tests can
/// fabricate campaign trees without training anything.
pub fn open_campaign_root(
    root: &Path,
    plan: &CampaignPlan,
    shard: ShardId,
    resume: bool,
) -> Result<CampaignManifest> {
    if !root.join(CAMPAIGN_MANIFEST_FILE).exists() {
        if root.join(store::MANIFEST_FILE).exists() {
            // never stack a campaign manifest on top of a sweep run dir:
            // status/gc/merge dispatch on which manifest is present, so a
            // mixed-kind tree would hide the sweep's recorded progress
            bail!(
                "{} is already a sweep run dir (it contains {}); point \
                 --run-dir at a fresh directory",
                root.display(),
                store::MANIFEST_FILE
            );
        }
        let cm = manifest_from_plan(plan, shard);
        std::fs::create_dir_all(root)
            .with_context(|| format!("create {}", root.display()))?;
        write_campaign_manifest(root, &cm)?;
        return Ok(cm);
    }
    if !resume {
        bail!(
            "campaign root {} already contains {CAMPAIGN_MANIFEST_FILE}; \
             pass --resume to continue it, or use a fresh directory",
            root.display()
        );
    }
    let cm = read_campaign_manifest(root)?;
    if cm.campaign_hash != plan.campaign_hash {
        bail!(
            "cannot resume {}: it was created for a different campaign \
             (manifest hash {}, requested {})",
            root.display(),
            cm.campaign_hash,
            plan.campaign_hash
        );
    }
    if cm.cpt_version != RunStore::code_version() {
        bail!(
            "cannot resume {}: it was written by cpt {} but this binary is \
             {} — training code may have changed; use a fresh root",
            root.display(),
            cm.cpt_version,
            RunStore::code_version()
        );
    }
    if cm.shard != shard {
        bail!(
            "cannot resume {}: it belongs to shard {} but this run is \
             shard {}",
            root.display(),
            cm.shard,
            shard
        );
    }
    let mut cm = cm;
    let want = manifest_from_plan(plan, shard);
    if cm.members != want.members {
        // unreachable if the hash matches, but fail loudly rather than
        // trusting a hand-edited manifest
        bail!(
            "campaign manifest in {} is inconsistent with the plan",
            root.display()
        );
    }
    if cm.name != plan.name {
        // the name is a label (it keys the default CSV dir), deliberately
        // outside the campaign hash — a rename is legitimate, so relabel
        // the root instead of refusing a content-identical resume
        crate::log_info!(
            "[campaign] note: relabeling root {} from '{}' to '{}' \
             (member set is unchanged)",
            root.display(),
            cm.name,
            plan.name
        );
        cm.name = plan.name.clone();
        write_campaign_manifest(root, &cm)?;
    }
    Ok(cm)
}

/// Which campaign execution path to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Members run one after another, each on its own worker pool (the
    /// pre-global-scheduler behavior; kept as the equivalence baseline).
    Sequential,
    /// One shared worker pool claims cells across member boundaries,
    /// with a per-worker compiled-executable cache (the default).
    Global,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        match s {
            "sequential" | "seq" => Ok(SchedulerKind::Sequential),
            "global" => Ok(SchedulerKind::Global),
            other => bail!(
                "unknown scheduler '{other}' (known: global, sequential)"
            ),
        }
    }
}

/// Execution knobs for one `cpt campaign` invocation.
#[derive(Clone, Debug)]
pub struct CampaignRunOpts {
    pub root: PathBuf,
    pub shard: ShardId,
    pub jobs: usize,
    pub resume: bool,
    pub verbose: bool,
    pub scheduler: SchedulerKind,
}

/// Results of one member sweep execution (this shard's share).
#[derive(Clone, Debug)]
pub struct MemberOutcome {
    pub name: String,
    pub model: String,
    pub outcomes: Vec<RunOutcome>,
    /// Under the global scheduler members overlap, so `wall_seconds` and
    /// `jobs` are campaign-wide figures repeated per member; `cells` and
    /// `resumed` remain member-accurate.
    pub timing: SweepTiming,
}

/// Results of one `run_campaign` invocation (this shard's share).
#[derive(Clone, Debug)]
pub struct CampaignRunResult {
    /// Members in canonical order.
    pub members: Vec<MemberOutcome>,
    pub wall_seconds: f64,
    /// Worker-pool accounting: None on the sequential path; on a fully
    /// resumed global run (no fresh cells), the stats of the run that
    /// did the work, straight from the manifest.
    pub scheduler: Option<SchedulerStats>,
}

impl CampaignRunResult {
    pub fn total_cells(&self) -> usize {
        self.members.iter().map(|m| m.timing.cells).sum()
    }

    pub fn total_resumed(&self) -> usize {
        self.members.iter().map(|m| m.timing.resumed).sum()
    }
}

/// A member's effective in-flight cap inside a pool of `jobs` workers.
pub(crate) fn member_cap(member_jobs: Option<usize>, jobs: usize) -> usize {
    member_jobs.unwrap_or(jobs).min(jobs).max(1)
}

/// Execute a campaign plan's owned shard. Both schedulers persist every
/// completed cell before moving on, so a kill at any point loses at most
/// the in-flight cells; re-running with `resume` picks up exactly where
/// it stopped. Results are byte-identical between the two schedulers —
/// every cell is an independently seeded run routed to its member's
/// store and canonical slot — only wall clock and compile counts differ.
pub fn run_campaign(
    manifest: &Manifest,
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
) -> Result<CampaignRunResult> {
    match opts.scheduler {
        SchedulerKind::Sequential => {
            run_campaign_sequential(manifest, plan, opts)
        }
        SchedulerKind::Global => {
            // shared pre-validated specs + fingerprints, one per model
            // (members often share a model across figure panels)
            let mut specs: HashMap<String, ModelSpec> = HashMap::new();
            let mut fingerprints: HashMap<String, String> = HashMap::new();
            for m in &plan.members {
                if !specs.contains_key(&m.spec.model) {
                    let ms = manifest.model(&m.spec.model)?.clone();
                    ms.validate()?; // fail fast, before spawning workers
                    fingerprints.insert(
                        m.spec.model.clone(),
                        store::model_fingerprint(&ms)?,
                    );
                    specs.insert(m.spec.model.clone(), ms);
                }
            }
            let specs = Arc::new(exec::SpecRegistry::from_map(specs));
            let cache_cap = exec::exec_cache_cap()?;
            let aot = aot::store_for_run()?.map(Arc::new);
            run_campaign_global(plan, opts, &fingerprints, None, |_| {
                exec::PjrtCellRunner::new(specs.clone(), cache_cap, aot.clone())
            })
        }
    }
}


/// Sequential path: members in canonical order, each through
/// `run_sweep_timed` with its run dir nested under the campaign root.
fn run_campaign_sequential(
    manifest: &Manifest,
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
) -> Result<CampaignRunResult> {
    let t0 = Instant::now();
    // Resolve every member's model up front — fail fast like the global
    // path, before the campaign root is created or any member trains
    // (a missing model would otherwise strand a root that only --resume
    // can reopen). Members often share a model (panels across q_max
    // settings); hash each compiled model once, not once per member.
    let mut fingerprints: HashMap<String, String> = HashMap::new();
    for m in &plan.members {
        if !fingerprints.contains_key(&m.spec.model) {
            let ms = manifest.model(&m.spec.model)?;
            ms.validate()?;
            let fp = store::model_fingerprint(ms)?;
            fingerprints.insert(m.spec.model.clone(), fp);
        }
    }
    open_campaign_root(&opts.root, plan, opts.shard, opts.resume)?;
    let mut results = Vec::with_capacity(plan.members.len());
    for m in &plan.members {
        let fp = fingerprints[&m.spec.model].clone();
        let mut spec = m.spec.clone();
        spec.shard = Some(opts.shard);
        spec.run_dir = Some(opts.root.join(&m.name));
        // the campaign-root fence already vetted the whole tree, so
        // member dirs reopen unconditionally (fresh dirs are unaffected)
        spec.resume = true;
        spec.jobs = member_cap(m.jobs, opts.jobs);
        spec.verbose = opts.verbose;
        spec.model_fingerprint = Some(fp);
        if opts.verbose {
            crate::log_info!(
                "[campaign {}] sweep '{}' ({}, shard {})",
                plan.name, m.name, m.spec.model, opts.shard
            );
        }
        let (outcomes, timing) = run_sweep_timed(manifest, &spec)
            .with_context(|| format!("campaign member '{}'", m.name))?;
        results.push(MemberOutcome {
            name: m.name.clone(),
            model: m.spec.model.clone(),
            outcomes,
            timing,
        });
    }
    Ok(CampaignRunResult {
        members: results,
        wall_seconds: t0.elapsed().as_secs_f64(),
        scheduler: None,
    })
}

/// Global-scheduler path with an injected worker factory — `run_campaign`
/// wires the PJRT-backed [`exec::PjrtCellRunner`]; the fabricated-outcome
/// tests inject a runner that needs no PJRT. `fingerprints` maps each
/// member model to its compiled-model fingerprint (the store fence and
/// the executable-cache key). `halt_after_cells` overrides the
/// CPT_HALT_AFTER_CELLS env knob so tests can kill deterministically
/// without mutating process env.
pub fn run_campaign_global<R, F>(
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
    fingerprints: &HashMap<String, String>,
    halt_after_cells: Option<usize>,
    make_worker: F,
) -> Result<CampaignRunResult>
where
    R: exec::CellRunner,
    F: Fn(usize) -> Result<R> + Sync,
{
    let t0 = Instant::now();
    open_campaign_root(&opts.root, plan, opts.shard, opts.resume)?;
    let jobs = opts.jobs.max(1);
    let mut prep = prepare_members(plan, opts, fingerprints, jobs)?;

    if opts.verbose {
        crate::log_info!(
            "[campaign {}] global scheduler: {} cell(s) across {} member(s) \
             on {} worker(s)",
            plan.name,
            prep.items.len(),
            plan.members.len(),
            jobs.min(prep.items.len().max(1))
        );
    }
    let had_items = !prep.items.is_empty();
    let req = exec::ExecRequest {
        label: format!("campaign {}", plan.name),
        members: &prep.members_meta,
        items: &prep.items,
        jobs,
        verbose: opts.verbose,
        halt_after_cells,
        source: None,
    };
    let mut store_refs: Vec<Option<&mut dyn exec::CellSink>> = prep
        .stores
        .iter_mut()
        .map(|s| s.as_mut().map(|st| st as &mut dyn exec::CellSink))
        .collect();
    let stats =
        exec::run_items(&req, &mut store_refs, &mut prep.slots, make_worker)
            .with_context(|| format!("campaign '{}'", plan.name))?;
    drop(store_refs);

    finish_campaign(plan, opts, t0, stats, had_items, prep.slots, prep.resumed)
}

/// Pooled path: attach the campaign as one job on a persistent
/// [`pool::WorkerPool`] instead of spawning (and tearing down) workers
/// per call. Member stores, resume, slot routing, and manifest stats all
/// match `run_campaign_global` — the difference is who owns the workers,
/// and therefore whose executable caches this job warms or reuses. The
/// daemon routes every concurrent job through one pool, so a job sharing
/// a model fingerprint with an earlier one compiles nothing.
pub fn run_campaign_pooled(
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
    fingerprints: &HashMap<String, String>,
    halt_after_cells: Option<usize>,
    pool: &pool::WorkerPool,
) -> Result<CampaignRunResult> {
    let t0 = Instant::now();
    open_campaign_root(&opts.root, plan, opts.shard, opts.resume)?;
    let mut prep = prepare_members(plan, opts, fingerprints, pool.size())?;

    if opts.verbose {
        crate::log_info!(
            "[campaign {}] pooled scheduler: {} cell(s) across {} member(s) \
             on a {}-worker shared pool",
            plan.name,
            prep.items.len(),
            plan.members.len(),
            pool.size()
        );
    }
    let had_items = !prep.items.is_empty();
    let req = pool::PoolRequest {
        label: format!("campaign {}", plan.name),
        members: prep.members_meta,
        items: prep.items,
        verbose: opts.verbose,
        halt_after_cells,
    };
    let mut store_refs: Vec<Option<&mut dyn exec::CellSink>> = prep
        .stores
        .iter_mut()
        .map(|s| s.as_mut().map(|st| st as &mut dyn exec::CellSink))
        .collect();
    let stats = pool
        .run_job(&req, &mut store_refs, &mut prep.slots)
        .with_context(|| format!("campaign '{}'", plan.name))?;
    drop(store_refs);

    finish_campaign(plan, opts, t0, stats, had_items, prep.slots, prep.resumed)
}

/// Per-member execution state shared by the global and pooled paths.
struct PreparedMembers {
    stores: Vec<Option<RunStore>>,
    slots: Vec<Vec<Option<RunOutcome>>>,
    members_meta: Vec<ExecMember>,
    resumed: Vec<usize>,
    items: Vec<ExecItem>,
}

/// Open every member's nested store, resume cells with valid artifacts
/// into canonical-order slots, describe each member to the executor
/// (model, fingerprint, resolved steps/cycles, cap against `jobs`
/// workers), and flatten the remaining cells into the item list.
fn prepare_members(
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
    fingerprints: &HashMap<String, String>,
    jobs: usize,
) -> Result<PreparedMembers> {
    let mut stores: Vec<Option<RunStore>> = Vec::new();
    let mut slots: Vec<Vec<Option<RunOutcome>>> = Vec::new();
    let mut members_meta: Vec<ExecMember> = Vec::new();
    let mut resumed: Vec<usize> = Vec::new();
    for m in &plan.members {
        let fp = fingerprints.get(&m.spec.model).with_context(|| {
            format!("no fingerprint for model '{}'", m.spec.model)
        })?;
        let mut spec = m.spec.clone();
        spec.shard = Some(opts.shard);
        let mplan = SweepPlan::build(&spec)
            .with_context(|| format!("campaign member '{}'", m.name))?;
        // the campaign-root fence already vetted the whole tree, so
        // member dirs reopen unconditionally (fresh dirs are unaffected)
        let mut store =
            RunStore::open(&opts.root.join(&m.name), &mplan, fp, true)
                .with_context(|| format!("campaign member '{}'", m.name))?;
        let owned = mplan.owned();
        let mut mslots: Vec<Option<RunOutcome>> = vec![None; owned.len()];
        let mut res = 0usize;
        for (pos, pc) in owned.iter().enumerate() {
            if let Some(out) = store.take_valid_outcome(pc.index) {
                mslots[pos] = Some(out);
                res += 1;
            }
        }
        if opts.verbose && res > 0 {
            crate::log_info!(
                "[campaign {}] '{}': resumed {res}/{} cells from {}",
                plan.name,
                m.name,
                owned.len(),
                store.dir().display()
            );
        }
        members_meta.push(ExecMember {
            name: m.name.clone(),
            model: m.spec.model.clone(),
            fingerprint: fp.clone(),
            policy: m.spec.policy.clone(),
            steps: mplan.steps,
            cycles: mplan.cycles,
            eval_every: m.spec.eval_every,
            cap: member_cap(m.jobs, jobs),
        });
        stores.push(Some(store));
        slots.push(mslots);
        resumed.push(res);
    }

    // Flatten to the canonical (member, cell) item list and drop the
    // cells already filled from artifacts. `flatten_owned` and the
    // per-member `owned()` lists enumerate identically, so slot
    // positions line up by construction.
    let mut items: Vec<ExecItem> = Vec::new();
    let mut slot_cursor = vec![0usize; plan.members.len()];
    for (mi, pc) in plan.flatten_owned(opts.shard) {
        let pos = slot_cursor[mi];
        slot_cursor[mi] += 1;
        if slots[mi][pos].is_some() {
            continue; // resumed from its artifact
        }
        items.push(ExecItem {
            member: mi,
            cell_index: pc.index,
            slot: pos,
            cell: pc.cell,
        });
    }
    Ok(PreparedMembers { stores, slots, members_meta, resumed, items })
}

/// Shared tail of the global and pooled paths: record scheduler stats
/// into the campaign manifest and assemble per-member outcomes.
fn finish_campaign(
    plan: &CampaignPlan,
    opts: &CampaignRunOpts,
    t0: Instant,
    stats: exec::ExecStats,
    had_items: bool,
    slots: Vec<Vec<Option<RunOutcome>>>,
    resumed: Vec<usize>,
) -> Result<CampaignRunResult> {
    // Record per-worker compile accounting into the campaign manifest so
    // `cpt status` can surface it after the fact. A fully resumed run
    // spawned no workers — keep the stats of the run that did the work
    // instead of overwriting them with an empty record.
    let jobs_run = stats.jobs;
    let sched = if !had_items {
        read_campaign_manifest(&opts.root)?.scheduler
    } else {
        let s = SchedulerStats { jobs: stats.jobs, workers: stats.workers };
        record_scheduler_stats(&opts.root, &s)?;
        Some(s)
    };

    let wall = t0.elapsed().as_secs_f64();
    let mut members_out = Vec::with_capacity(plan.members.len());
    for ((m, mslots), res) in
        plan.members.iter().zip(slots).zip(resumed)
    {
        let cells = mslots.len();
        members_out.push(MemberOutcome {
            name: m.name.clone(),
            model: m.spec.model.clone(),
            outcomes: mslots.into_iter().flatten().collect(),
            timing: SweepTiming {
                wall_seconds: wall,
                jobs: jobs_run,
                cells,
                resumed: res,
            },
        });
    }
    Ok(CampaignRunResult {
        members: members_out,
        wall_seconds: wall,
        scheduler: sched,
    })
}

/// Rewrite the campaign manifest with the latest pool accounting (all
/// fence fields unchanged).
pub(crate) fn record_scheduler_stats(root: &Path, stats: &SchedulerStats) -> Result<()> {
    let mut cm = read_campaign_manifest(root)?;
    cm.scheduler = Some(stats.clone());
    write_campaign_manifest(root, &cm)
}

/// One member's merged, canonical-order outcomes.
#[derive(Clone, Debug)]
pub struct MergedMember {
    pub name: String,
    pub model: String,
    pub outcomes: Vec<RunOutcome>,
}

/// A fully merged campaign (every member complete across the roots).
#[derive(Clone, Debug)]
pub struct MergedCampaign {
    pub name: String,
    pub campaign_hash: String,
    pub members: Vec<MergedMember>,
}

/// Merge N campaign shard roots into complete per-member outcome lists.
/// Refuses roots whose campaign hashes or cpt versions disagree, member
/// directories whose sweep spec hash is not the one the campaign
/// manifest recorded, and (via [`merge_run_dirs`]) any member whose
/// cells are missing, duplicated, or corrupt — so the result is exactly
/// what one process running every member serially would have produced.
pub fn merge_campaign_roots(roots: &[PathBuf]) -> Result<MergedCampaign> {
    if roots.is_empty() {
        bail!("campaign merge needs at least one campaign root");
    }
    let mut head: Option<CampaignManifest> = None;
    for root in roots {
        let cm = read_campaign_manifest(root)
            .with_context(|| format!("load campaign root {}", root.display()))?;
        match &head {
            None => head = Some(cm),
            Some(h) => {
                if h.campaign_hash != cm.campaign_hash {
                    bail!(
                        "cannot merge {}: campaign hash {} does not match \
                         {} — the roots come from different campaigns",
                        root.display(),
                        cm.campaign_hash,
                        h.campaign_hash
                    );
                }
                if h.cpt_version != cm.cpt_version {
                    bail!(
                        "cannot merge {}: its members were computed by cpt \
                         {} but other roots used {}",
                        root.display(),
                        cm.cpt_version,
                        h.cpt_version
                    );
                }
                if h.members != cm.members {
                    bail!(
                        "cannot merge {}: campaign manifest disagrees on \
                         members despite matching hash",
                        root.display()
                    );
                }
                if h.name != cm.name {
                    // same content, different labels — refusing beats
                    // silently picking one name for the merged report
                    bail!(
                        "cannot merge {}: it is labeled campaign '{}' but \
                         other roots say '{}' (same member set) — rerun \
                         the renamed root with --resume to relabel it",
                        root.display(),
                        cm.name,
                        h.name
                    );
                }
            }
        }
    }
    let h = head.unwrap();
    let mut members = Vec::with_capacity(h.members.len());
    for (name, e) in &h.members {
        let dirs: Vec<PathBuf> = roots
            .iter()
            .map(|r| r.join(&e.dir))
            .filter(|d| d.join(store::MANIFEST_FILE).exists())
            .collect();
        if dirs.is_empty() {
            bail!(
                "campaign member '{name}' has no run directory in any \
                 root — did its shards ever run?"
            );
        }
        for d in &dirs {
            let ms = store::read_manifest(d)
                .with_context(|| format!("campaign member '{name}'"))?;
            if ms.spec_hash != e.spec_hash {
                bail!(
                    "cannot merge member '{name}': {} holds spec hash {} \
                     but the campaign manifest records {} — the directory \
                     belongs to a different sweep",
                    d.display(),
                    ms.spec_hash,
                    e.spec_hash
                );
            }
            if ms.cpt_version != h.cpt_version {
                bail!(
                    "cannot merge member '{name}': {} was written by cpt \
                     {} but the campaign root records {} — training code \
                     may differ between builds",
                    d.display(),
                    ms.cpt_version,
                    h.cpt_version
                );
            }
        }
        let (model, outcomes) = merge_run_dirs(&dirs)
            .with_context(|| format!("campaign member '{name}'"))?;
        if model != e.model {
            bail!(
                "campaign member '{name}': merged model '{model}' does not \
                 match the manifest's '{}'",
                e.model
            );
        }
        members.push(MergedMember { name: name.clone(), model, outcomes });
    }
    Ok(MergedCampaign {
        name: h.name,
        campaign_hash: h.campaign_hash,
        members,
    })
}

/// Progress of one campaign member, derived from its run manifest (or
/// from the campaign manifest alone if the member dir does not exist
/// yet).
#[derive(Clone, Debug)]
pub struct MemberStatus {
    pub name: String,
    pub model: String,
    pub planned: usize,
    pub done: usize,
    pub exec_seconds: f64,
    /// Mean realized q/q_max over recorded cells with a trace summary
    /// (None for pre-policy manifests or unstarted members — reporting
    /// falls back silently).
    pub mean_q: Option<f64>,
    /// Mean realized relative cost over recorded cells with a summary.
    pub realized_cost: Option<f64>,
}

impl MemberStatus {
    pub fn remaining(&self) -> usize {
        self.planned - self.done
    }
}

/// Progress of a whole campaign root.
#[derive(Clone, Debug)]
pub struct CampaignStatus {
    pub name: String,
    pub campaign_hash: String,
    pub shard: ShardId,
    pub members: Vec<MemberStatus>,
    /// Pool accounting from the last completed global-scheduler run.
    pub scheduler: Option<SchedulerStats>,
}

impl CampaignStatus {
    pub fn planned(&self) -> usize {
        self.members.iter().map(|m| m.planned).sum()
    }

    pub fn done(&self) -> usize {
        self.members.iter().map(|m| m.done).sum()
    }

    pub fn remaining(&self) -> usize {
        self.planned() - self.done()
    }

    pub fn exec_seconds(&self) -> f64 {
        self.members.iter().map(|m| m.exec_seconds).sum()
    }
}

/// What `cpt status DIR` found at `DIR`.
#[derive(Clone, Debug)]
pub enum Status {
    /// A single sweep run dir (its validated manifest view).
    Sweep(ManifestSummary),
    Campaign(CampaignStatus),
}

/// Report progress for either a sweep run dir or a campaign root,
/// straight from the manifests (no artifact is opened). Refuses trees
/// whose manifests are inconsistent — status must never present a
/// mismatched tree as healthy progress.
pub fn status(dir: &Path) -> Result<Status> {
    if dir.join(CAMPAIGN_MANIFEST_FILE).exists() {
        let cm = read_campaign_manifest(dir)?;
        let mut members = Vec::with_capacity(cm.members.len());
        for (name, e) in &cm.members {
            let mdir = dir.join(&e.dir);
            let st = if mdir.join(store::MANIFEST_FILE).exists() {
                let ms = store::read_manifest(&mdir)
                    .with_context(|| format!("campaign member '{name}'"))?;
                cm.check_member_dir(name, e, &ms, &mdir)?;
                MemberStatus {
                    name: name.clone(),
                    model: e.model.clone(),
                    planned: ms.planned(),
                    done: ms.done(),
                    exec_seconds: ms.exec_seconds(),
                    mean_q: ms.mean_q(),
                    realized_cost: ms.realized_cost(),
                }
            } else {
                // not started: everything the shard owns is still to do
                MemberStatus {
                    name: name.clone(),
                    model: e.model.clone(),
                    planned: cm.shard.owned_count(e.total_cells),
                    done: 0,
                    exec_seconds: 0.0,
                    mean_q: None,
                    realized_cost: None,
                }
            };
            members.push(st);
        }
        return Ok(Status::Campaign(CampaignStatus {
            name: cm.name,
            campaign_hash: cm.campaign_hash,
            shard: cm.shard,
            members,
            scheduler: cm.scheduler,
        }));
    }
    if dir.join(store::MANIFEST_FILE).exists() {
        return Ok(Status::Sweep(store::read_manifest(dir)?));
    }
    bail!(
        "{} contains neither {} nor {} — not a run dir or campaign root",
        dir.display(),
        store::MANIFEST_FILE,
        CAMPAIGN_MANIFEST_FILE
    );
}

/// `cpt gc`: compact a sweep run dir, or every started member of a
/// campaign root. Returns per-directory stats labeled by member name
/// ("" for a plain sweep dir).
pub fn gc(dir: &Path) -> Result<Vec<(String, GcStats)>> {
    if dir.join(CAMPAIGN_MANIFEST_FILE).exists() {
        let cm = read_campaign_manifest(dir)?;
        let mut out = Vec::new();
        for (name, e) in &cm.members {
            let mdir = dir.join(&e.dir);
            if !mdir.join(store::MANIFEST_FILE).exists() {
                continue; // member not started yet — nothing to compact
            }
            // same fence as status: never rewrite a member dir the rest
            // of the tooling would refuse as mismatched
            let ms = store::read_manifest(&mdir)
                .with_context(|| format!("campaign member '{name}'"))?;
            cm.check_member_dir(name, e, &ms, &mdir)?;
            let stats = compact_run_dir(&mdir)
                .with_context(|| format!("campaign member '{name}'"))?;
            out.push((name.clone(), stats));
        }
        return Ok(out);
    }
    if dir.join(store::MANIFEST_FILE).exists() {
        return Ok(vec![(String::new(), compact_run_dir(dir)?)]);
    }
    bail!(
        "{} contains neither {} nor {} — not a run dir or campaign root",
        dir.display(),
        store::MANIFEST_FILE,
        CAMPAIGN_MANIFEST_FILE
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck::propcheck;

    fn member_spec(trials: usize) -> SweepSpec {
        let mut s = SweepSpec::new("mlp");
        s.schedules = vec!["CR".into(), "RR".into()];
        s.q_maxes = vec![8.0];
        s.trials = trials;
        s.steps = Some(8);
        s
    }

    fn campaign(names: &[&str]) -> CampaignSpec {
        CampaignSpec {
            name: "c".into(),
            run_dir: None,
            members: names
                .iter()
                .enumerate()
                .map(|(i, n)| CampaignMember {
                    name: n.to_string(),
                    spec: member_spec(1 + i),
                    jobs: None,
                })
                .collect(),
        }
    }

    #[test]
    fn from_toml_reads_campaign_and_members() {
        let doc = TomlDoc::parse(
            r#"
[campaign]
name = "fig367"
run_dir = "runs/fig367"

[[campaign.sweep]]
name = "cifar"
model = "cnn_tiny"
q_maxes = [6, 8]
trials = 2
jobs = 1

[[campaign.sweep]]
model = "mlp"          # name defaults to the model
steps = 16
eval_every = 4
"#,
        )
        .unwrap();
        let c = CampaignSpec::from_toml(&doc).unwrap();
        assert_eq!(c.name, "fig367");
        assert_eq!(c.run_dir.as_deref(), Some(Path::new("runs/fig367")));
        assert_eq!(c.members.len(), 2);
        assert_eq!(c.members[0].name, "cifar");
        assert_eq!(c.members[0].spec.q_maxes, vec![6.0, 8.0]);
        assert_eq!(c.members[0].jobs, Some(1));
        assert_eq!(c.members[1].name, "mlp");
        assert_eq!(c.members[1].spec.steps, Some(16));
        assert_eq!(c.members[1].spec.eval_every, 4);
        assert_eq!(c.members[1].jobs, None);
        // jobs = 0 is rejected (it would deadlock the member)
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"x\"\n[[campaign.sweep]]\nmodel = \"mlp\"\njobs = 0",
        )
        .unwrap();
        assert!(CampaignSpec::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_rejects_bad_campaigns() {
        // no members
        let doc = TomlDoc::parse("[campaign]\nname = \"x\"").unwrap();
        assert!(CampaignSpec::from_toml(&doc)
            .unwrap_err()
            .to_string()
            .contains("no [[campaign.sweep]]"));
        // members may not set execution knobs
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"x\"\n[[campaign.sweep]]\nmodel = \"mlp\"\nshard = \"1/2\"",
        )
        .unwrap();
        assert!(CampaignSpec::from_toml(&doc)
            .unwrap_err()
            .to_string()
            .contains("unknown sweep key 'shard'"));
        // unknown [campaign] keys are typos, not silently dropped config
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"x\"\nrundir = \"y\"\n[[campaign.sweep]]\nmodel = \"mlp\"",
        )
        .unwrap();
        assert!(CampaignSpec::from_toml(&doc).is_err());
        // a misspelled table header must not silently drop a member
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"x\"\n[[campaign.sweep]]\nmodel = \"mlp\"\n[[campaign.sweeps]]\nmodel = \"mlp\"",
        )
        .unwrap();
        let err = CampaignSpec::from_toml(&doc).unwrap_err();
        assert!(err.to_string().contains("campaign.sweeps"), "{err:#}");
        // stray top-level keys and sections are rejected too
        let doc = TomlDoc::parse(
            "title = \"x\"\n[campaign]\nname = \"x\"\n[[campaign.sweep]]\nmodel = \"mlp\"",
        )
        .unwrap();
        assert!(CampaignSpec::from_toml(&doc).is_err());
        // a [sweep] preset section may not smuggle a 'name' key (inert
        // there), while members accept it — asymmetric by design
        let sec = TomlDoc::parse("[sweep]\nmodel = \"mlp\"\nname = \"x\"")
            .unwrap();
        let sec = sec.section("sweep").unwrap().clone();
        assert!(
            sweep_spec_from_section(&sec, SweepSectionKind::Preset).is_err()
        );
        assert!(sweep_spec_from_section(&sec, SweepSectionKind::CampaignMember)
            .is_ok());
    }

    #[test]
    fn member_policy_key_collapses_the_schedule_axis() {
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"p\"\n[[campaign.sweep]]\nmodel = \"mlp\"\n\
             policy = \"loss_plateau:patience=3\"\ntrials = 2",
        )
        .unwrap();
        let c = CampaignSpec::from_toml(&doc).unwrap();
        assert!(c.members[0].spec.policy.is_adaptive());
        assert_eq!(
            c.members[0].spec.schedules,
            vec!["LOSS_PLATEAU".to_string()],
            "adaptive member must collapse to one schedule-axis entry"
        );
        // an explicit schedules list alongside an adaptive policy is
        // rejected — every entry would run the identical cell
        let doc = TomlDoc::parse(
            "[campaign]\nname = \"p\"\n[[campaign.sweep]]\nmodel = \"mlp\"\n\
             policy = \"cost_governor\"\nschedules = [\"CR\"]",
        )
        .unwrap();
        let err = CampaignSpec::from_toml(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("drives q_t"), "{err:#}");
        // set_policy refuses to downgrade an adaptive spec to static
        let mut spec = SweepSpec::new("mlp");
        set_policy(&mut spec, PolicySpec::parse("cost_governor").unwrap(), false)
            .unwrap();
        let err = set_policy(&mut spec, PolicySpec::StaticSuite, false)
            .unwrap_err();
        assert!(format!("{err:#}").contains("cannot override"), "{err:#}");
    }

    #[test]
    fn plan_rejects_bad_member_names() {
        for bad in
            ["", "a/b", "..", ".hidden", "run-manifest.json", "campaign"]
        {
            let c = campaign(&[bad]);
            assert!(
                CampaignPlan::build(&c).is_err(),
                "accepted member name '{bad}'"
            );
        }
        let c = campaign(&["a", "a"]);
        let err = CampaignPlan::build(&c).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err:#}");
        // the campaign name lands in the default CSV path — same alphabet
        let mut c = campaign(&["a"]);
        c.name = "fig/3..7".into();
        let err = CampaignPlan::build(&c).unwrap_err();
        assert!(err.to_string().contains("campaign name"), "{err:#}");
    }

    #[test]
    fn member_order_is_canonical_regardless_of_listing_order() {
        propcheck(50, |rng| {
            let n = 2 + rng.below(4) as usize;
            let names: Vec<String> =
                (0..n).map(|i| format!("m{i}")).collect();
            // a random permutation of the members (Fisher-Yates)
            let mut shuffled: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = rng.below(i as u32 + 1) as usize;
                shuffled.swap(i, j);
            }
            let in_order = CampaignSpec {
                name: "c".into(),
                run_dir: None,
                members: (0..n)
                    .map(|i| CampaignMember {
                        name: names[i].clone(),
                        spec: member_spec(1 + i),
                        jobs: None,
                    })
                    .collect(),
            };
            let permuted = CampaignSpec {
                name: "c".into(),
                run_dir: None,
                members: shuffled
                    .iter()
                    .map(|&i| CampaignMember {
                        name: names[i].clone(),
                        spec: member_spec(1 + i),
                        jobs: None,
                    })
                    .collect(),
            };
            let a = CampaignPlan::build(&in_order).unwrap();
            let b = CampaignPlan::build(&permuted).unwrap();
            let order_a: Vec<&str> =
                a.members.iter().map(|m| m.name.as_str()).collect();
            let order_b: Vec<&str> =
                b.members.iter().map(|m| m.name.as_str()).collect();
            prop_assert!(order_a == order_b, "{order_a:?} != {order_b:?}");
            prop_assert!(
                a.campaign_hash == b.campaign_hash,
                "hash depends on listing order"
            );
            // and the hash is stable across rebuilds
            prop_assert!(
                CampaignPlan::build(&in_order).unwrap().campaign_hash
                    == a.campaign_hash,
                "hash unstable"
            );
            Ok(())
        });
    }

    #[test]
    fn campaign_hash_tracks_result_determining_member_fields_only() {
        propcheck(100, |rng| {
            let base = campaign(&["a", "b"]);
            let base_hash = CampaignPlan::build(&base).unwrap().campaign_hash;
            let which = rng.below(2) as usize;
            let mut c = campaign(&["a", "b"]);
            // an execution knob never moves the hash...
            match rng.below(6) {
                0 => c.members[which].spec.jobs = 2 + rng.below(6) as usize,
                1 => c.members[which].spec.verbose = true,
                2 => {
                    c.members[which].spec.shard = Some(ShardId {
                        index: 1,
                        count: 2 + rng.below(3) as usize,
                    })
                }
                3 => c.members[which].spec.run_dir = Some("/tmp/x".into()),
                4 => c.members[which].spec.resume = true,
                // the member-level in-flight cap is an execution knob too
                _ => c.members[which].jobs = Some(1 + rng.below(4) as usize),
            }
            let hash = CampaignPlan::build(&c).unwrap().campaign_hash;
            prop_assert!(
                hash == base_hash,
                "execution knob changed the campaign hash"
            );
            // ...and a result-determining change always does
            let mut c = campaign(&["a", "b"]);
            match rng.below(8) {
                0 => c.members[which].spec.trials += 1,
                1 => c.members[which].spec.steps = Some(9999),
                2 => c.members[which].spec.cycles = Some(3),
                3 => c.members[which].spec.q_maxes.push(4.0),
                4 => c.members[which].spec.schedules.push("ETH".into()),
                5 => c.members[which].spec.eval_every = 5,
                // the precision policy determines the realized trace
                6 => {
                    c.members[which].spec.policy =
                        PolicySpec::parse("loss_plateau").unwrap()
                }
                // renames change the report keying, so they count too
                _ => c.members[which].name.push('x'),
            }
            let hash = CampaignPlan::build(&c).unwrap().campaign_hash;
            prop_assert!(
                hash != base_hash,
                "result-determining change kept the campaign hash"
            );
            // membership changes count as well
            let bigger = campaign(&["a", "b", "c"]);
            prop_assert!(
                CampaignPlan::build(&bigger).unwrap().campaign_hash
                    != base_hash,
                "adding a member kept the campaign hash"
            );
            Ok(())
        });
    }

    #[test]
    fn flattened_items_are_canonical_and_respect_member_boundaries() {
        // The global scheduler's work-item list must be identical for
        // any two processes that agree on the campaign — independent of
        // TOML listing order — and each item must point at exactly one
        // member's own cells (the store route).
        propcheck(50, |rng| {
            let n = 2 + rng.below(3) as usize;
            let names: Vec<String> = (0..n).map(|i| format!("m{i}")).collect();
            let mut shuffled: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = rng.below(i as u32 + 1) as usize;
                shuffled.swap(i, j);
            }
            let build = |order: &[usize]| CampaignSpec {
                name: "c".into(),
                run_dir: None,
                members: order
                    .iter()
                    .map(|&i| CampaignMember {
                        name: names[i].clone(),
                        spec: member_spec(1 + i),
                        jobs: None,
                    })
                    .collect(),
            };
            let in_order: Vec<usize> = (0..n).collect();
            let a = CampaignPlan::build(&build(&in_order)).unwrap();
            let b = CampaignPlan::build(&build(&shuffled)).unwrap();
            let count = 1 + rng.below(3) as usize;
            let index = 1 + rng.below(count as u32) as usize;
            let shard = ShardId { index, count };
            let fa = a.flatten_owned(shard);
            let fb = b.flatten_owned(shard);
            prop_assert!(fa == fb, "flattened order depends on TOML order");
            // concatenation of per-member owned lists, member by member
            let mut expect = Vec::new();
            for (mi, m) in a.members.iter().enumerate() {
                let mut s = m.spec.clone();
                s.shard = Some(shard);
                for pc in SweepPlan::build(&s).unwrap().owned() {
                    expect.push((mi, pc));
                }
            }
            prop_assert!(
                fa == expect,
                "flatten disagrees with per-member owned() lists"
            );
            // member routing: indices in range, cells belong to their
            // member's own plan
            for (mi, pc) in &fa {
                prop_assert!(*mi < a.members.len(), "member {mi} oob");
                prop_assert!(
                    a.members[*mi].plan.cells[pc.index] == pc.cell,
                    "item cell is not its member's cell"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn campaign_root_fences_resume() {
        let root = std::env::temp_dir().join("cpt_campaign_root_fences");
        std::fs::remove_dir_all(&root).ok();
        let plan = CampaignPlan::build(&campaign(&["a", "b"])).unwrap();
        let shard = ShardId::single();
        open_campaign_root(&root, &plan, shard, false).unwrap();
        // reopening needs resume
        let err =
            open_campaign_root(&root, &plan, shard, false).unwrap_err();
        assert!(err.to_string().contains("--resume"), "{err:#}");
        // same plan resumes
        open_campaign_root(&root, &plan, shard, true).unwrap();
        // a different campaign refuses
        let other = CampaignPlan::build(&campaign(&["a", "zz"])).unwrap();
        let err = open_campaign_root(&root, &other, shard, true).unwrap_err();
        assert!(err.to_string().contains("different campaign"), "{err:#}");
        // a different shard refuses
        let err = open_campaign_root(
            &root,
            &plan,
            ShardId { index: 1, count: 2 },
            true,
        )
        .unwrap_err();
        assert!(err.to_string().contains("shard"), "{err:#}");
        // a different code version refuses
        let mp = root.join(CAMPAIGN_MANIFEST_FILE);
        let edited = std::fs::read_to_string(&mp)
            .unwrap()
            .replace(RunStore::code_version(), "0.0.0-other-build");
        std::fs::write(&mp, edited).unwrap();
        let err = open_campaign_root(&root, &plan, shard, true).unwrap_err();
        assert!(err.to_string().contains("this binary"), "{err:#}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn campaign_rename_relabels_root_instead_of_refusing() {
        let root = std::env::temp_dir().join("cpt_campaign_rename");
        std::fs::remove_dir_all(&root).ok();
        let plan = CampaignPlan::build(&campaign(&["a", "b"])).unwrap();
        open_campaign_root(&root, &plan, ShardId::single(), false).unwrap();
        // same members, new label: resume succeeds and relabels
        let mut renamed_spec = campaign(&["a", "b"]);
        renamed_spec.name = "c-v2".into();
        let renamed = CampaignPlan::build(&renamed_spec).unwrap();
        assert_eq!(renamed.campaign_hash, plan.campaign_hash);
        let cm =
            open_campaign_root(&root, &renamed, ShardId::single(), true)
                .unwrap();
        assert_eq!(cm.name, "c-v2");
        assert_eq!(read_campaign_manifest(&root).unwrap().name, "c-v2");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn manifest_kinds_never_stack_in_one_directory() {
        let dir = std::env::temp_dir().join("cpt_campaign_kind_clash");
        std::fs::remove_dir_all(&dir).ok();
        // a sweep run dir refuses to become a campaign root...
        let mut s = member_spec(1);
        s.shard = Some(ShardId::single());
        let splan = SweepPlan::build(&s).unwrap();
        drop(RunStore::open(&dir, &splan, "fp-test", false).unwrap());
        let plan = CampaignPlan::build(&campaign(&["a"])).unwrap();
        let err = open_campaign_root(&dir, &plan, ShardId::single(), false)
            .unwrap_err();
        assert!(err.to_string().contains("sweep run dir"), "{err:#}");
        // ...and a campaign root refuses to host a sweep store directly
        let root = std::env::temp_dir().join("cpt_campaign_kind_clash2");
        std::fs::remove_dir_all(&root).ok();
        open_campaign_root(&root, &plan, ShardId::single(), false).unwrap();
        let err =
            RunStore::open(&root, &splan, "fp-test", false).unwrap_err();
        assert!(err.to_string().contains("campaign root"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn campaign_manifest_rejects_redirected_member_dirs() {
        // status/gc/merge follow MemberEntry.dir; a manifest pointing a
        // member outside the root must be refused at read time
        let root = std::env::temp_dir().join("cpt_campaign_dir_redirect");
        std::fs::remove_dir_all(&root).ok();
        let plan = CampaignPlan::build(&campaign(&["a", "b"])).unwrap();
        open_campaign_root(&root, &plan, ShardId::single(), false).unwrap();
        let mp = root.join(CAMPAIGN_MANIFEST_FILE);
        let src = std::fs::read_to_string(&mp).unwrap();
        let edited = src.replace("\"dir\": \"a\"", "\"dir\": \"../evil\"");
        std::fs::write(&mp, &edited).unwrap();
        let err = read_campaign_manifest(&root).unwrap_err();
        assert!(err.to_string().contains("must equal"), "{err:#}");
        // a path-unsafe campaign *name* is refused the same way (it
        // feeds the default CSV directory)
        let edited = src.replace("\"name\": \"c\"", "\"name\": \"../evil\"");
        std::fs::write(&mp, edited).unwrap();
        let err = read_campaign_manifest(&root).unwrap_err();
        assert!(err.to_string().contains("campaign name"), "{err:#}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn status_errors_on_unrecognized_dirs() {
        let dir = std::env::temp_dir().join("cpt_campaign_status_none");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        assert!(status(&dir).is_err());
        assert!(gc(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! `cpt` — command-line launcher for the CPT reproduction.
//!
//! Subcommands:
//!   info                         list models in the artifact manifest
//!   schedules [--csv PATH]       dump S(t)/q_t series for the suite (Fig 2)
//!   train     --model M [...]    one training run with a chosen schedule
//!   sweep     --model M [...]    schedule suite sweep (one figure panel)
//!   range-test --model M [...]   precision range test (discovers q_min)
//!   preset    --file F.toml      run a sweep described by a preset file
//!
//! Run `cpt <subcommand> --help` for flags.

use anyhow::{bail, Context, Result};

use cpt::coordinator::{self, recipes};
use cpt::prelude::*;
use cpt::quant::range_test;
use cpt::schedule::relative_cost;
use cpt::{artifacts_dir, config::toml::TomlDoc, results_dir};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::from_env()?;
    match cli.command.as_str() {
        "info" => cmd_info(&cli),
        "schedules" => cmd_schedules(&cli),
        "train" => cmd_train(&cli),
        "sweep" => cmd_sweep(&cli),
        "range-test" => cmd_range_test(&cli),
        "preset" => cmd_preset(&cli),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `cpt help`)"),
    }
}

fn print_help() {
    println!(
        "cpt — Better Schedules for Low Precision Training (reproduction)

USAGE: cpt <subcommand> [flags]

  info                          list models in artifacts/manifest.json
  schedules [--total N] [--cycles N] [--qmin Q] [--qmax Q] [--csv PATH]
                                dump the schedule suite's q_t series (Fig 2)
  train --model M [--schedule CR] [--steps N] [--qmax 8] [--qmin Q]
        [--cycles N] [--trial T] [--eval-every N] [--verbose]
                                one training run
  sweep --model M [--schedules CR,RR,...] [--qmaxes 6,8] [--trials N]
        [--steps N] [--cycles N] [--jobs N] [--csv PATH] [--verbose]
                                full schedule sweep (one figure panel);
                                --jobs N > 1 fans cells over N workers
                                (results identical to serial)
  range-test --model M [--qlo 2] [--qhi 8] [--probe-steps N]
                                discover q_min (paper §3.1)
  preset --file configs/X.toml  run a sweep preset

ENV: CPT_ARTIFACTS (default: artifacts), CPT_RESULTS (default: results),
     CPT_JOBS (default sweep worker count, default: 1)"
    );
}

fn cmd_info(_cli: &Cli) -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    println!("chunk size K = {}", manifest.chunk);
    println!(
        "{:<18} {:>10} {:>10} {:>14} {:>14} {:>8}",
        "model", "params", "opt", "qGEMM MFLOP", "fpGEMM MFLOP", "metric"
    );
    for (name, m) in &manifest.models {
        println!(
            "{:<18} {:>10} {:>10} {:>14.2} {:>14.2} {:>8}",
            name,
            m.param_count,
            m.opt_state_count,
            m.q_gemm_flops_fwd as f64 / 1e6,
            m.fp_gemm_flops_fwd as f64 / 1e6,
            m.metric
        );
    }
    Ok(())
}

fn cmd_schedules(cli: &Cli) -> Result<()> {
    cli.check_known(&["total", "cycles", "qmin", "qmax", "csv"])?;
    let total = cli.usize_or("total", 800)?;
    let n = cli.usize_or("cycles", 8)?;
    let q_min = cli.f64_or("qmin", 3.0)?;
    let q_max = cli.f64_or("qmax", 8.0)?;

    println!(
        "{:<10} {:<10} {:>10} {:>12}",
        "schedule", "group", "mean q/qmax", "rel. cost"
    );
    for name in suite::suite_names() {
        let s = suite::by_name(name, q_min, q_max, total, n)?;
        println!(
            "{:<10} {:<10} {:>10.4} {:>12.4}",
            name,
            group_of(name).label(),
            s.mean_relative_precision(total),
            relative_cost(&s, q_max, total),
        );
    }

    if let Some(path) = cli.flag("csv") {
        let mut w = cpt::metrics::CsvWriter::new(&["schedule", "t", "s_t", "q_t"]);
        for name in suite::suite_names() {
            let s = suite::by_name(name, q_min, q_max, total, n)?;
            for t in 0..total {
                w.row(&[
                    name.to_string(),
                    t.to_string(),
                    format!("{:.4}", s.value_at(t)),
                    s.q_at(t).to_string(),
                ]);
            }
        }
        w.write_to(path)?;
        println!("wrote series to {path}");
    }
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "model", "schedule", "steps", "qmax", "qmin", "cycles", "trial",
        "eval-every", "verbose", "curve-csv",
    ])?;
    let model_name = cli.require("model")?;
    let sched_name = cli.str_or("schedule", "CR");
    let rec = recipes::recipe(model_name)?;
    let steps = cli.usize_or("steps", rec.steps)?;
    let q_max = cli.f64_or("qmax", 8.0)?;
    let _q_min = cli.f64_or("qmin", rec.q_min)?;
    let cycles = cli.usize_or("cycles", rec.cycles)?;
    let trial = cli.usize_or("trial", 0)?;
    let eval_every = cli.usize_or("eval-every", (steps / 8).max(1))?;

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir())?;
    let model = rt.load_model(manifest.model(model_name)?)?;
    let out = coordinator::run_one(
        &model, model_name, &sched_name, q_max, trial, steps, cycles,
        eval_every, cli.bool("verbose"),
    )?;
    println!(
        "{model_name} {sched_name} q_max={q_max}: metric={:.4} eval_loss={:.4} ({:.3} GBitOps, {:.1}s exec)",
        out.metric, out.eval_loss, out.gbitops, out.exec_seconds
    );
    if let Some(path) = cli.flag("curve-csv") {
        let rep = SweepReport::new("train", "metric", rec.higher_is_better);
        rep.write_curves_csv(&[out], path)?;
        println!("wrote loss curve to {path}");
    }
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "model", "schedules", "qmaxes", "trials", "steps", "cycles", "jobs",
        "csv", "verbose",
    ])?;
    let model = cli.require("model")?;
    let rec = recipes::recipe(model)?;
    let mut spec = SweepSpec::new(model);
    if cli.flag("schedules").is_some() {
        spec.schedules = cli.list_or("schedules", &[]);
    }
    spec.q_maxes = cli
        .list_or("qmaxes", &["6", "8"])
        .iter()
        .map(|s| s.parse::<f64>().context("bad qmax"))
        .collect::<Result<_>>()?;
    spec.trials = cli.usize_or("trials", 1)?;
    spec.steps = cli.flag("steps").map(|s| s.parse()).transpose()?;
    spec.cycles = cli.flag("cycles").map(|s| s.parse()).transpose()?;
    spec.jobs = cli.usize_or("jobs", spec.jobs)?;
    spec.verbose = cli.bool("verbose");

    let manifest = Manifest::load(artifacts_dir())?;
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let rows = aggregate(&outs);
    let rep = SweepReport::new(model, "metric", rec.higher_is_better);
    rep.print(&rows);
    println!(
        "\nsweep wall-clock: {:.2}s for {} cells on {} worker(s)",
        timing.wall_seconds, timing.cells, timing.jobs
    );
    let csv = cli.str_or(
        "csv",
        &results_dir().join(format!("sweep_{model}.csv")).to_string_lossy(),
    );
    rep.write_csv_with_timing(&rows, timing, &csv)?;
    println!("wrote {csv}");
    Ok(())
}

fn cmd_range_test(cli: &Cli) -> Result<()> {
    cli.check_known(&["model", "qlo", "qhi", "probe-steps"])?;
    let model_name = cli.require("model")?;
    let q_lo = cli.usize_or("qlo", 2)? as u32;
    let q_hi = cli.usize_or("qhi", 8)? as u32;
    let probe_steps = cli.usize_or("probe-steps", 32)?;

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir())?;
    let model = rt.load_model(manifest.model(model_name)?)?;
    let rec = recipes::recipe(model_name)?;

    let outcome = range_test(
        |q: u32| {
            let out = coordinator::run_one(
                &model, model_name, "STATIC", q as f64, 0, probe_steps,
                rec.cycles, 0, false,
            )?;
            let first = out
                .history
                .losses
                .first()
                .map(|&(_, l)| l)
                .unwrap_or(f32::NAN);
            let last = out.history.tail_train_loss(4);
            println!(
                "  probe q={q}: loss {first:.4} -> {last:.4}"
            );
            Ok((first, last))
        },
        q_lo,
        q_hi,
        0.02,
    )?;
    println!(
        "range test for {model_name}: q_min = {} (paper protocol §3.1)",
        outcome.q_min
    );
    Ok(())
}

fn cmd_preset(cli: &Cli) -> Result<()> {
    cli.check_known(&["file"])?;
    let path = cli.require("file")?;
    let doc = TomlDoc::load(path)?;
    let s = doc
        .section("sweep")
        .context("preset needs a [sweep] section")?;
    let model = s
        .get("model")
        .context("[sweep] needs model")?
        .as_str()?
        .to_string();
    let rec = recipes::recipe(&model)?;
    let mut spec = SweepSpec::new(&model);
    if let Some(v) = s.get("schedules") {
        spec.schedules = v
            .as_list()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
    }
    if let Some(v) = s.get("q_maxes") {
        spec.q_maxes =
            v.as_list()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?;
    }
    if let Some(v) = s.get("trials") {
        spec.trials = v.as_usize()?;
    }
    if let Some(v) = s.get("steps") {
        spec.steps = Some(v.as_usize()?);
    }
    if let Some(v) = s.get("cycles") {
        spec.cycles = Some(v.as_usize()?);
    }
    if let Some(v) = s.get("jobs") {
        spec.jobs = v.as_usize()?;
    }
    let manifest = Manifest::load(artifacts_dir())?;
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let rows = aggregate(&outs);
    let title = doc
        .get("", "title")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("preset")
        .to_string();
    let rep = SweepReport::new(&title, "metric", rec.higher_is_better);
    rep.print(&rows);
    println!(
        "\nsweep wall-clock: {:.2}s for {} cells on {} worker(s)",
        timing.wall_seconds, timing.cells, timing.jobs
    );
    let csv = results_dir().join(format!("{title}.csv"));
    rep.write_csv_with_timing(&rows, timing, &csv)?;
    println!("wrote {}", csv.display());
    Ok(())
}

//! `cpt` — command-line launcher for the CPT reproduction.
//!
//! Subcommands:
//!   info                         list models in the artifact manifest
//!   schedules [--csv PATH]       dump S(t)/q_t series for the suite (Fig 2)
//!   train     --model M [...]    one training run with a chosen schedule
//!                                or an adaptive --policy
//!   sweep     --model M [...]    schedule suite sweep (one figure panel);
//!                                shardable + resumable via --shard/--run-dir;
//!                                --policy swaps the schedule suite for a
//!                                feedback-driven precision policy
//!   campaign  --file F.toml      run several named sweeps as one
//!                                content-addressed tree (a figure campaign)
//!   merge     DIR...             validate + combine shard run dirs — or
//!                                campaign roots — into the aggregate CSVs
//!   status    DIR                report done/remaining cells and per-cell
//!                                wall-clock for a run dir or campaign
//!                                root; on a serve root: job tickets and
//!                                states
//!   serve     --root DIR [...]   long-running campaign service: accepts
//!                                specs over localhost TCP, dedupes by
//!                                spec hash, caches finished CSVs
//!   submit    --connect A --file F.toml   submit a campaign spec to a
//!                                running `cpt serve` (ticket = spec hash)
//!   jobs      --connect A        list a serve daemon's jobs
//!   result    --connect A --ticket T     fetch a finished job's CSVs
//!   shutdown  --connect A        stop a serve daemon cleanly
//!   gc        DIR                compact artifacts (strip per-step
//!                                histories; aggregates are unchanged);
//!                                on an AOT cache dir: sweep + evict
//!   cache     status|gc          inspect / collect the persistent AOT
//!                                executable cache (CPT_AOT_CACHE)
//!   trace     DIR                per-worker/per-member timeline breakdown
//!                                of a traced run (`--trace` on sweep,
//!                                campaign, or serve)
//!   stats     --connect A        a serve daemon's self-description:
//!                                uptime, jobs by state, request/error
//!                                counters, pool compile/cache totals
//!   range-test --model M [...]   precision range test (discovers q_min)
//!   preset    --file F.toml      run a sweep described by a preset file
//!
//! Run `cpt help` for flags.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use cpt::coordinator::aot;
use cpt::coordinator::campaign::{
    self, set_policy, CampaignRunOpts, SchedulerKind, Status,
};
use cpt::coordinator::lease::{self, ClaimConfig, Clock, SystemClock};
use cpt::coordinator::{
    self, exec, merge_run_dirs, pool, recipes, ClaimerId, RunOutcome, ShardId,
};
use cpt::prelude::*;
use cpt::quant::range_test;
use cpt::schedule::relative_cost;
use cpt::server::{self, Client, JobState, ServeConfig, ServeOpts, Server};
use cpt::{artifacts_dir, config::toml::TomlDoc, results_dir};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    // strict CPT_LOG parsing up front: an unparsable level fails the
    // whole invocation loudly instead of silently logging at the default
    cpt::obs::log::init_from_env()?;
    let cli = Cli::from_env()?;
    match cli.command.as_str() {
        "info" => cmd_info(&cli),
        "schedules" => cmd_schedules(&cli),
        "train" => cmd_train(&cli),
        "sweep" => cmd_sweep(&cli),
        "campaign" => cmd_campaign(&cli),
        "merge" => cmd_merge(&cli),
        "status" => cmd_status(&cli),
        "trace" => cmd_trace(&cli),
        "stats" => cmd_stats(&cli),
        "gc" => cmd_gc(&cli),
        "cache" => cmd_cache(&cli),
        "range-test" => cmd_range_test(&cli),
        "preset" => cmd_preset(&cli),
        "serve" => cmd_serve(&cli),
        "submit" => cmd_submit(&cli),
        "jobs" => cmd_jobs(&cli),
        "result" => cmd_result(&cli),
        "shutdown" => cmd_shutdown(&cli),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `cpt help`)"),
    }
}

fn print_help() {
    println!(
        "cpt — Better Schedules for Low Precision Training (reproduction)

USAGE: cpt <subcommand> [flags]

  info                          list models in artifacts/manifest.json
  schedules [--total N] [--cycles N] [--qmin Q] [--qmax Q] [--csv PATH]
                                dump the schedule suite's q_t series (Fig 2)
  train --model M [--schedule CR | --policy P] [--steps N] [--qmax 8]
        [--qmin Q] [--cycles N] [--trial T] [--eval-every N] [--verbose]
                                one training run; --policy P runs an
                                adaptive precision policy instead of a
                                schedule (P = loss_plateau | cost_governor
                                | static, with optional key=val args,
                                e.g. loss_plateau:patience=3,ema=0.25)
  sweep --model M [--schedules CR,RR,... | --policy P] [--qmaxes 6,8]
        [--trials N] [--steps N] [--cycles N] [--jobs N] [--csv PATH]
        [--verbose] [--shard I/N] [--run-dir DIR] [--resume]
        [--claim NAME] [--trace]
                                full schedule sweep (one figure panel);
                                with --policy P the schedule axis
                                collapses to the policy (adaptive cells:
                                one per q_max x trial); stable CSVs carry
                                realized mean_q + realized_cost columns;
                                --jobs N > 1 fans cells over N workers
                                (results identical to serial);
                                --shard I/N runs shard I of an N-way
                                partition into --run-dir (one artifact
                                per cell + run-manifest.json);
                                --resume reopens a run dir and skips
                                cells with valid artifacts;
                                --claim NAME replaces --shard with
                                dynamic cell claiming: N processes
                                (unique NAMEs, shared --run-dir) divide
                                the cells via time-limited leases, so
                                the sweep finishes at the speed of the
                                surviving claimers — dead or stalled
                                peers have their expired leases stolen,
                                and no cell is ever recorded twice
  campaign --file configs/X.toml [--run-dir ROOT] [--shard I/N]
           [--jobs N] [--scheduler global|sequential] [--resume]
           [--csv-dir DIR] [--verbose] [--policy P] [--claim NAME]
           [--trace]
                                run a multi-sweep figure campaign: the
                                TOML's [[campaign.sweep]] members execute
                                in canonical (name-sorted) order, one
                                nested run dir per member under ROOT,
                                governed by campaign-manifest.json;
                                the default global scheduler fans every
                                member's cells over one shared --jobs N
                                pool (per-worker compiled-model cache;
                                members may cap themselves with jobs = N;
                                results byte-identical to sequential);
                                --shard I/N shards every member the same
                                way (one root per shard; combine with
                                `cpt merge ROOT1 ROOT2 ...`); --resume
                                reopens a root and skips recorded cells;
                                members may carry their own policy key
                                (policy = \"loss_plateau:...\") and
                                --policy P overrides every member;
                                --claim NAME (global scheduler only)
                                claims cells dynamically across every
                                member, like `sweep --claim`
  merge [--csv PATH] [--title T] DIR [DIR ...]
        [--csv-dir DIR] ROOT [ROOT ...]
                                validate N shard run dirs (matching spec
                                hashes, no missing/duplicate cells) and
                                emit the aggregate CSV a single-process
                                run would have produced; given campaign
                                roots instead, cross-merge every member
                                and write per-sweep CSVs + campaign.csv
                                (keyed by sweep name) under --csv-dir
  status DIR [--cells]          report progress straight from the
                                manifests: done/remaining cells,
                                recorded per-cell wall-clock, and (on
                                policy-era manifests) realized mean
                                q/qmax + relative cost, for one sweep
                                run dir or a whole campaign root; on a
                                serve root: every job's ticket, state
                                and done/planned cells from the durable
                                job records
  serve --root DIR [--listen 127.0.0.1:0] [--jobs N]
        [--concurrent-jobs N] [--allow-remote] [--file F.toml]
        [--verbose] [--aot-cache DIR] [--trace]
                                long-running campaign service: accepts
                                campaign specs over a line-delimited
                                JSON protocol on localhost TCP (bound
                                address published to <root>/serve-addr),
                                runs each through a persistent shared
                                worker pool into jobs/<ticket>/run, and
                                caches the finished CSV tree; the ticket
                                is the spec's campaign hash, so identical
                                submissions dedupe — in-flight jobs are
                                attached to, finished ones answer from
                                the store with zero new compiles/cells;
                                --concurrent-jobs admits N jobs to the
                                pool at once (fair-share across jobs, so
                                a small job behind a large one still
                                finishes fast) and jobs sharing a model
                                fingerprint reuse each other's compiled
                                executables; non-loopback --listen is
                                refused without --allow-remote (the
                                protocol has no authentication);
                                interrupted jobs resume on restart;
                                --file reads a [serve] table (root,
                                listen, jobs, concurrent_jobs), CLI
                                flags win
  submit --connect HOST:PORT --file configs/X.toml [--wait]
         [--out DIR] [--poll-ms N]
                                submit a campaign spec to a running
                                serve daemon; prints the job ticket and
                                whether it deduped; --wait polls to
                                completion; --out fetches the CSVs
                                (implies --wait)
  jobs --connect HOST:PORT      list the daemon's jobs: ticket, state,
                                live done/planned cells, per-job pool
                                stats (compiles/cache hits/disk hits),
                                campaign name
  result --connect HOST:PORT --ticket T [--out DIR]
                                fetch a finished job's CSV tree (default
                                out dir: <results>/serve_<ticket>)
  shutdown --connect HOST:PORT  stop the daemon gracefully: the worker
                                pool drains (in-flight cells finish and
                                stay durable), drained and queued jobs
                                resume on the next `cpt serve` of the
                                same root
  trace TRACED_DIR [--json] [--top N]
                                per-worker and per-member timeline
                                breakdown of a traced run (sweep run dir,
                                campaign root, or serve root run with
                                --trace): queue-wait/compile/exec/record
                                seconds per worker, compile/exec per
                                member, and the top N slowest cells;
                                tracing is off by default and
                                result-inert — traced CSVs are
                                byte-identical to untraced ones
  stats --connect HOST:PORT [--json]
                                a serve daemon's self-description:
                                uptime, job counts by state, request and
                                typed-error counters, and pool
                                compile/cache totals over finished jobs
  gc DIR [--max-age S] [--max-bytes N] | gc --connect HOST:PORT [...]
                                compact recorded cell artifacts (strip
                                per-step histories, keep every scalar);
                                merged/aggregate CSVs are byte-identical
                                before and after; given an AOT cache dir
                                instead, sweep orphaned .tmp files,
                                remove damaged entries, and evict
                                least-recently-used entries over the
                                CPT_AOT_CACHE_CAP byte budget; given a
                                serve root (or --connect to a live
                                daemon), prune finished job dirs older
                                than --max-age seconds and/or evict
                                least-recently-finished jobs until under
                                --max-bytes — queued/running jobs are
                                never touched
  cache status|gc [--aot-cache DIR] [--cap BYTES]
                                inspect or collect the persistent AOT
                                executable cache (dir from --aot-cache,
                                else CPT_AOT_CACHE); sweeps/campaigns
                                with the cache configured publish every
                                compile and warm-start later processes
                                on a backend that can serialize
                                executables (reported by `cache status`)
  range-test --model M [--qlo 2] [--qhi 8] [--probe-steps N]
                                discover q_min (paper §3.1)
  preset --file configs/X.toml [--shard I/N] [--run-dir D] [--resume]
         [--jobs N] [--verbose] [--policy P]
                                run a sweep preset ([sweep] may set
                                shard/run_dir/resume/jobs, a policy key,
                                or a [sweep.policy] table; these CLI
                                flags override it, so one preset file
                                drives every shard of a campaign)

ENV: CPT_ARTIFACTS (default: artifacts), CPT_RESULTS (default: results),
     CPT_JOBS (default sweep worker count, default: 1),
     CPT_EXEC_CACHE (compiled models kept per worker, default: 4),
     CPT_RUN_DIR (bench resume base dir — artifacts land under
     <dir>/<model>-<spec_hash>-<model_fingerprint>),
     CPT_LEASE_SECS (--claim lease duration, default: 60),
     CPT_CLAIM_POLL_SECS (--claim board poll interval, default: lease/4),
     CPT_HALT_AFTER_CELLS (fault injection: abort after N fresh cells),
     CPT_STALL_AFTER_CELLS / CPT_STALL_SECS (fault injection: a --claim
     worker goes dark for STALL_SECS after N committed cells),
     CPT_AOT_CACHE (persistent AOT executable cache dir; sweep/campaign/
     preset also accept --aot-cache DIR, which overrides the env),
     CPT_AOT_CACHE_CAP (gc byte budget for that cache),
     CPT_LOG (stderr log level: error|warn|info|debug, default: info —
     warn silences operational chatter, debug exposes per-cell
     claim/lease/steal detail);
     every knob fails loudly on an unparsable value"
    );
}

fn cmd_info(_cli: &Cli) -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    println!("chunk size K = {}", manifest.chunk);
    println!(
        "{:<18} {:>10} {:>10} {:>14} {:>14} {:>8}",
        "model", "params", "opt", "qGEMM MFLOP", "fpGEMM MFLOP", "metric"
    );
    for (name, m) in &manifest.models {
        println!(
            "{:<18} {:>10} {:>10} {:>14.2} {:>14.2} {:>8}",
            name,
            m.param_count,
            m.opt_state_count,
            m.q_gemm_flops_fwd as f64 / 1e6,
            m.fp_gemm_flops_fwd as f64 / 1e6,
            m.metric
        );
    }
    Ok(())
}

fn cmd_schedules(cli: &Cli) -> Result<()> {
    cli.check_known(&["total", "cycles", "qmin", "qmax", "csv"])?;
    let total = cli.usize_or("total", 800)?;
    let n = cli.usize_or("cycles", 8)?;
    let q_min = cli.f64_or("qmin", 3.0)?;
    let q_max = cli.f64_or("qmax", 8.0)?;

    println!(
        "{:<10} {:<10} {:>10} {:>12}",
        "schedule", "group", "mean q/qmax", "rel. cost"
    );
    for name in suite::suite_names() {
        let s = suite::by_name(name, q_min, q_max, total, n)?;
        println!(
            "{:<10} {:<10} {:>10.4} {:>12.4}",
            name,
            group_of(name).label(),
            s.mean_relative_precision(total),
            relative_cost(&s, q_max, total),
        );
    }

    if let Some(path) = cli.flag("csv") {
        let mut w = cpt::metrics::CsvWriter::new(&["schedule", "t", "s_t", "q_t"]);
        for name in suite::suite_names() {
            let s = suite::by_name(name, q_min, q_max, total, n)?;
            for t in 0..total {
                w.row(&[
                    name.to_string(),
                    t.to_string(),
                    format!("{:.4}", s.value_at(t)),
                    s.q_at(t).to_string(),
                ]);
            }
        }
        w.write_to(path)?;
        println!("wrote series to {path}");
    }
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "model", "schedule", "policy", "steps", "qmax", "qmin", "cycles",
        "trial", "eval-every", "verbose", "curve-csv",
    ])?;
    let model_name = cli.require("model")?;
    let policy = match cli.flag("policy") {
        Some(p) => PolicySpec::parse(p)?,
        None => PolicySpec::StaticSuite,
    };
    let sched_name = if policy.is_adaptive() {
        if cli.flag("schedule").is_some() {
            bail!(
                "--schedule conflicts with an adaptive --policy: the \
                 policy chooses q_t from training feedback"
            );
        }
        policy.label().to_string()
    } else {
        cli.str_or("schedule", "CR")
    };
    let rec = recipes::recipe(model_name)?;
    let steps = cli.usize_or("steps", rec.steps)?;
    let q_max = cli.f64_or("qmax", 8.0)?;
    let _q_min = cli.f64_or("qmin", rec.q_min)?;
    let cycles = cli.usize_or("cycles", rec.cycles)?;
    let trial = cli.usize_or("trial", 0)?;
    let eval_every = cli.usize_or("eval-every", (steps / 8).max(1))?;

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir())?;
    let model = rt.load_model(manifest.model(model_name)?)?;
    let out = coordinator::run_one_with_policy(
        &model, model_name, &policy, &sched_name, q_max, trial, steps,
        cycles, eval_every, cli.bool("verbose"),
    )?;
    println!(
        "{model_name} {sched_name} q_max={q_max}: metric={:.4} eval_loss={:.4} ({:.3} GBitOps, {:.1}s exec)",
        out.metric, out.eval_loss, out.gbitops, out.exec_seconds
    );
    println!(
        "realized trace: mean q/qmax {:.4}, relative cost {:.4} vs static q_max",
        out.mean_q, out.realized_cost
    );
    if let Some(path) = cli.flag("curve-csv") {
        let rep = SweepReport::new("train", "metric", rec.higher_is_better);
        rep.write_curves_csv(&[out], path)?;
        println!("wrote loss curve to {path}");
    }
    Ok(())
}

/// `--aot-cache DIR` overrides `CPT_AOT_CACHE` for this invocation. The
/// executors read the cache dir from the env at startup, so the flag
/// just installs it process-wide — called before any worker spawns.
fn apply_aot_flag(cli: &Cli) {
    if let Some(dir) = cli.flag("aot-cache") {
        std::env::set_var("CPT_AOT_CACHE", dir);
    }
}

/// `--trace` installs the process-global span tracer, writing JSONL
/// event files under `<root>/trace/`. Tracing is result-inert: the run's
/// CSVs are byte-identical with and without it (gated in check.sh).
fn install_tracer(root: &Path) -> Result<()> {
    let tracer = cpt::obs::trace::Tracer::create_system(root)?;
    if !cpt::obs::trace::install(tracer) {
        bail!("a tracer is already installed for this process");
    }
    Ok(())
}

/// Apply the shared sharding/persistence flags to a sweep spec.
fn apply_shard_flags(cli: &Cli, spec: &mut SweepSpec) -> Result<()> {
    if let Some(sh) = cli.flag("shard") {
        spec.shard = Some(ShardId::parse(sh)?);
    }
    if let Some(dir) = cli.flag("run-dir") {
        spec.run_dir = Some(PathBuf::from(dir));
    }
    // tri-state: absent keeps the preset's value; `--resume` /
    // `--resume=false` explicitly override it in either direction
    if cli.flag("resume").is_some() {
        spec.resume = cli.bool("resume");
    }
    if spec.shard.map_or(false, |s| s.count > 1) && spec.run_dir.is_none() {
        bail!(
            "--shard needs --run-dir: shard results must be persisted so \
             `cpt merge` can combine them"
        );
    }
    if spec.resume && spec.run_dir.is_none() {
        bail!(
            "--resume needs --run-dir: there is no run directory to resume \
             from, so the sweep would silently recompute everything"
        );
    }
    Ok(())
}

/// Shared post-run reporting for `sweep` and `preset`: table, timing
/// line, and either the aggregate CSV (whole sweep) or a merge hint
/// (one shard of many — a partial aggregate would be misleading, so an
/// explicitly requested --csv is called out as ignored).
fn report_sweep(
    title: &str,
    higher_is_better: bool,
    spec: &SweepSpec,
    outs: &[RunOutcome],
    timing: SweepTiming,
    csv: &Path,
    csv_explicit: bool,
) -> Result<()> {
    let rows = aggregate(outs);
    let sharded = spec.shard.map_or(false, |s| s.count > 1);
    // a shard's table only aggregates its round-robin subset of trials —
    // label it so nobody reads half-trial means as the panel result
    let shown_title = if sharded {
        format!(
            "{title} [shard {} — PARTIAL: subset of trials per row; run \
             `cpt merge` for panel results]",
            spec.shard.unwrap()
        )
    } else {
        title.to_string()
    };
    let rep = SweepReport::new(&shown_title, "metric", higher_is_better);
    rep.print(&rows);
    let resumed = if timing.resumed > 0 {
        format!(" ({} resumed from artifacts)", timing.resumed)
    } else {
        String::new()
    };
    println!(
        "\nsweep wall-clock: {:.2}s for {} cells on {} worker(s){resumed}",
        timing.wall_seconds, timing.cells, timing.jobs
    );
    match (spec.shard, &spec.run_dir) {
        (Some(shard), Some(dir)) if shard.count > 1 => {
            if csv_explicit {
                eprintln!(
                    "note: --csv {} ignored — one shard's aggregate would \
                     be partial; `cpt merge` writes the combined CSV",
                    csv.display()
                );
            }
            println!(
                "shard {shard} complete: {} cell artifact(s) in {}",
                timing.cells,
                dir.display()
            );
            println!(
                "combine all shards with: cpt merge --csv OUT <run dirs>"
            );
        }
        _ => {
            rep.write_csv_with_timing(&rows, timing, csv)?;
            println!("wrote {}", csv.display());
        }
    }
    Ok(())
}

/// Parse `--claim NAME`. The claimer name keys lease records, the
/// liveness file, and artifact suffixes, so it must be unique per
/// process — a bare `--claim` parses as the boolean "true", and two
/// workers both defaulting to the same name would silently break the
/// mutual exclusion the leases provide, so that spelling is rejected.
fn parse_claimer(name: &str) -> Result<ClaimerId> {
    if name == "true" {
        bail!(
            "--claim needs a unique claimer name (e.g. --claim host1-a): \
             leases, liveness, and artifacts are keyed by it"
        );
    }
    ClaimerId::parse(name)
}

fn print_claim_stats(cfg: &ClaimConfig, stats: &lease::ClaimRunStats) {
    println!(
        "claimer '{}': {} cell(s) committed here, {} lease(s) stolen, {} \
         record(s) refused, {} already on the board at start",
        cfg.claimer,
        stats.committed_here,
        stats.stolen,
        stats.exec.refused,
        stats.resumed()
    );
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "model", "schedules", "policy", "qmaxes", "trials", "steps",
        "cycles", "jobs", "csv", "verbose", "shard", "run-dir", "resume",
        "claim", "aot-cache", "trace",
    ])?;
    apply_aot_flag(cli);
    let model = cli.require("model")?;
    let rec = recipes::recipe(model)?;
    let mut spec = SweepSpec::new(model);
    if cli.flag("schedules").is_some() {
        spec.schedules = cli.list_or("schedules", &[]);
    }
    if let Some(p) = cli.flag("policy") {
        // adaptive policies collapse the schedule axis to the policy's
        // label (one cell per q_max x trial); an explicit --schedules
        // list alongside one is rejected inside set_policy
        set_policy(
            &mut spec,
            PolicySpec::parse(p)?,
            cli.flag("schedules").is_some(),
        )?;
    }
    spec.q_maxes = cli
        .list_or("qmaxes", &["6", "8"])
        .iter()
        .map(|s| s.parse::<f64>().context("bad qmax"))
        .collect::<Result<_>>()?;
    spec.trials = cli.usize_or("trials", 1)?;
    spec.steps = cli.flag("steps").map(|s| s.parse()).transpose()?;
    spec.cycles = cli.flag("cycles").map(|s| s.parse()).transpose()?;
    spec.jobs = cli.usize_or("jobs", spec.jobs)?;
    spec.verbose = cli.bool("verbose");
    apply_shard_flags(cli, &mut spec)?;
    if cli.bool("trace") {
        let dir = spec.run_dir.clone().context(
            "--trace needs --run-dir: trace files live under the run dir \
             (inspect them with `cpt trace DIR`)",
        )?;
        install_tracer(&dir)?;
    }

    let manifest = Manifest::load(artifacts_dir())?;
    let (outs, timing) = match cli.flag("claim") {
        Some(name) => {
            let cfg = ClaimConfig::from_env(parse_claimer(name)?)?;
            let (outs, timing, stats) =
                lease::run_claim_sweep(&manifest, &spec, &cfg)?;
            print_claim_stats(&cfg, &stats);
            (outs, timing)
        }
        None => run_sweep_timed(&manifest, &spec)?,
    };
    let csv = PathBuf::from(cli.str_or(
        "csv",
        &results_dir().join(format!("sweep_{model}.csv")).to_string_lossy(),
    ));
    report_sweep(
        model,
        rec.higher_is_better,
        &spec,
        &outs,
        timing,
        &csv,
        cli.flag("csv").is_some(),
    )
}

/// Aggregate + print every campaign member and write the campaign's CSV
/// tree — one stable CSV per member (byte-identical to an independent
/// run of that sweep) plus `campaign.csv` keyed by sweep name — under
/// `--csv-dir`, defaulting to `<results>/campaign_<name>`. Shared by
/// `cpt campaign` (unsharded) and `cpt merge` on campaign roots.
fn report_campaign(
    cli: &Cli,
    name: &str,
    members: &[(String, String, Vec<RunOutcome>)],
) -> Result<()> {
    let csv_dir = cli
        .flag("csv-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| results_dir().join(format!("campaign_{name}")));
    // one writer for the whole CSV tree, shared with `cpt serve`'s
    // result cache, so served results stay byte-identical to this path
    let keyed = coordinator::report::write_campaign_csv_tree(
        &csv_dir,
        members.iter().map(|(m, _, outs)| (m.as_str(), outs.as_slice())),
    )?;
    for ((member, model, _), (_, rows)) in members.iter().zip(&keyed) {
        let rec = recipes::recipe(model)?;
        SweepReport::new(
            &format!("campaign {name} · {member} ({model})"),
            "metric",
            rec.higher_is_better,
        )
        .print(rows);
    }
    println!(
        "\nwrote {} member CSV(s) + campaign.csv under {}",
        members.len(),
        csv_dir.display()
    );
    Ok(())
}

fn cmd_campaign(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "file", "run-dir", "shard", "jobs", "resume", "verbose", "csv-dir",
        "scheduler", "policy", "claim", "aot-cache", "trace",
    ])?;
    apply_aot_flag(cli);
    let path = cli.require("file")?;
    let doc = TomlDoc::load(path)?;
    let mut cspec = CampaignSpec::from_toml(&doc)?;
    if let Some(p) = cli.flag("policy") {
        // --policy overrides every member's policy (result-determining:
        // the campaign hash moves, so it lands in a different root). An
        // adaptive override replaces each member's schedule axis inside
        // set_policy; a `static` override of an adaptive member is
        // refused there — the member's schedule list is gone, so the
        // override would silently run the STATIC baseline instead.
        let pol = PolicySpec::parse(p)?;
        for m in &mut cspec.members {
            set_policy(&mut m.spec, pol.clone(), false)
                .with_context(|| format!("campaign member '{}'", m.name))?;
        }
    }
    let plan = CampaignPlan::build(&cspec)?;
    let root = cli
        .flag("run-dir")
        .map(PathBuf::from)
        .or_else(|| cspec.run_dir.clone())
        .context(
            "a campaign needs its root directory: pass --run-dir or set \
             run_dir in [campaign]",
        )?;
    if cli.bool("trace") {
        install_tracer(&root)?;
    }
    let shard = match cli.flag("shard") {
        Some(s) => ShardId::parse(s)?,
        None => ShardId::single(),
    };
    let scheduler = match cli.flag("scheduler") {
        Some(s) => SchedulerKind::parse(s)?,
        None => SchedulerKind::Global,
    };
    let opts = CampaignRunOpts {
        root: root.clone(),
        shard,
        jobs: cli.usize_or("jobs", cpt::default_jobs())?,
        resume: cli.bool("resume"),
        verbose: cli.bool("verbose"),
        scheduler,
    };
    let manifest = Manifest::load(artifacts_dir())?;
    let result = match cli.flag("claim") {
        Some(name) => {
            let cfg = ClaimConfig::from_env(parse_claimer(name)?)?;
            let (result, stats) =
                lease::run_claim_campaign(&manifest, &plan, &opts, &cfg)?;
            print_claim_stats(&cfg, &stats);
            result
        }
        None => run_campaign(&manifest, &plan, &opts)?,
    };

    for r in &result.members {
        println!(
            "sweep '{}' ({}): {} cell(s), {} resumed",
            r.name, r.model, r.timing.cells, r.timing.resumed
        );
    }
    if let Some(sc) = &result.scheduler {
        println!(
            "global scheduler: {} worker(s), {} compile(s) ({:.2}s \
             compiling), {} cache hit(s) ({} from disk), {} miss(es)",
            sc.jobs,
            sc.total_compiles(),
            sc.total_compile_seconds(),
            sc.total_hits(),
            sc.total_disk_hits(),
            sc.total_misses()
        );
    }
    println!(
        "campaign '{}' shard {shard}: {} cells ({} resumed) in {:.2}s -> {}",
        plan.name,
        result.total_cells(),
        result.total_resumed(),
        result.wall_seconds,
        root.display()
    );
    if shard.count > 1 {
        if cli.flag("csv-dir").is_some() {
            // one shard's aggregates would be partial and misleading
            eprintln!(
                "note: --csv-dir ignored — one shard's aggregate would be \
                 partial; `cpt merge` writes the campaign CSVs"
            );
        }
        println!(
            "shard {shard} complete: combine all roots with: cpt merge \
             --csv-dir OUT <campaign roots>"
        );
        return Ok(());
    }
    let members: Vec<(String, String, Vec<RunOutcome>)> = result
        .members
        .into_iter()
        .map(|r| (r.name, r.model, r.outcomes))
        .collect();
    report_campaign(cli, &plan.name, &members)
}

/// Print the claim boards of `members` (label, member run dir) and the
/// claimer liveness files under `root`, when the tree has ever been run
/// with `--claim`; silent otherwise, so static-shard trees look exactly
/// as they always did.
fn report_claim(root: &Path, members: &[(String, PathBuf)]) -> Result<()> {
    let now = SystemClock.now();
    let mut any = false;
    for (label, mdir) in members {
        let Some(board) = lease::claim_board_status(mdir, now)? else {
            continue;
        };
        any = true;
        let name =
            if label.is_empty() { "claim board" } else { label.as_str() };
        println!(
            "  {name}: {} committed, {} active lease(s), {} expired lease(s)",
            board.committed,
            board.active.len(),
            board.expired.len()
        );
        for l in board.active.iter().chain(board.expired.iter()) {
            let state = if l.remaining > 0.0 {
                format!("{:.0}s left", l.remaining)
            } else {
                format!("expired {:.0}s ago, steal-eligible", -l.remaining)
            };
            println!(
                "    cell {:05} leased by '{}' (generation {}, {state})",
                l.cell, l.claimer, l.generation
            );
        }
    }
    if !any {
        return Ok(());
    }
    for w in &lease::claim_workers(root, now)? {
        println!(
            "  claimer '{}': {} (last heartbeat {:.0}s ago, lease {:.0}s)",
            w.claimer,
            if w.looks_alive() { "alive" } else { "presumed dead" },
            w.since_last_seen.max(0.0),
            w.lease_secs
        );
    }
    Ok(())
}

fn cmd_status(cli: &Cli) -> Result<()> {
    cli.check_known(&["cells"])?;
    if cli.positional.len() != 1 {
        // the flag must follow the directory: a bare `--cells` would
        // otherwise swallow the next token as its value
        bail!("usage: cpt status RUN_DIR_OR_CAMPAIGN_ROOT [--cells]");
    }
    let dir = Path::new(&cli.positional[0]);
    // a serve root is neither a sweep run dir nor a campaign root: it
    // reports job tickets and states from its durable job records (live
    // progress for a running job comes from the nested campaign root)
    if server::jobs::is_serve_root(dir) {
        if cli.bool("cells") {
            eprintln!(
                "note: --cells applies to a single sweep run dir; a serve \
                 root reports per-job totals"
            );
        }
        let views = server::jobs::serve_status(dir)?;
        println!("serve root {} ({} job(s))", dir.display(), views.len());
        if !views.is_empty() {
            print_job_views(&views);
        }
        return Ok(());
    }
    match campaign::status(dir)? {
        Status::Sweep(m) => {
            println!(
                "sweep run dir {} (cpt {})",
                dir.display(),
                m.cpt_version
            );
            println!(
                "  model {}  shard {}  spec {}  fingerprint {}",
                m.model, m.shard, m.spec_hash, m.model_fingerprint
            );
            println!(
                "  cells: done {}/{} ({} remaining), exec {:.2}s recorded",
                m.done(),
                m.planned(),
                m.remaining(),
                m.exec_seconds()
            );
            // trace summaries exist only on policy-era manifests; old
            // trees simply print nothing here
            if let (Some(mq), Some(rc)) = (m.mean_q(), m.realized_cost()) {
                println!(
                    "  realized: mean q/qmax {mq:.4}, relative cost {rc:.4} \
                     (over recorded cells)"
                );
            }
            if cli.bool("cells") {
                for (index, e) in &m.cells {
                    let trace = match (e.mean_q, e.realized_cost) {
                        (Some(mq), Some(rc)) => {
                            format!("  meanq={mq:.3} cost={rc:.3}")
                        }
                        _ => String::new(),
                    };
                    println!(
                        "  {index:05}  {:<32} {:>8.2}s{trace}",
                        e.file, e.seconds
                    );
                }
            }
            report_claim(dir, &[(String::new(), dir.to_path_buf())])?;
        }
        Status::Campaign(c) => {
            if cli.bool("cells") {
                eprintln!(
                    "note: --cells applies to a single sweep run dir; a \
                     campaign root reports per-member totals"
                );
            }
            println!(
                "campaign '{}' root {} (hash {}, shard {})",
                c.name,
                dir.display(),
                c.campaign_hash,
                c.shard
            );
            for m in &c.members {
                let trace = match (m.mean_q, m.realized_cost) {
                    (Some(mq), Some(rc)) => {
                        format!(", meanq {mq:.3}, cost {rc:.3}")
                    }
                    _ => String::new(),
                };
                println!(
                    "  {:<16} {:<16} done {}/{} ({} remaining), exec {:.2}s{trace}",
                    m.name,
                    m.model,
                    m.done,
                    m.planned,
                    m.remaining(),
                    m.exec_seconds
                );
            }
            println!(
                "  total: done {}/{} ({} remaining), exec {:.2}s recorded",
                c.done(),
                c.planned(),
                c.remaining(),
                c.exec_seconds()
            );
            if let Some(sc) = &c.scheduler {
                println!(
                    "  scheduler: {} worker(s), {} compile(s) ({:.2}s \
                     compiling), {} cache hit(s) ({} from disk), {} \
                     miss(es) in the last global run",
                    sc.jobs,
                    sc.total_compiles(),
                    sc.total_compile_seconds(),
                    sc.total_hits(),
                    sc.total_disk_hits(),
                    sc.total_misses()
                );
                for w in &sc.workers {
                    println!(
                        "    worker {}: {} cell(s), {} compile(s) \
                         ({:.2}s), {} hit(s), {} disk hit(s), {} miss(es)",
                        w.worker,
                        w.cells,
                        w.compiles,
                        w.compile_seconds,
                        w.hits,
                        w.disk_hits,
                        w.misses
                    );
                }
            }
            let members: Vec<(String, PathBuf)> = c
                .members
                .iter()
                .map(|m| (m.name.clone(), dir.join(&m.name)))
                .collect();
            report_claim(dir, &members)?;
        }
    }
    Ok(())
}

fn cmd_gc(cli: &Cli) -> Result<()> {
    cli.check_known(&["max-age", "max-bytes", "connect"])?;
    let max_age = match cli.flag("max-age") {
        Some(_) => Some(cli.f64_or("max-age", 0.0)?),
        None => None,
    };
    let max_bytes = match cli.flag("max-bytes") {
        Some(v) => Some(v.parse::<u64>().with_context(|| {
            format!("--max-bytes expects an integer byte count, got '{v}'")
        })?),
        None => None,
    };
    // through a live daemon: the server prunes under its own state lock,
    // so queued/running jobs are never touched
    if let Some(addr) = cli.flag("connect") {
        if !cli.positional.is_empty() {
            bail!("cpt gc --connect takes no directory argument");
        }
        let (removed, freed) = Client::connect(addr)?.gc(max_age, max_bytes)?;
        println!("serve gc: removed {removed} job dir(s), freed {freed} bytes");
        return Ok(());
    }
    if cli.positional.len() != 1 {
        bail!("usage: cpt gc RUN_DIR_OR_CAMPAIGN_ROOT_OR_CACHE_OR_SERVE_ROOT");
    }
    let dir = Path::new(&cli.positional[0]);
    if server::jobs::is_serve_root(dir) {
        if max_age.is_none() && max_bytes.is_none() {
            bail!(
                "cpt gc on a serve root needs a policy: pass --max-age \
                 SECONDS and/or --max-bytes N"
            );
        }
        let out = server::jobs::gc_serve_root(
            dir,
            max_age,
            max_bytes,
            SystemClock.now(),
        )?;
        println!(
            "serve gc {}: removed {} finished job dir(s), freed {} bytes",
            dir.display(),
            out.removed.len(),
            out.bytes_freed
        );
        for t in &out.removed {
            println!("    pruned {t}");
        }
        return Ok(());
    }
    if max_age.is_some() || max_bytes.is_some() {
        bail!(
            "--max-age/--max-bytes apply to serve roots; {} is not one",
            dir.display()
        );
    }
    if aot::is_cache_dir(dir) {
        return gc_cache_dir(dir, aot::cache_cap_from_env()?);
    }
    let all = campaign::gc(dir)?;
    let (mut cells, mut compacted, mut orphaned, mut before, mut after) =
        (0usize, 0usize, 0usize, 0u64, 0u64);
    for (label, st) in &all {
        cells += st.cells;
        compacted += st.compacted;
        orphaned += st.orphaned_tmp;
        before += st.bytes_before;
        after += st.bytes_after;
        let name = if label.is_empty() { "run dir" } else { label.as_str() };
        let mut notes = String::new();
        if st.skipped > 0 {
            notes.push_str(&format!(" ({} skipped as damaged)", st.skipped));
        }
        if st.orphaned_tmp > 0 {
            notes.push_str(&format!(
                " ({} orphaned tmp file(s) removed)",
                st.orphaned_tmp
            ));
        }
        println!(
            "{name}: compacted {}/{} cell artifact(s), {} -> {} bytes{notes}",
            st.compacted, st.cells, st.bytes_before, st.bytes_after,
        );
    }
    println!(
        "gc {}: {compacted}/{cells} artifact(s) compacted, {orphaned} \
         orphaned tmp file(s) removed, {before} -> {after} bytes",
        dir.display()
    );
    Ok(())
}

/// Shared by `cpt gc CACHE_DIR` and `cpt cache gc`.
fn gc_cache_dir(dir: &Path, cap: Option<u64>) -> Result<()> {
    let st = aot::AotStore::open(dir)?.gc(cap)?;
    let budget = match cap {
        Some(b) => format!(" (budget {b} bytes)"),
        None => " (no byte budget: set CPT_AOT_CACHE_CAP or pass --cap)"
            .to_string(),
    };
    println!(
        "gc {}: {} entr{} kept, {} evicted, {} orphaned tmp file(s) \
         removed, {} -> {} bytes{budget}",
        dir.display(),
        st.cells,
        if st.cells == 1 { "y" } else { "ies" },
        st.evicted,
        st.orphaned_tmp,
        st.bytes_before,
        st.bytes_after,
    );
    Ok(())
}

fn cmd_cache(cli: &Cli) -> Result<()> {
    cli.check_known(&["aot-cache", "cap"])?;
    if cli.positional.len() != 1 {
        bail!("usage: cpt cache status|gc [--aot-cache DIR] [--cap BYTES]");
    }
    let dir = match cli.flag("aot-cache") {
        Some(d) => PathBuf::from(d),
        None => aot::cache_dir_from_env()?.context(
            "no cache dir: pass --aot-cache DIR or set CPT_AOT_CACHE",
        )?,
    };
    match cli.positional[0].as_str() {
        "status" => {
            let status = aot::AotStore::open(&dir)?.status()?;
            println!("AOT executable cache at {}", dir.display());
            match cpt::runtime::exec_serialization_support() {
                Ok(()) => println!("  serialization support: available"),
                Err(reason) => println!(
                    "  serialization support: unavailable — {reason}; \
                     runs fall back to plain compiles"
                ),
            }
            for e in &status.entries {
                let note = match &e.problem {
                    Some(p) => format!("  — {p}"),
                    None => String::new(),
                };
                println!(
                    "  entry {}  model {}  platform {}  cpt {}  {} \
                     payload(s)  {} bytes{note}",
                    e.id, e.model, e.platform, e.cpt_version, e.payloads,
                    e.bytes
                );
            }
            println!(
                "  total: {} entr{}, {} bytes",
                status.entries.len(),
                if status.entries.len() == 1 { "y" } else { "ies" },
                status.total_bytes
            );
            Ok(())
        }
        "gc" => {
            let cap = match cli.flag("cap") {
                Some(c) => Some(
                    c.parse::<u64>()
                        .with_context(|| format!("bad --cap '{c}'"))?,
                ),
                None => aot::cache_cap_from_env()?,
            };
            gc_cache_dir(&dir, cap)
        }
        other => bail!("unknown cache action '{other}' (known: status, gc)"),
    }
}

fn cmd_merge(cli: &Cli) -> Result<()> {
    cli.check_known(&["csv", "title", "csv-dir"])?;
    if cli.positional.is_empty() {
        bail!(
            "usage: cpt merge [--csv OUT] [--title T] RUN_DIR [RUN_DIR ...]\n\
             \x20      cpt merge [--csv-dir OUT] CAMPAIGN_ROOT [ROOT ...]"
        );
    }
    let dirs: Vec<PathBuf> =
        cli.positional.iter().map(PathBuf::from).collect();
    let roots = dirs
        .iter()
        .filter(|d| d.join(campaign::CAMPAIGN_MANIFEST_FILE).exists())
        .count();
    if roots > 0 {
        if roots != dirs.len() {
            bail!(
                "cannot mix campaign roots and sweep run dirs in one merge \
                 ({roots} of {} are campaign roots)",
                dirs.len()
            );
        }
        if cli.flag("csv").is_some() || cli.flag("title").is_some() {
            bail!(
                "--csv/--title apply to sweep merges; campaign merges \
                 write per-sweep CSVs + campaign.csv under --csv-dir"
            );
        }
        let merged = merge_campaign_roots(&dirs)?;
        let members: Vec<(String, String, Vec<RunOutcome>)> = merged
            .members
            .into_iter()
            .map(|m| (m.name, m.model, m.outcomes))
            .collect();
        report_campaign(cli, &merged.name, &members)?;
        println!(
            "merged campaign '{}' ({} sweeps) from {} root(s)",
            merged.name,
            members.len(),
            dirs.len()
        );
        return Ok(());
    }
    if cli.flag("csv-dir").is_some() {
        bail!("--csv-dir applies to campaign merges; use --csv for sweeps");
    }
    let (model, outs) = merge_run_dirs(&dirs)?;
    let rec = recipes::recipe(&model)?;
    let rows = aggregate(&outs);
    let title = cli.str_or("title", &format!("merged sweep ({model})"));
    let rep = SweepReport::new(&title, "metric", rec.higher_is_better);
    rep.print(&rows);
    let csv = cli.str_or(
        "csv",
        &results_dir()
            .join(format!("merged_{model}.csv"))
            .to_string_lossy(),
    );
    rep.write_csv_stable(&rows, &csv)?;
    println!(
        "\nmerged {} cells from {} run dir(s) -> {csv}",
        outs.len(),
        dirs.len()
    );
    Ok(())
}

fn cmd_range_test(cli: &Cli) -> Result<()> {
    cli.check_known(&["model", "qlo", "qhi", "probe-steps"])?;
    let model_name = cli.require("model")?;
    let q_lo = cli.usize_or("qlo", 2)? as u32;
    let q_hi = cli.usize_or("qhi", 8)? as u32;
    let probe_steps = cli.usize_or("probe-steps", 32)?;

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(artifacts_dir())?;
    let model = rt.load_model(manifest.model(model_name)?)?;
    let rec = recipes::recipe(model_name)?;

    let outcome = range_test(
        |q: u32| {
            let out = coordinator::run_one(
                &model, model_name, "STATIC", q as f64, 0, probe_steps,
                rec.cycles, 0, false,
            )?;
            let first = out
                .history
                .losses
                .first()
                .map(|&(_, l)| l)
                .unwrap_or(f32::NAN);
            let last = out.history.tail_train_loss(4);
            println!(
                "  probe q={q}: loss {first:.4} -> {last:.4}"
            );
            Ok((first, last))
        },
        q_lo,
        q_hi,
        0.02,
    )?;
    println!(
        "range test for {model_name}: q_min = {} (paper protocol §3.1)",
        outcome.q_min
    );
    Ok(())
}

fn cmd_preset(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "file", "shard", "run-dir", "resume", "jobs", "verbose", "policy",
        "aot-cache",
    ])?;
    apply_aot_flag(cli);
    let path = cli.require("file")?;
    let doc = TomlDoc::load(path)?;
    // reject misspelled sections up front: a typo'd [sweep.policy] (or
    // [sweep]) header would otherwise be silently ignored — a silent
    // result change, the same rule the key-level readers apply
    for name in doc.sections.keys() {
        if !["", "sweep", "sweep.policy"].contains(&name.as_str()) {
            bail!(
                "unknown section [{name}] in preset file (known: [sweep], \
                 [sweep.policy])"
            );
        }
    }
    if let Some(t) = doc.tables.keys().next() {
        bail!(
            "unexpected table [[{t}]] in a preset file (campaign files \
             with [[campaign.sweep]] members run via `cpt campaign`)"
        );
    }
    let s = doc
        .section("sweep")
        .context("preset needs a [sweep] section")?;
    // shared reader with [[campaign.sweep]] members; presets may also set
    // the execution knobs (shard/run_dir/resume/jobs/verbose), which the
    // CLI flags override — so one preset file can drive every
    // shard/machine of a multi-host run
    let mut spec = campaign::sweep_spec_from_section(
        s,
        campaign::SweepSectionKind::Preset,
    )?;
    let schedules_explicit = s.get("schedules").is_some();
    // a [sweep.policy] table is the long-form alternative to the compact
    // `policy` key inside [sweep]; exactly one of the two may appear
    if let Some(psec) = doc.section("sweep.policy") {
        if s.get("policy").is_some() {
            bail!(
                "preset sets both a [sweep] policy key and a \
                 [sweep.policy] table — keep one"
            );
        }
        set_policy(
            &mut spec,
            PolicySpec::from_section(psec)?,
            schedules_explicit,
        )?;
    }
    // The CLI flag overrides whatever the file chose: an adaptive
    // override replaces the schedule axis inside set_policy; a `static`
    // override of an adaptive preset is refused there (the preset's
    // original schedule list is gone, so silently running the STATIC
    // baseline would be a result change).
    if let Some(p) = cli.flag("policy") {
        set_policy(&mut spec, PolicySpec::parse(p)?, false)?;
    }
    let rec = recipes::recipe(&spec.model)?;
    spec.jobs = cli.usize_or("jobs", spec.jobs)?;
    if cli.bool("verbose") {
        spec.verbose = true;
    }
    apply_shard_flags(cli, &mut spec)?;
    let manifest = Manifest::load(artifacts_dir())?;
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let title = doc
        .get("", "title")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("preset")
        .to_string();
    let csv = results_dir().join(format!("{title}.csv"));
    report_sweep(&title, rec.higher_is_better, &spec, &outs, timing, &csv, false)
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    cli.check_known(&[
        "root",
        "listen",
        "jobs",
        "concurrent-jobs",
        "allow-remote",
        "file",
        "verbose",
        "aot-cache",
        "trace",
    ])?;
    apply_aot_flag(cli);
    let cfg = match cli.flag("file") {
        Some(path) => ServeConfig::from_toml(&TomlDoc::load(path)?)?,
        None => ServeConfig::default(),
    };
    let root = cli
        .flag("root")
        .map(PathBuf::from)
        .or(cfg.root)
        .context(
            "cpt serve needs its root directory: pass --root or set root \
             in [serve] of --file",
        )?;
    if cli.bool("trace") {
        install_tracer(&root)?;
    }
    let listen = cli
        .flag("listen")
        .map(str::to_string)
        .or(cfg.listen)
        .unwrap_or_else(|| server::DEFAULT_LISTEN.to_string());
    let jobs = match cli.flag("jobs") {
        Some(_) => cli.usize_or("jobs", 1)?,
        None => cfg.jobs.unwrap_or_else(cpt::default_jobs),
    };
    let concurrent = match cli.flag("concurrent-jobs") {
        Some(_) => cli.usize_or("concurrent-jobs", 1)?,
        None => cfg.concurrent_jobs.unwrap_or(1),
    };
    let manifest = Manifest::load(artifacts_dir())?;
    // One persistent worker pool for the daemon's whole lifetime: every
    // job's cells are multiplexed over the same workers, so a second job
    // sharing a model fingerprint reuses compiled executables instead of
    // recompiling (the cross-job warm start `cpt jobs` reports as hits).
    let specs = std::sync::Arc::new(exec::SpecRegistry::new());
    let cache_cap = exec::exec_cache_cap()?;
    let aot = aot::store_for_run()?.map(std::sync::Arc::new);
    let factory: std::sync::Arc<pool::WorkerFactory> = {
        let specs = specs.clone();
        std::sync::Arc::new(move |_worker| {
            let runner =
                exec::PjrtCellRunner::new(specs.clone(), cache_cap, aot.clone())?;
            Ok(Box::new(runner) as Box<dyn exec::CellRunner>)
        })
    };
    let pool =
        std::sync::Arc::new(pool::WorkerPool::new(jobs, "serve", factory));
    let exec: server::CampaignExec = {
        let specs = specs.clone();
        let pool = pool.clone();
        std::sync::Arc::new(move |plan, opts| {
            let mut fingerprints = std::collections::HashMap::new();
            for m in &plan.members {
                if !fingerprints.contains_key(&m.spec.model) {
                    let ms = manifest.model(&m.spec.model)?.clone();
                    ms.validate()?;
                    fingerprints.insert(
                        m.spec.model.clone(),
                        coordinator::store::model_fingerprint(&ms)?,
                    );
                    // idempotent: re-registering a model a later job
                    // shares is a no-op for already-warm workers
                    specs.insert(&m.spec.model, ms);
                }
            }
            campaign::run_campaign_pooled(plan, opts, &fingerprints, None, &pool)
        })
    };
    let drain: server::DrainHook = {
        let pool = pool.clone();
        std::sync::Arc::new(move || pool.shutdown())
    };
    let srv = Server::start(
        ServeOpts {
            root: root.clone(),
            listen,
            jobs,
            concurrent,
            allow_remote: cli.bool("allow-remote"),
            verbose: cli.bool("verbose"),
        },
        exec,
        Some(drain),
        std::sync::Arc::new(SystemClock),
    )?;
    println!(
        "cpt serve listening on {} (root {}; {} worker(s), {} concurrent \
         job(s); address also in {})",
        srv.addr(),
        root.display(),
        pool.size(),
        concurrent.max(1),
        root.join(server::jobs::SERVE_ADDR_FILE).display()
    );
    let res = srv.wait();
    // the daemon has stopped handing out work; drain in-flight cells and
    // release the PJRT clients before exiting
    pool.join();
    res
}

fn cmd_submit(cli: &Cli) -> Result<()> {
    cli.check_known(&["connect", "file", "wait", "out", "poll-ms"])?;
    let addr = cli.require("connect")?;
    let path = cli.require("file")?;
    let spec_toml = std::fs::read_to_string(path)
        .with_context(|| format!("read campaign spec {path}"))?;
    let mut client = Client::connect(addr)?;
    let (ticket, state, attached) = client.submit(&spec_toml)?;
    match (attached, state) {
        (true, JobState::Done) => println!(
            "ticket {ticket}: cache hit — result served from the store \
             (zero new cells)"
        ),
        (true, _) => println!(
            "ticket {ticket}: deduped — attached to the existing {state} job"
        ),
        (false, _) => println!("ticket {ticket}: queued"),
    }
    if cli.bool("wait") || cli.flag("out").is_some() {
        let poll_ms = cli.usize_or("poll-ms", 500)? as u64;
        let v = client.wait_done(&ticket, poll_ms)?;
        println!("job {ticket} done ({} cell(s) planned)", v.planned);
        if let Some(out) = cli.flag("out") {
            let out = PathBuf::from(out);
            let files = client.fetch_result(&ticket, &out)?;
            println!(
                "wrote {} CSV file(s) under {}",
                files.len(),
                out.display()
            );
        }
    }
    Ok(())
}

fn print_job_views(jobs: &[server::JobView]) {
    println!(
        "{:<18} {:<8} {:>13}  {:<22} {}",
        "ticket", "state", "done/planned", "compiles/hits/disk", "name"
    );
    for j in jobs {
        let done =
            j.done.map(|d| d.to_string()).unwrap_or_else(|| "?".to_string());
        let stats = match &j.stats {
            Some(s) => {
                format!("{}/{}/{}", s.compiles, s.hits, s.disk_hits)
            }
            None => "-".to_string(),
        };
        println!(
            "{:<18} {:<8} {:>6}/{:<6}  {:<22} {}",
            j.ticket, j.state, done, j.planned, stats, j.name
        );
        if let Some(e) = &j.error {
            println!("    error: {e}");
        }
    }
}

fn cmd_jobs(cli: &Cli) -> Result<()> {
    cli.check_known(&["connect"])?;
    let mut client = Client::connect(cli.require("connect")?)?;
    let jobs = client.jobs()?;
    if jobs.is_empty() {
        println!("no jobs submitted");
        return Ok(());
    }
    print_job_views(&jobs);
    Ok(())
}

fn cmd_result(cli: &Cli) -> Result<()> {
    cli.check_known(&["connect", "ticket", "out"])?;
    let addr = cli.require("connect")?;
    let ticket = cli.require("ticket")?;
    let out = cli
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| results_dir().join(format!("serve_{ticket}")));
    let mut client = Client::connect(addr)?;
    let files = client.fetch_result(ticket, &out)?;
    println!("wrote {} CSV file(s) under {}", files.len(), out.display());
    Ok(())
}

fn cmd_trace(cli: &Cli) -> Result<()> {
    cli.check_known(&["json", "top"])?;
    if cli.positional.len() != 1 {
        bail!("usage: cpt trace TRACED_DIR [--json] [--top N]");
    }
    let dir = Path::new(&cli.positional[0]);
    let top = cli.usize_or("top", 5)?;
    let events = cpt::obs::trace::read_root(dir)?;
    if events.is_empty() {
        bail!(
            "no trace events under {} — re-run the sweep/campaign/serve \
             with --trace",
            dir.display()
        );
    }
    let summary = cpt::obs::analyze::summarize(&events, top);
    if cli.bool("json") {
        println!("{}", summary.to_json().to_string_pretty());
    } else {
        print!("{}", summary.render_text());
    }
    Ok(())
}

fn cmd_stats(cli: &Cli) -> Result<()> {
    cli.check_known(&["connect", "json"])?;
    let mut client = Client::connect(cli.require("connect")?)?;
    let s = client.stats()?;
    if cli.bool("json") {
        println!("{}", s.to_json().to_string_pretty());
        return Ok(());
    }
    println!("uptime: {:.1}s", s.uptime_seconds);
    let jobs: Vec<String> = s
        .jobs_by_state
        .iter()
        .map(|(k, n)| format!("{n} {k}"))
        .collect();
    println!(
        "jobs: {}",
        if jobs.is_empty() { "none".to_string() } else { jobs.join(", ") }
    );
    println!("requests answered: {}", s.requests);
    if s.errors_by_code.is_empty() {
        println!("errors: none");
    } else {
        println!("errors:");
        for (code, n) in &s.errors_by_code {
            println!("  {code:<20} {n}");
        }
    }
    println!(
        "pool (finished jobs): {} compile(s) ({:.2}s compiling), {} cache \
         hit(s) ({} from disk), {} miss(es)",
        s.pool.compiles,
        s.pool.compile_seconds,
        s.pool.hits,
        s.pool.disk_hits,
        s.pool.misses
    );
    Ok(())
}

fn cmd_shutdown(cli: &Cli) -> Result<()> {
    cli.check_known(&["connect"])?;
    let mut client = Client::connect(cli.require("connect")?)?;
    client.shutdown()?;
    println!("server acknowledged shutdown");
    Ok(())
}

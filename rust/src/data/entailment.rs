//! Synthetic entailment pairs — the XNLI stand-in (paper Fig 7 right;
//! DESIGN.md §4).
//!
//! A premise is a Markov-chain sentence; the hypothesis is derived from
//! it with a class-dependent transformation:
//!   class 0 ("entail")     — a subsequence of the premise (light noise);
//!   class 1 ("neutral")    — shares the premise's prefix only;
//!   class 2 ("contradict") — premise tokens order-reversed + shifted.
//! The pair is packed [premise SEP hypothesis] into one sequence, as BERT
//! packs sentence pairs. A transformer must compare the two segments to
//! classify — mirroring the relational structure of NLI.

use anyhow::Result;

use super::text::MarkovCorpus;
use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

pub const SEP: i32 = 63; // reserved separator token (vocab 64)

pub struct EntailmentDataset {
    corpus: MarkovCorpus,
    pub seq: usize,
    pub batch: usize,
    rng: Pcg32,
    eval_seed: u64,
    n_eval: usize,
}

impl EntailmentDataset {
    pub fn new(seed: u64, seq: usize, batch: usize) -> Self {
        EntailmentDataset {
            corpus: MarkovCorpus::new(seed, 63, 40_000), // keep 63 for SEP
            seq,
            batch,
            rng: Pcg32::new(seed, 51),
            eval_seed: seed ^ 0xEA7A11,
            n_eval: 6,
        }
    }

    fn make_pair(&self, rng: &mut Pcg32, class: usize) -> Vec<i32> {
        let t = self.seq;
        let half = (t - 1) / 2;
        let start =
            rng.below((self.corpus.tokens.len() - 2 * t) as u32) as usize;
        let premise = &self.corpus.tokens[start..start + half];
        let hypothesis: Vec<i32> = match class {
            0 => {
                // entail: noisy subsequence
                let mut h: Vec<i32> = premise
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % 4 != 3)
                    .map(|(_, &x)| x)
                    .collect();
                while h.len() < half {
                    h.push(premise[h.len() % premise.len()]);
                }
                h
            }
            1 => {
                // neutral: same prefix, unrelated continuation
                let other = rng
                    .below((self.corpus.tokens.len() - half - 1) as u32)
                    as usize;
                let mut h = premise[..half / 4].to_vec();
                h.extend_from_slice(
                    &self.corpus.tokens[other..other + (half - half / 4)],
                );
                h
            }
            _ => {
                // contradict: reversed + shifted premise
                premise.iter().rev().map(|&x| (x + 7) % 63).collect()
            }
        };
        let mut seqv = Vec::with_capacity(t);
        seqv.extend_from_slice(premise);
        seqv.push(SEP);
        seqv.extend_from_slice(&hypothesis[..half]);
        while seqv.len() < t {
            seqv.push(SEP);
        }
        seqv.truncate(t);
        seqv
    }

    fn make_batch(&self, rng: &mut Pcg32) -> (HostTensor, HostTensor) {
        let (b, t) = (self.batch, self.seq);
        let mut xs = Vec::with_capacity(b * t);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let class = rng.below(3) as usize;
            ys.push(class as i32);
            xs.extend(self.make_pair(rng, class));
        }
        (
            HostTensor::I32(vec![b, t], xs),
            HostTensor::I32(vec![b], ys),
        )
    }
}

impl Dataset for EntailmentDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        let mut rng = self.rng.fork(0xE1);
        let (x, y) = self.make_batch(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>> {
        let mut rng = Pcg32::new(self.eval_seed, i as u64 + 3);
        let (x, y) = self.make_batch(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batches(&self) -> usize {
        self.n_eval
    }

    fn shared_static(&self) -> bool {
        true // no shared inputs; eval batches are seeded per index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_classes() {
        let mut d = EntailmentDataset::new(3, 32, 8);
        let b = d.train_batch(0).unwrap();
        assert_eq!(b[0].shape(), &[8, 32]);
        assert_eq!(b[1].shape(), &[8]);
        let HostTensor::I32(_, ys) = &b[1] else { panic!() };
        assert!(ys.iter().all(|&y| (0..3).contains(&y)));
        let HostTensor::I32(_, xs) = &b[0] else { panic!() };
        assert!(xs.iter().all(|&x| (0..64).contains(&x)));
        // every sequence contains the separator
        for row in 0..8 {
            assert!(xs[row * 32..(row + 1) * 32].contains(&SEP));
        }
    }

    #[test]
    fn entail_pairs_share_tokens_contradict_dont() {
        let mut d = EntailmentDataset::new(5, 32, 1);
        let mut rng = Pcg32::seeded(4);
        let overlap = |v: &[i32]| {
            let sep_pos = v.iter().position(|&x| x == SEP).unwrap();
            let (p, h) = (&v[..sep_pos], &v[sep_pos + 1..]);
            let hits = h.iter().filter(|x| p.contains(x)).count();
            hits as f64 / h.len() as f64
        };
        let mut o_entail = 0.0;
        let mut o_contra = 0.0;
        for _ in 0..20 {
            o_entail += overlap(&d.make_pair(&mut rng, 0));
            o_contra += overlap(&d.make_pair(&mut rng, 2));
        }
        assert!(
            o_entail > o_contra,
            "entail overlap {o_entail} <= contradict {o_contra}"
        );
    }
}

//! Gaussian-blob vector classification — the quickstart (MLP) workload.

use anyhow::Result;

use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

pub struct BlobDataset {
    pub dim: usize,
    pub classes: usize,
    pub batch: usize,
    centers: Vec<f32>,
    rng: Pcg32,
    eval_seed: u64,
    n_eval: usize,
}

impl BlobDataset {
    pub fn new(seed: u64, dim: usize, classes: usize, batch: usize) -> Self {
        let mut crng = Pcg32::new(seed, 61);
        let centers: Vec<f32> =
            (0..classes * dim).map(|_| 2.0 * crng.normal()).collect();
        BlobDataset {
            dim,
            classes,
            batch,
            centers,
            rng: Pcg32::new(seed, 62),
            eval_seed: seed ^ 0xB10B,
            n_eval: 4,
        }
    }

    fn make(&self, rng: &mut Pcg32) -> (HostTensor, HostTensor) {
        let (b, d) = (self.batch, self.dim);
        let mut xs = Vec::with_capacity(b * d);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let c = rng.below(self.classes as u32) as usize;
            ys.push(c as i32);
            for j in 0..d {
                xs.push(self.centers[c * d + j] + rng.normal());
            }
        }
        (HostTensor::F32(vec![b, d], xs), HostTensor::I32(vec![b], ys))
    }
}

impl Dataset for BlobDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        let mut rng = self.rng.fork(0xB1);
        let (x, y) = self.make(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>> {
        let mut rng = Pcg32::new(self.eval_seed, i as u64);
        let (x, y) = self.make(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batches(&self) -> usize {
        self.n_eval
    }

    fn shared_static(&self) -> bool {
        true // no shared inputs; eval batches are seeded per index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let mut d = BlobDataset::new(1, 32, 4, 8);
        let b = d.train_batch(0).unwrap();
        assert_eq!(b[0].shape(), &[8, 32]);
        assert_eq!(b[1].shape(), &[8]);
    }

    #[test]
    fn distinct_batches() {
        let mut d = BlobDataset::new(1, 32, 4, 8);
        let a = d.train_batch(0).unwrap();
        let b = d.train_batch(1).unwrap();
        match (&a[0], &b[0]) {
            (HostTensor::F32(_, x), HostTensor::F32(_, y)) => assert_ne!(x, y),
            _ => panic!(),
        }
    }
}

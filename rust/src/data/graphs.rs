//! Stochastic-block-model graphs — the OGBN-Arxiv / OGBN-Products
//! stand-in (paper §4.3, Figs 5/6/8).
//!
//! Nodes belong to `classes` communities; intra-community edges are much
//! likelier than inter-community ones, and node features are a noisy
//! community prototype — so aggregation over the (mostly intra-community)
//! neighborhood denoises features and a GCN genuinely benefits from
//! message passing, replicating the structure that makes OGBN node
//! classification non-trivial.
//!
//! Two aggregation-operator constructions:
//! * `full_adjacency()` — degree-normalized Â = D^{-1/2}(A+I)D^{-1/2}
//!   (GCN / full-graph training, paper Eq. 1);
//! * `sampled_adjacency(rng, s)` — GraphSAGE-style: per node, mean over
//!   `s` sampled neighbors (truncated sum; paper footnote 4). Re-sampled
//!   every epoch by the dataset wrapper.

use anyhow::Result;

use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

pub struct SbmGraph {
    pub nodes: usize,
    pub classes: usize,
    pub feat_dim: usize,
    /// adjacency list (undirected, no self loops)
    pub neighbors: Vec<Vec<usize>>,
    pub labels: Vec<i32>,
    pub feats: Vec<f32>, // [nodes, feat_dim]
    pub train_mask: Vec<f32>,
    pub val_mask: Vec<f32>,
}

impl SbmGraph {
    pub fn new(
        seed: u64,
        nodes: usize,
        classes: usize,
        feat_dim: usize,
        p_in: f64,
        p_out: f64,
        train_frac: f64,
    ) -> Self {
        let mut rng = Pcg32::new(seed, 11);
        let labels: Vec<i32> =
            (0..nodes).map(|_| rng.below(classes as u32) as i32).collect();

        // community feature prototypes
        let mut protos = vec![0f32; classes * feat_dim];
        for v in protos.iter_mut() {
            *v = rng.normal();
        }
        let mut feats = Vec::with_capacity(nodes * feat_dim);
        for i in 0..nodes {
            let c = labels[i] as usize;
            for j in 0..feat_dim {
                feats.push(protos[c * feat_dim + j] + 2.2 * rng.normal());
            }
        }

        // SBM edges
        let mut neighbors = vec![Vec::new(); nodes];
        for i in 0..nodes {
            for j in (i + 1)..nodes {
                let p = if labels[i] == labels[j] { p_in } else { p_out };
                if (rng.next_f32() as f64) < p {
                    neighbors[i].push(j);
                    neighbors[j].push(i);
                }
            }
        }

        // train/val split
        let mut idx: Vec<usize> = (0..nodes).collect();
        rng.shuffle(&mut idx);
        let n_train = (nodes as f64 * train_frac) as usize;
        let mut train_mask = vec![0f32; nodes];
        let mut val_mask = vec![0f32; nodes];
        for (k, &i) in idx.iter().enumerate() {
            if k < n_train {
                train_mask[i] = 1.0;
            } else {
                val_mask[i] = 1.0;
            }
        }

        SbmGraph {
            nodes,
            classes,
            feat_dim,
            neighbors,
            labels,
            feats,
            train_mask,
            val_mask,
        }
    }

    /// Dense Â = D^{-1/2} (A + I) D^{-1/2}.
    pub fn full_adjacency(&self) -> Vec<f32> {
        let n = self.nodes;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
            for &j in &self.neighbors[i] {
                a[i * n + j] = 1.0;
            }
        }
        let deg: Vec<f32> =
            (0..n).map(|i| (0..n).map(|j| a[i * n + j]).sum()).collect();
        for i in 0..n {
            for j in 0..n {
                if a[i * n + j] != 0.0 {
                    a[i * n + j] /= (deg[i] * deg[j]).sqrt().max(1e-6);
                }
            }
        }
        a
    }

    /// GraphSAGE-style sampled mean-aggregation operator: each row i has
    /// 1/(s+1) on itself and on `s` sampled neighbors (with replacement if
    /// the neighborhood is smaller). Truncates the aggregation sum —
    /// paper footnote 4's stability argument.
    pub fn sampled_adjacency(&self, rng: &mut Pcg32, s: usize) -> Vec<f32> {
        let mut a = Vec::new();
        self.sampled_adjacency_into(rng, s, &mut a);
        a
    }

    /// Like [`Self::sampled_adjacency`], but fills a caller-owned scratch
    /// buffer (cleared and resized to n×n) instead of allocating. SAGE
    /// rebuilds this operator every epoch; reusing one per-run buffer
    /// removes an n×n allocation + free from every epoch boundary. The
    /// fill order — and therefore the PRNG draw sequence — is identical
    /// to the allocating variant.
    pub fn sampled_adjacency_into(
        &self,
        rng: &mut Pcg32,
        s: usize,
        a: &mut Vec<f32>,
    ) {
        let n = self.nodes;
        a.clear();
        a.resize(n * n, 0.0);
        let w = 1.0 / (s as f32 + 1.0);
        for i in 0..n {
            a[i * n + i] += w;
            let nb = &self.neighbors[i];
            if nb.is_empty() {
                a[i * n + i] += s as f32 * w;
                continue;
            }
            for _ in 0..s {
                let j = nb[rng.below(nb.len() as u32) as usize];
                a[i * n + j] += w;
            }
        }
    }
}

/// Dataset adapter for the GCN/SAGE artifacts. Shared inputs are
/// (feats, adj, labels, mask); there are no stacked inputs (full-graph
/// training — the paper trains OGBN-Arxiv on the full graph each epoch).
pub struct GraphDataset {
    pub graph: SbmGraph,
    adj_full: Vec<f32>,
    /// if Some(s): SAGE mode, re-sample an s-neighbor operator per epoch
    pub sample_neighbors: Option<usize>,
    pub steps_per_epoch: usize,
    rng: Pcg32,
    cached_epoch: Option<usize>,
    cached_adj: Vec<f32>,
}

impl GraphDataset {
    pub fn new(seed: u64, nodes: usize, sample_neighbors: Option<usize>) -> Self {
        let graph = SbmGraph::new(seed, nodes, 8, 32, 0.04, 0.004, 0.6);
        let adj_full = graph.full_adjacency();
        GraphDataset {
            graph,
            adj_full,
            sample_neighbors,
            steps_per_epoch: 4,
            rng: Pcg32::new(seed, 21),
            cached_epoch: None,
            cached_adj: Vec::new(),
        }
    }

    fn adj_for_step(&mut self, step: usize) -> Vec<f32> {
        match self.sample_neighbors {
            None => self.adj_full.clone(),
            Some(s) => {
                let epoch = step / self.steps_per_epoch;
                if self.cached_epoch != Some(epoch) {
                    // `cached_adj` doubles as the per-run scratch buffer:
                    // the epoch resample writes into it in place, so the
                    // n×n operator is allocated once per run, not once
                    // per epoch (ROADMAP arena-scratch item)
                    self.graph.sampled_adjacency_into(
                        &mut self.rng,
                        s,
                        &mut self.cached_adj,
                    );
                    self.cached_epoch = Some(epoch);
                }
                self.cached_adj.clone()
            }
        }
    }
}

impl Dataset for GraphDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        Ok(vec![]) // no stacked inputs: full-graph training
    }

    fn shared_inputs(&mut self, step: usize) -> Result<Vec<HostTensor>> {
        let n = self.graph.nodes;
        let d = self.graph.feat_dim;
        Ok(vec![
            HostTensor::F32(vec![n, d], self.graph.feats.clone()),
            HostTensor::F32(vec![n, n], self.adj_for_step(step)),
            HostTensor::I32(vec![n], self.graph.labels.clone()),
            HostTensor::F32(vec![n], self.graph.train_mask.clone()),
        ])
    }

    fn eval_batch(&mut self, _i: usize) -> Result<Vec<HostTensor>> {
        let n = self.graph.nodes;
        let d = self.graph.feat_dim;
        Ok(vec![
            HostTensor::F32(vec![n, d], self.graph.feats.clone()),
            HostTensor::F32(vec![n, n], self.adj_full.clone()),
            HostTensor::I32(vec![n], self.graph.labels.clone()),
            HostTensor::F32(vec![n], self.graph.val_mask.clone()),
        ])
    }

    fn eval_batches(&self) -> usize {
        1
    }

    fn shared_static(&self) -> bool {
        // GCN full-graph training: feats/adjacency/labels/masks never
        // change — literals can be built once per run. SAGE re-samples
        // its aggregation operator every epoch, so it must NOT be cached.
        self.sample_neighbors.is_none()
    }

    fn agg_density(&self) -> f64 {
        // nnz of the full normalized adjacency (incl. self loops) / n^2;
        // the sampled (SAGE) operator is at most as dense.
        let n = self.graph.nodes;
        let nnz: usize =
            n + self.graph.neighbors.iter().map(|v| v.len()).sum::<usize>();
        nnz as f64 / (n * n) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sbm_structure() {
        let g = SbmGraph::new(5, 128, 4, 16, 0.1, 0.005, 0.6);
        // intra-community edges dominate
        let mut intra = 0usize;
        let mut inter = 0usize;
        for i in 0..g.nodes {
            for &j in &g.neighbors[i] {
                if g.labels[i] == g.labels[j] {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        assert!(intra > inter * 2, "intra {intra} inter {inter}");
    }

    #[test]
    fn full_adjacency_rows_normalized() {
        let g = SbmGraph::new(6, 64, 4, 8, 0.1, 0.01, 0.5);
        let a = g.full_adjacency();
        // symmetric
        for i in 0..64 {
            for j in 0..64 {
                assert!((a[i * 64 + j] - a[j * 64 + i]).abs() < 1e-6);
            }
        }
        // spectral norm <= 1 for sym-normalized adjacency: check via power
        // iteration that ||Âx|| <= ||x||
        let mut x = vec![1f32; 64];
        for _ in 0..5 {
            let y: Vec<f32> = (0..64)
                .map(|i| (0..64).map(|j| a[i * 64 + j] * x[j]).sum())
                .collect();
            let nx: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!(ny <= nx * 1.001, "norm grew: {nx} -> {ny}");
            x = y;
        }
    }

    #[test]
    fn sampled_adjacency_rows_sum_to_one() {
        let g = SbmGraph::new(7, 64, 4, 8, 0.1, 0.01, 0.5);
        let mut rng = Pcg32::seeded(1);
        let a = g.sampled_adjacency(&mut rng, 4);
        for i in 0..64 {
            let s: f32 = (0..64).map(|j| a[i * 64 + j]).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn masks_partition_nodes() {
        let g = SbmGraph::new(8, 100, 4, 8, 0.1, 0.01, 0.6);
        for i in 0..100 {
            assert_eq!(g.train_mask[i] + g.val_mask[i], 1.0);
        }
        let n_train: f32 = g.train_mask.iter().sum();
        assert_eq!(n_train, 60.0);
    }

    #[test]
    fn sampled_adjacency_into_matches_allocating_variant() {
        let g = SbmGraph::new(7, 64, 4, 8, 0.1, 0.01, 0.5);
        let mut rng_a = Pcg32::seeded(3);
        let mut rng_b = Pcg32::seeded(3);
        let fresh = g.sampled_adjacency(&mut rng_a, 4);
        // scratch starts dirty and wrongly sized: must still match
        let mut scratch = vec![9.9f32; 7];
        g.sampled_adjacency_into(&mut rng_b, 4, &mut scratch);
        assert_eq!(fresh, scratch);
        // second fill reuses the buffer and draws the next epoch's
        // operator exactly as the allocating variant would
        let next_alloc = g.sampled_adjacency(&mut rng_a, 4);
        g.sampled_adjacency_into(&mut rng_b, 4, &mut scratch);
        assert_eq!(next_alloc, scratch);
        assert_ne!(fresh, scratch);
    }

    #[test]
    fn sage_resamples_per_epoch() {
        let mut d = GraphDataset::new(9, 64, Some(4));
        let a0 = d.shared_inputs(0).unwrap();
        let a1 = d.shared_inputs(1).unwrap(); // same epoch -> same operator
        let a2 = d.shared_inputs(d.steps_per_epoch).unwrap(); // next epoch
        let get = |v: &Vec<HostTensor>| match &v[1] {
            HostTensor::F32(_, x) => x.clone(),
            _ => panic!(),
        };
        assert_eq!(get(&a0), get(&a1));
        assert_ne!(get(&a0), get(&a2));
    }
}

//! Synthetic Markov corpus — the Penn Treebank stand-in (paper Fig 7
//! left) and the token source for the end-to-end transformer LM example.
//!
//! An order-2 Markov chain over a 64-symbol vocabulary with a sparse,
//! peaked transition table. The corpus has real sequential structure
//! (conditional entropy well below log|V|), so an LSTM/transformer LM
//! must learn the transition statistics to reduce perplexity — and
//! quantization noise in training measurably slows/limits that learning,
//! which is exactly the contrast the CPT experiments need.

use anyhow::Result;

use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

impl MarkovCorpus {
    /// Generate `len` tokens. Each (prev2, prev1) context concentrates
    /// probability on ~4 successor symbols.
    pub fn new(seed: u64, vocab: usize, len: usize) -> Self {
        let mut rng = Pcg32::new(seed, 31);
        // per-context successor candidates (deterministic hash of context)
        let branch = 4usize;
        let mut tokens = Vec::with_capacity(len);
        tokens.push(rng.below(vocab as u32) as i32);
        tokens.push(rng.below(vocab as u32) as i32);
        for _ in 2..len {
            let p2 = tokens[tokens.len() - 2] as u64;
            let p1 = tokens[tokens.len() - 1] as u64;
            // successor set keyed on the previous token (order-1 dominant,
            // so bigram statistics carry most of the signal an LM can
            // learn); the older token only biases *which* of the `branch`
            // successors is chosen, adding weaker order-2 structure.
            let h = p1
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(seed);
            // 85%: pick one of `branch` successors of p1; 15%: uniform
            let t = if rng.next_f32() < 0.85 {
                let k = (rng.below(branch as u32) as u64 + p2) % branch as u64;
                ((h >> (8 * k)) % vocab as u64) as i32
            } else {
                rng.below(vocab as u32) as i32
            };
            tokens.push(t);
        }
        MarkovCorpus { vocab, tokens }
    }
}

/// Sliding-window LM batches: x = tokens[i..i+T], y = tokens[i+1..i+T+1].
pub struct LmDataset {
    corpus: MarkovCorpus,
    pub seq: usize,
    pub batch: usize,
    rng: Pcg32,
    /// windows reserved for eval (fixed positions at the corpus tail)
    eval_offset: usize,
    n_eval: usize,
}

impl LmDataset {
    pub fn new(seed: u64, vocab: usize, seq: usize, batch: usize) -> Self {
        let corpus_len = 40_000;
        let corpus = MarkovCorpus::new(seed, vocab, corpus_len);
        let eval_offset = corpus_len * 8 / 10;
        LmDataset {
            corpus,
            seq,
            batch,
            rng: Pcg32::new(seed, 32),
            eval_offset,
            n_eval: 4,
        }
    }

    fn window(&self, start: usize) -> (Vec<i32>, Vec<i32>) {
        let t = self.seq;
        let xs = self.corpus.tokens[start..start + t].to_vec();
        let ys = self.corpus.tokens[start + 1..start + t + 1].to_vec();
        (xs, ys)
    }

    fn batch_at(&mut self, train: bool, i: usize) -> (HostTensor, HostTensor) {
        let b = self.batch;
        let t = self.seq;
        let mut xs = Vec::with_capacity(b * t);
        let mut ys = Vec::with_capacity(b * t);
        for j in 0..b {
            let start = if train {
                self.rng.below((self.eval_offset - t - 1) as u32) as usize
            } else {
                // fixed eval windows in the held-out tail
                let span = self.corpus.tokens.len() - self.eval_offset - t - 1;
                self.eval_offset + (i * b + j) * 131 % span
            };
            let (x, y) = self.window(start);
            xs.extend(x);
            ys.extend(y);
        }
        (
            HostTensor::I32(vec![b, t], xs),
            HostTensor::I32(vec![b, t], ys),
        )
    }
}

impl Dataset for LmDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        let (x, y) = self.batch_at(true, 0);
        Ok(vec![x, y])
    }

    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>> {
        let (x, y) = self.batch_at(false, i);
        Ok(vec![x, y])
    }

    fn eval_batches(&self) -> usize {
        self.n_eval
    }

    fn shared_static(&self) -> bool {
        true // no shared inputs; eval windows are fixed corpus positions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_vocab() {
        let c = MarkovCorpus::new(1, 64, 10_000);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn corpus_has_low_conditional_entropy() {
        // bigram conditional entropy must be far below log2(64) = 6 bits
        let c = MarkovCorpus::new(2, 64, 40_000);
        let v = 64usize;
        let mut pair = vec![0f64; v * v];
        let mut uni = vec![0f64; v];
        for w in c.tokens.windows(2) {
            pair[w[0] as usize * v + w[1] as usize] += 1.0;
            uni[w[0] as usize] += 1.0;
        }
        let n = (c.tokens.len() - 1) as f64;
        let mut h = 0.0;
        for a in 0..v {
            for b in 0..v {
                let p_ab = pair[a * v + b] / n;
                if p_ab > 0.0 {
                    let p_b_given_a = pair[a * v + b] / uni[a];
                    h -= p_ab * p_b_given_a.log2();
                }
            }
        }
        assert!(h < 5.2, "conditional entropy {h} too close to uniform");
        assert!(h > 1.0, "corpus degenerate: H={h}");
    }

    #[test]
    fn lm_batches_shift_by_one() {
        let mut d = LmDataset::new(3, 64, 16, 4);
        let b = d.train_batch(0).unwrap();
        let (HostTensor::I32(_, xs), HostTensor::I32(_, ys)) = (&b[0], &b[1])
        else {
            panic!()
        };
        // y[t] should equal x[t+1] within each row
        for row in 0..4 {
            for t in 0..15 {
                assert_eq!(ys[row * 16 + t], xs[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn eval_windows_fixed() {
        let mut d = LmDataset::new(3, 64, 16, 4);
        let a = d.eval_batch(1).unwrap();
        let b = d.eval_batch(1).unwrap();
        match (&a[0], &b[0]) {
            (HostTensor::I32(_, x), HostTensor::I32(_, y)) => assert_eq!(x, y),
            _ => panic!(),
        }
    }
}

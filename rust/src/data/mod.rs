//! Synthetic dataset generators (DESIGN.md §4 substitutions).
//!
//! Each generator produces a deterministic, seeded dataset that exercises
//! the same code path as the paper's real dataset: class-conditional
//! images for CIFAR/ImageNet, object grids for PascalVOC, stochastic-
//! block-model graphs for OGBN, and a Markov-chain corpus for Penn
//! Treebank / XNLI. The `Dataset` trait yields the model's data inputs as
//! `HostTensor`s in manifest order, so the trainer is generic.

pub mod blobs;
pub mod detection;
pub mod entailment;
pub mod graphs;
pub mod images;
pub mod text;

use anyhow::Result;

use crate::runtime::HostTensor;

/// A source of training/eval batches for one model.
///
/// `train_batch(step)` returns the *stacked* inputs for one optimizer step
/// (manifest order, stacked inputs only). `shared_inputs()` returns the
/// per-chunk shared tensors (e.g. the graph), if any — they may change per
/// epoch (e.g. SAGE neighbor re-sampling). `eval_batch(i)` returns the
/// full data-input list (stacked + shared, manifest order) for evaluation.
pub trait Dataset {
    /// Stacked per-step inputs for optimizer step `step`.
    fn train_batch(&mut self, step: usize) -> Result<Vec<HostTensor>>;

    /// Shared (non-stacked) inputs for the chunk starting at `step`.
    fn shared_inputs(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        Ok(vec![])
    }

    /// Full input list for evaluation batch `i`.
    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>>;

    /// Static-data hint: true when `shared_inputs` and `eval_batch(i)`
    /// return identical contents every time they are called within one
    /// run. The trainer then converts them to device literals exactly
    /// once per run (the GNN adjacency and eval sets dominate host->device
    /// traffic otherwise). Datasets that re-sample shared inputs (e.g.
    /// SAGE neighbor sampling) must return false. Defaults to false —
    /// caching is opt-in, never assumed.
    fn shared_static(&self) -> bool {
        false
    }

    /// Number of distinct eval batches.
    fn eval_batches(&self) -> usize;

    /// Density (nnz / n^2) of the aggregation operator, for BitOps
    /// accounting of GNN models (1.0 for everything else).
    fn agg_density(&self) -> f64 {
        1.0
    }
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!(m1, 5.0);
        assert_eq!(s1, 0.0);
    }
}

//! Synthetic detection grids — the PascalVOC / RetinaNet stand-in
//! (paper Fig 4; DESIGN.md §4).
//!
//! Images contain 1-3 square "objects" of 4 classes, each class a
//! distinctive color/texture patch on a noisy background. Labels are a
//! 4x4 occupancy grid: per-cell objectness (focal-loss target) and class
//! id. This keeps the detection-specific loss structure (dense per-cell
//! prediction, extreme fg/bg imbalance → focal loss) under quantized
//! training, which is what Fig 4 contrasts across schedules.

use anyhow::Result;

use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

pub struct DetectionDataset {
    pub img: usize,
    pub grid: usize,
    pub classes: usize,
    pub batch: usize,
    rng: Pcg32,
    eval_seed: u64,
    n_eval: usize,
}

impl DetectionDataset {
    pub fn new(seed: u64, img: usize, grid: usize, classes: usize, batch: usize) -> Self {
        DetectionDataset {
            img,
            grid,
            classes,
            batch,
            rng: Pcg32::new(seed, 41),
            eval_seed: seed ^ 0xDE7EC7,
            n_eval: 6,
        }
    }

    /// Class-specific RGB signature + texture frequency.
    fn class_color(c: usize) -> [f32; 3] {
        match c % 4 {
            0 => [1.2, -0.8, -0.8],
            1 => [-0.8, 1.2, -0.8],
            2 => [-0.8, -0.8, 1.2],
            _ => [1.0, 1.0, -1.0],
        }
    }

    fn make_batch(&self, rng: &mut Pcg32) -> (HostTensor, HostTensor, HostTensor) {
        let (b, n, g) = (self.batch, self.img, self.grid);
        let cell = n / g;
        let mut xs = vec![0f32; b * n * n * 3];
        let mut obj = vec![0f32; b * g * g];
        let mut cls = vec![0i32; b * g * g];

        for i in 0..b {
            // noisy background
            for p in 0..n * n * 3 {
                xs[i * n * n * 3 + p] = 0.5 * rng.normal();
            }
            let n_obj = 1 + rng.below(3) as usize;
            for _ in 0..n_obj {
                let gy = rng.below(g as u32) as usize;
                let gx = rng.below(g as u32) as usize;
                let c = rng.below(self.classes as u32) as usize;
                obj[i * g * g + gy * g + gx] = 1.0;
                cls[i * g * g + gy * g + gx] = c as i32;
                let col = Self::class_color(c);
                // fill the cell with the class signature + texture
                for dy in 0..cell {
                    for dx in 0..cell {
                        let y = gy * cell + dy;
                        let x = gx * cell + dx;
                        let tex =
                            0.4 * ((dx + dy * (c + 2)) as f32 * 1.3).sin();
                        for ch in 0..3 {
                            let idx = i * n * n * 3 + (y * n + x) * 3 + ch;
                            xs[idx] = col[ch] * 0.6 + tex + 0.45 * rng.normal();
                        }
                    }
                }
            }
        }
        (
            HostTensor::F32(vec![b, n, n, 3], xs),
            HostTensor::F32(vec![b, g * g], obj),
            HostTensor::I32(vec![b, g * g], cls),
        )
    }
}

impl Dataset for DetectionDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        let mut rng = self.rng.fork(0xD7);
        let (x, o, c) = self.make_batch(&mut rng);
        Ok(vec![x, o, c])
    }

    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>> {
        let mut rng = Pcg32::new(self.eval_seed, i as u64 + 7);
        let (x, o, c) = self.make_batch(&mut rng);
        Ok(vec![x, o, c])
    }

    fn eval_batches(&self) -> usize {
        self.n_eval
    }

    fn shared_static(&self) -> bool {
        true // no shared inputs; eval batches are seeded per index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_consistency() {
        let mut d = DetectionDataset::new(3, 16, 4, 4, 8);
        let b = d.train_batch(0).unwrap();
        assert_eq!(b[0].shape(), &[8, 16, 16, 3]);
        assert_eq!(b[1].shape(), &[8, 16]);
        assert_eq!(b[2].shape(), &[8, 16]);
        let (HostTensor::F32(_, obj), HostTensor::I32(_, cls)) = (&b[1], &b[2])
        else {
            panic!()
        };
        // every image has 1..=3 objects; class ids valid
        for i in 0..8 {
            let count: f32 = obj[i * 16..(i + 1) * 16].iter().sum();
            assert!((1.0..=3.0).contains(&count), "img {i}: {count} objects");
        }
        assert!(cls.iter().all(|&c| (0..4).contains(&c)));
    }

    #[test]
    fn object_cells_are_visibly_distinct() {
        let mut d = DetectionDataset::new(5, 16, 4, 4, 16);
        let b = d.train_batch(0).unwrap();
        let (HostTensor::F32(_, xs), HostTensor::F32(_, obj)) = (&b[0], &b[1])
        else {
            panic!()
        };
        // mean |pixel| over object cells must exceed background cells
        let (mut so, mut no, mut sb, mut nb) = (0f64, 0usize, 0f64, 0usize);
        let n = 16;
        for i in 0..16 {
            for gy in 0..4 {
                for gx in 0..4 {
                    let is_obj = obj[i * 16 + gy * 4 + gx] > 0.5;
                    for dy in 0..4 {
                        for dx in 0..4 {
                            let y = gy * 4 + dy;
                            let x = gx * 4 + dx;
                            for ch in 0..3 {
                                let v = xs
                                    [i * n * n * 3 + (y * n + x) * 3 + ch]
                                    .abs() as f64;
                                if is_obj {
                                    so += v;
                                    no += 1;
                                } else {
                                    sb += v;
                                    nb += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        assert!(so / no as f64 > 1.5 * (sb / nb as f64));
    }
}

//! Class-conditional synthetic image generator — the CIFAR-10/100 and
//! ImageNet stand-in (paper Fig 3, Table 1).
//!
//! Each class owns a random smooth prototype (mixture of low-frequency
//! sinusoids in 3 channels) plus a class-specific texture frequency;
//! samples are prototype + texture + pixel noise, then per-image crop
//! jitter and horizontal flips (the paper's augmentations). The task is
//! learnable to high accuracy by a small CNN but not trivially (noise and
//! shared frequency bands force feature learning), and — crucially for
//! CPT experiments — class margins are tight enough that quantization
//! noise measurably moves accuracy.

use anyhow::Result;

use super::Dataset;
use crate::runtime::HostTensor;
use crate::util::prng::Pcg32;

#[derive(Clone, Debug)]
struct ClassProto {
    /// per-channel sinusoid params: (fx, fy, phase, amp) x 3 waves
    waves: Vec<[f32; 4]>,
}

pub struct ImageDataset {
    pub img: usize,
    pub classes: usize,
    pub batch: usize,
    protos: Vec<ClassProto>,
    rng: Pcg32,
    eval_rng_seed: u64,
    noise: f32,
    n_eval: usize,
}

impl ImageDataset {
    pub fn new(seed: u64, img: usize, classes: usize, batch: usize) -> Self {
        let mut proto_rng = Pcg32::new(seed, 1);
        let protos = (0..classes)
            .map(|_| {
                let waves = (0..9)
                    .map(|_| {
                        [
                            proto_rng.uniform(0.5, 3.0),
                            proto_rng.uniform(0.5, 3.0),
                            proto_rng.uniform(0.0, std::f32::consts::TAU),
                            proto_rng.uniform(0.3, 0.9),
                        ]
                    })
                    .collect();
                ClassProto { waves }
            })
            .collect();
        ImageDataset {
            img,
            classes,
            batch,
            protos,
            rng: Pcg32::new(seed, 2),
            eval_rng_seed: seed ^ 0xEE11AA77,
            noise: 1.1,
            n_eval: 8,
        }
    }

    fn render(&self, rng: &mut Pcg32, class: usize, out: &mut Vec<f32>) {
        let p = &self.protos[class];
        let n = self.img;
        let dx = rng.uniform(-1.5, 1.5);
        let dy = rng.uniform(-1.5, 1.5);
        let flip = rng.below(2) == 1;
        for y in 0..n {
            for x in 0..n {
                let xe = if flip { n - 1 - x } else { x };
                let xf = (xe as f32 + dx) / n as f32;
                let yf = (y as f32 + dy) / n as f32;
                for c in 0..3 {
                    let mut v = 0.0f32;
                    for w in 0..3 {
                        let [fx, fy, ph, amp] = p.waves[c * 3 + w];
                        v += amp
                            * (std::f32::consts::TAU * (fx * xf + fy * yf) + ph)
                                .sin();
                    }
                    v += self.noise * rng.normal();
                    out.push(v);
                }
            }
        }
    }

    fn make_batch(&self, rng: &mut Pcg32) -> (HostTensor, HostTensor) {
        let b = self.batch;
        let n = self.img;
        let mut xs = Vec::with_capacity(b * n * n * 3);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let class = rng.below(self.classes as u32) as usize;
            ys.push(class as i32);
            self.render(rng, class, &mut xs);
        }
        (
            HostTensor::F32(vec![b, n, n, 3], xs),
            HostTensor::I32(vec![b], ys),
        )
    }
}

impl Dataset for ImageDataset {
    fn train_batch(&mut self, _step: usize) -> Result<Vec<HostTensor>> {
        let mut rng = self.rng.fork(0xBA7C4);
        let (x, y) = self.make_batch(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batch(&mut self, i: usize) -> Result<Vec<HostTensor>> {
        // fixed eval set: derived from a seed disjoint from training
        let mut rng = Pcg32::new(self.eval_rng_seed, i as u64 + 100);
        let (x, y) = self.make_batch(&mut rng);
        Ok(vec![x, y])
    }

    fn eval_batches(&self) -> usize {
        self.n_eval
    }

    fn shared_static(&self) -> bool {
        true // no shared inputs; eval batches are seeded per index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let mut a = ImageDataset::new(7, 16, 10, 4);
        let mut b = ImageDataset::new(7, 16, 10, 4);
        let ba = a.train_batch(0).unwrap();
        let bb = b.train_batch(0).unwrap();
        assert_eq!(ba[0].shape(), &[4, 16, 16, 3]);
        assert_eq!(ba[1].shape(), &[4]);
        match (&ba[0], &bb[0]) {
            (HostTensor::F32(_, x), HostTensor::F32(_, y)) => assert_eq!(x, y),
            _ => panic!("dtype"),
        }
    }

    #[test]
    fn eval_fixed_and_disjoint_from_train() {
        let mut d = ImageDataset::new(7, 16, 10, 4);
        let e1 = d.eval_batch(0).unwrap();
        let e2 = d.eval_batch(0).unwrap();
        match (&e1[0], &e2[0]) {
            (HostTensor::F32(_, x), HostTensor::F32(_, y)) => assert_eq!(x, y),
            _ => panic!("dtype"),
        }
        let t = d.train_batch(0).unwrap();
        match (&e1[0], &t[0]) {
            (HostTensor::F32(_, x), HostTensor::F32(_, y)) => assert_ne!(x, y),
            _ => panic!("dtype"),
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let mut d = ImageDataset::new(3, 16, 10, 64);
        let mut counts = [0usize; 10];
        for s in 0..50 {
            let b = d.train_batch(s).unwrap();
            if let HostTensor::I32(_, ys) = &b[1] {
                for &y in ys {
                    counts[y as usize] += 1;
                }
            }
        }
        let total: usize = counts.iter().sum();
        for &c in &counts {
            let frac = c as f64 / total as f64;
            assert!((0.05..0.2).contains(&frac), "{counts:?}");
        }
    }

    #[test]
    fn classes_are_separable_by_pixels() {
        // nearest-prototype classification on clean means should beat
        // chance by a wide margin — sanity that the task is learnable
        let mut d = ImageDataset::new(11, 16, 4, 32);
        // build per-class mean images from many samples
        let mut means = vec![vec![0f32; 16 * 16 * 3]; 4];
        let mut counts = vec![0usize; 4];
        let mut batches = Vec::new();
        for s in 0..20 {
            batches.push(d.train_batch(s).unwrap());
        }
        for b in &batches[..10] {
            let (HostTensor::F32(_, xs), HostTensor::I32(_, ys)) = (&b[0], &b[1])
            else {
                panic!()
            };
            let stride = 16 * 16 * 3;
            for (i, &y) in ys.iter().enumerate() {
                counts[y as usize] += 1;
                for j in 0..stride {
                    means[y as usize][j] += xs[i * stride + j];
                }
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        // classify held-out samples by nearest mean
        let mut hit = 0;
        let mut tot = 0;
        for b in &batches[10..] {
            let (HostTensor::F32(_, xs), HostTensor::I32(_, ys)) = (&b[0], &b[1])
            else {
                panic!()
            };
            let stride = 16 * 16 * 3;
            for (i, &y) in ys.iter().enumerate() {
                let mut best = (f32::MAX, 0usize);
                for (k, m) in means.iter().enumerate() {
                    let d2: f32 = (0..stride)
                        .map(|j| {
                            let d = xs[i * stride + j] - m[j];
                            d * d
                        })
                        .sum();
                    if d2 < best.0 {
                        best = (d2, k);
                    }
                }
                hit += (best.1 == y as usize) as usize;
                tot += 1;
            }
        }
        let acc = hit as f64 / tot as f64;
        assert!(acc > 0.35, "nearest-mean accuracy only {acc}");
    }
}

//! Quantization accounting and tooling (paper §4.1).

pub mod bitops;
pub mod range_test;

pub use bitops::{BitOpsAccountant, BitOpsTotal};
pub use range_test::{range_test, RangeTestOutcome, RangeTestProbe};

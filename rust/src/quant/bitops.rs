//! Effective BitOps accounting — the paper's training-cost metric (§4.1):
//!
//!   BitOps = FLOP_{a×b} · (Bit_a / 32) · (Bit_b / 32)
//!
//! for each dot product, summed over the run. Per the paper's protocol:
//!
//! * forward GEMMs run with both operands at the schedule's q_t;
//! * backward GEMMs (2 per forward GEMM: dA and dW) contract the q_bwd-
//!   quantized cotangent against a q_t-quantized residual, so each costs
//!   FLOPs · (q_bwd/32)(q_t/32) — and q_bwd is pinned to q_max (§3.1);
//! * full-precision GEMMs (FP-Agg aggregation, attention scores) cost
//!   FLOPs · 1 in both directions.
//!
//! GEMM FLOP counts per model come from the artifact manifest (counted at
//! trace time by python/compile/models/common.py).

use crate::runtime::ModelSpec;

/// Accumulates effective BitOps over a training run.
#[derive(Clone, Debug)]
pub struct BitOpsAccountant {
    q_flops_fwd: f64,
    fp_flops_fwd: f64,
    q_bwd: f64,
    total: f64,
    /// Quantized-GEMM share of `total` — the part a precision trace
    /// controls; the realized-cost ratio is taken against this alone,
    /// matching `schedule::cost::relative_cost` (FP GEMMs cost the same
    /// under every schedule and would only dilute the ratio).
    q_total: f64,
    /// Σ q_t over recorded steps (for the realized mean q/q_max).
    q_sum: f64,
    steps: usize,
}

/// Fold a model's aggregation GEMMs into effective FLOP counts at the
/// given graph `density` (nnz / n² of the aggregation operator). On real
/// graphs aggregation is a sparse matvec whose cost scales with the edge
/// count — the paper calls it "a negligible portion of the GNN's forward
/// pass" — while our simulator runs it as a dense GEMM; scaling by
/// density restores the paper's accounting.
pub fn effective_flops(spec: &ModelSpec, density: f64) -> (f64, f64) {
    let q = spec.q_gemm_flops_fwd as f64
        + density * spec.agg_q_gemm_flops_fwd as f64;
    let fp = spec.fp_gemm_flops_fwd as f64
        + density * spec.agg_fp_gemm_flops_fwd as f64;
    (q, fp)
}

/// Final tally, in GBitOps (the unit the paper's figures use).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BitOpsTotal {
    pub gbitops: f64,
}

impl BitOpsAccountant {
    /// `q_bwd` is the fixed backward precision (= q_max per the paper).
    /// `agg_density` rescales GNN aggregation GEMMs (1.0 for non-GNNs).
    pub fn new(spec: &ModelSpec, q_bwd: f64, agg_density: f64) -> Self {
        let (q_flops_fwd, fp_flops_fwd) = effective_flops(spec, agg_density);
        Self::from_flops(q_flops_fwd, fp_flops_fwd, q_bwd)
    }

    /// Construct from raw FLOP counts (tests / analytic comparisons).
    pub fn from_flops(q_flops_fwd: f64, fp_flops_fwd: f64, q_bwd: f64) -> Self {
        BitOpsAccountant {
            q_flops_fwd,
            fp_flops_fwd,
            q_bwd,
            total: 0.0,
            q_total: 0.0,
            q_sum: 0.0,
            steps: 0,
        }
    }

    /// Account one training step at forward precision `q_t`.
    pub fn record_step(&mut self, q_t: f64) {
        let rq = q_t / 32.0;
        let rb = self.q_bwd / 32.0;
        // forward + two backward GEMMs per quantized GEMM
        let q_cost = self.q_flops_fwd * (rq * rq + 2.0 * rb * rq);
        // FP GEMMs: fwd + 2 bwd at full precision
        let fp_cost = self.fp_flops_fwd * 3.0;
        self.total += q_cost + fp_cost;
        self.q_total += q_cost;
        self.q_sum += q_t;
        self.steps += 1;
    }

    /// Account a whole chunk of steps.
    pub fn record_steps(&mut self, qs: &[f32]) {
        for &q in qs {
            self.record_step(q as f64);
        }
    }

    pub fn total(&self) -> BitOpsTotal {
        BitOpsTotal { gbitops: self.total / 1e9 }
    }

    /// Exact realized relative cost of the recorded trace vs a static run
    /// at `q_bwd` (= q_max) — quantized GEMMs only, so the figure equals
    /// [`crate::schedule::cost::relative_cost_of_trace`] on the same
    /// trace (the FLOP factor cancels). 1.0 when nothing quantized was
    /// recorded (FP-only model or an empty run).
    pub fn realized_relative_cost(&self) -> f64 {
        let rb = self.q_bwd / 32.0;
        let static_step = self.q_flops_fwd * 3.0 * rb * rb;
        let denom = self.steps as f64 * static_step;
        if denom <= 0.0 {
            return 1.0;
        }
        self.q_total / denom
    }

    /// Realized mean `q_t / q_bwd` over the recorded trace (1.0 for an
    /// empty run).
    pub fn realized_mean_q(&self) -> f64 {
        if self.steps == 0 || self.q_bwd <= 0.0 {
            return 1.0;
        }
        self.q_sum / (self.steps as f64 * self.q_bwd)
    }

    /// Cost of one step at precision q (without recording).
    pub fn step_cost(&self, q_t: f64) -> f64 {
        let rq = q_t / 32.0;
        let rb = self.q_bwd / 32.0;
        self.q_flops_fwd * (rq * rq + 2.0 * rb * rq) + self.fp_flops_fwd * 3.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{suite, Schedule};

    #[test]
    fn formula_matches_paper() {
        // one GEMM of 1000 FLOPs at 8/8 bits: 1000 * (8/32)^2 = 62.5
        let mut acc = BitOpsAccountant::from_flops(1000.0, 0.0, 8.0);
        acc.record_step(8.0);
        let fwd = 1000.0 * (8.0 / 32.0) * (8.0 / 32.0);
        let bwd = 2.0 * 1000.0 * (8.0 / 32.0) * (8.0 / 32.0);
        assert!((acc.total().gbitops * 1e9 - (fwd + bwd)).abs() < 1e-6);
    }

    #[test]
    fn lower_precision_costs_less() {
        let acc = BitOpsAccountant::from_flops(1e6, 0.0, 8.0);
        assert!(acc.step_cost(3.0) < acc.step_cost(4.0));
        assert!(acc.step_cost(4.0) < acc.step_cost(8.0));
    }

    #[test]
    fn fp_gemms_are_precision_independent() {
        let acc = BitOpsAccountant::from_flops(0.0, 1e6, 8.0);
        assert_eq!(acc.step_cost(3.0), acc.step_cost(8.0));
    }

    #[test]
    fn realized_accounting_matches_trace_cost() {
        // the accountant's realized figures must agree exactly with the
        // model-independent trace formulas in schedule::cost — and they
        // must ignore the FP-GEMM share, which no schedule controls
        let total_iters = 1500;
        let sched = suite::by_name("RTH", 3.0, 8.0, total_iters, 8).unwrap();
        let qs: Vec<u32> = (0..total_iters).map(|t| sched.q_at(t)).collect();
        let mut acc = BitOpsAccountant::from_flops(2e6, 5e5, 8.0);
        acc.record_steps(&sched.q_vec(0, total_iters));
        let want_cost =
            crate::schedule::cost::relative_cost_of_trace(&qs, 8.0);
        let want_mq =
            crate::schedule::cost::mean_relative_q_of_trace(&qs, 8.0);
        assert!(
            (acc.realized_relative_cost() - want_cost).abs() < 1e-9,
            "{} vs {want_cost}",
            acc.realized_relative_cost()
        );
        assert!(
            (acc.realized_mean_q() - want_mq).abs() < 1e-9,
            "{} vs {want_mq}",
            acc.realized_mean_q()
        );
        // degenerate: nothing recorded, or nothing quantized
        let empty = BitOpsAccountant::from_flops(1e6, 0.0, 8.0);
        assert_eq!(empty.realized_relative_cost(), 1.0);
        assert_eq!(empty.realized_mean_q(), 1.0);
        let mut fp_only = BitOpsAccountant::from_flops(0.0, 1e6, 8.0);
        fp_only.record_step(4.0);
        assert_eq!(fp_only.realized_relative_cost(), 1.0);
    }

    #[test]
    fn schedule_total_matches_relative_cost() {
        // BitOps of a CPT run / BitOps of the static run must equal the
        // schedule::cost::relative_cost prediction (q-GEMMs only).
        let total_iters = 2000;
        let sched = suite::by_name("CR", 3.0, 8.0, total_iters, 8).unwrap();

        let mut a = BitOpsAccountant::from_flops(1e6, 0.0, 8.0);
        a.record_steps(&sched.q_vec(0, total_iters));
        let mut b = BitOpsAccountant::from_flops(1e6, 0.0, 8.0);
        b.record_steps(&Schedule::static_q(8.0).q_vec(0, total_iters));

        let measured = a.total().gbitops / b.total().gbitops;
        let predicted =
            crate::schedule::cost::relative_cost(&sched, 8.0, total_iters);
        assert!(
            (measured - predicted).abs() < 1e-9,
            "measured {measured} vs predicted {predicted}"
        );
    }
}

//! Precision range test (paper §3.1 / CPT [5] §3.3): discover q_min.
//!
//! DNN training cannot progress when precision is too low; CPT therefore
//! derives q_min per model-dataset pair by probing short training runs at
//! increasing static precision and picking the lowest bit-width whose
//! loss decreases meaningfully. The probe closure abstracts "run N steps
//! at static precision q and report (initial_loss, final_loss)" so the
//! test works for every model the runtime can load (and is unit-testable
//! without a backend).

use anyhow::Result;

/// A probe runs a short training burst at static precision `q` and
/// returns (initial loss, final loss).
pub trait RangeTestProbe {
    fn probe(&mut self, q: u32) -> Result<(f32, f32)>;
}

impl<F: FnMut(u32) -> Result<(f32, f32)>> RangeTestProbe for F {
    fn probe(&mut self, q: u32) -> Result<(f32, f32)> {
        self(q)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct RangeTestOutcome {
    /// The discovered minimum workable precision.
    pub q_min: u32,
    /// (q, initial loss, final loss, improved) per probed bit-width.
    pub probes: Vec<(u32, f32, f32, bool)>,
}

/// Sweep q from `q_lo` up to `q_hi`; return the first precision at which
/// the probe's loss improves by at least `min_rel_improvement` (relative),
/// following the CPT precision-range-test protocol.
pub fn range_test<P: RangeTestProbe>(
    mut probe: P,
    q_lo: u32,
    q_hi: u32,
    min_rel_improvement: f32,
) -> Result<RangeTestOutcome> {
    let mut probes = Vec::new();
    let mut q_min = q_hi;
    for q in q_lo..=q_hi {
        let (init, fin) = probe.probe(q)?;
        let improved =
            init.is_finite() && fin.is_finite() && fin < init * (1.0 - min_rel_improvement);
        probes.push((q, init, fin, improved));
        if improved {
            q_min = q;
            // the paper only needs q_min; stop probing to save compute.
            break;
        }
    }
    Ok(RangeTestOutcome { q_min, probes })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_threshold() {
        // synthetic probe: training "works" (loss halves) from 4 bits up
        let probe = |q: u32| -> Result<(f32, f32)> {
            Ok(if q >= 4 { (2.0, 1.0) } else { (2.0, 2.1) })
        };
        let out = range_test(probe, 2, 8, 0.05).unwrap();
        assert_eq!(out.q_min, 4);
        assert_eq!(out.probes.len(), 3); // probed 2, 3, 4
    }

    #[test]
    fn falls_back_to_q_hi() {
        let probe = |_q: u32| -> Result<(f32, f32)> { Ok((2.0, 2.0)) };
        let out = range_test(probe, 2, 6, 0.05).unwrap();
        assert_eq!(out.q_min, 6);
        assert_eq!(out.probes.len(), 5);
    }

    #[test]
    fn nan_losses_do_not_count_as_improvement() {
        let probe = |q: u32| -> Result<(f32, f32)> {
            Ok(if q < 5 { (2.0, f32::NAN) } else { (2.0, 1.0) })
        };
        let out = range_test(probe, 2, 8, 0.05).unwrap();
        assert_eq!(out.q_min, 5);
    }
}

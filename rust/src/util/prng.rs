//! PCG32 — deterministic, seedable PRNG for data generation and tests.
//!
//! No `rand` crate is available in the offline vendor set, and determinism
//! across runs/trials is a hard requirement for the experiment harness
//! (every figure reports mean ± std over seeded trials), so we implement
//! the PCG-XSH-RR 64/32 generator (O'Neill 2014) directly.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id. Different streams are
    /// statistically independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for per-epoch / per-trial
    /// substreams without coupling consumption order).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = self.next_u64();
        Pcg32::new(s ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order random.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // partial Fisher-Yates over an index vector
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(5);
        let s = r.sample_indices(100, 32);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 32);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}

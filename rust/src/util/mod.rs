//! Hand-rolled substrates: PRNG, JSON, hashing, property testing, and
//! filesystem helpers.
//!
//! The offline vendor set contains only the `xla` crate and its build
//! chain, so everything usually pulled from crates.io (rand, serde,
//! proptest, csv) is implemented here, scoped to exactly what the
//! experiment harness needs.

pub mod hash;
pub mod json;
pub mod prng;
pub mod propcheck;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Strict env-var parsing: `Ok(None)` when unset, the parsed value when
/// set and valid, and a loud error otherwise. Every `CPT_*` knob goes
/// through here so a typo'd value aborts the run instead of silently
/// falling back to a default.
pub fn env_parse<T: std::str::FromStr>(name: &str) -> Result<Option<T>>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(std::env::VarError::NotUnicode(_)) => {
            anyhow::bail!("{name} is set but is not valid UTF-8")
        }
        Ok(v) => match v.trim().parse::<T>() {
            Ok(x) => Ok(Some(x)),
            Err(e) => anyhow::bail!("{name}='{v}' is invalid: {e}"),
        },
    }
}

/// Stage a unique `.tmp` sibling of `path` holding `bytes`, fsynced.
/// The name embeds the pid and a process-wide counter so two writers —
/// threads or *processes* sharing a directory — can never truncate each
/// other's in-flight staging file (a fixed `.tmp` name would: the second
/// `File::create` empties the inode the first is still writing).
fn stage_tmp(path: &Path, bytes: &[u8]) -> Result<std::path::PathBuf> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};

    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create dir {}", dir.display()))?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("write_atomic: no file name in {}", path.display()))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(
        ".{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = path.with_file_name(tmp_name);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    // data must hit disk before link/rename publishes the new name — else
    // a power loss could leave the final path pointing at unwritten blocks
    f.sync_all()
        .with_context(|| format!("fsync {}", tmp.display()))?;
    Ok(tmp)
}

/// Best-effort directory fsync so a just-published name is durable;
/// non-fatal if the platform disallows opening directories (the file
/// contents are already safe).
fn sync_parent(path: &Path) {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
}

/// Write `bytes` to `path` atomically and durably: write a uniquely
/// named `.tmp` sibling, fsync it, then rename it over the target (and
/// best-effort fsync the parent directory so the rename itself is
/// durable). On POSIX the rename is atomic, so neither a process crash
/// nor a power loss can leave a truncated `path` — readers either see
/// the old complete file or the new one. A stale `.tmp` may survive a
/// crash; `cpt gc` sweeps those orphans. Parent directories are created
/// as needed.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let tmp = stage_tmp(path, bytes)?;
    std::fs::rename(&tmp, path).with_context(|| {
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    sync_parent(path);
    Ok(())
}

/// Publish `bytes` at `path` if and only if nothing exists there yet.
/// The staged tmp is hard-linked into place: `link(2)` fails with
/// `EEXIST` when the name is taken, so among any number of concurrent
/// callers — across processes — exactly one ever succeeds, and the file
/// is complete and fsynced from the first instant it is visible. Returns
/// `true` if this caller published, `false` if the path already existed.
/// This is the commit primitive of the lease protocol (see
/// `coordinator::lease` and rust/DESIGN-sharding.md).
pub fn publish_exclusive(path: impl AsRef<Path>, bytes: &[u8]) -> Result<bool> {
    let path = path.as_ref();
    let tmp = stage_tmp(path, bytes)?;
    let res = std::fs::hard_link(&tmp, path);
    std::fs::remove_file(&tmp).ok();
    match res {
        Ok(()) => {
            sync_parent(path);
            Ok(true)
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(e).with_context(|| {
            format!("link {} -> {}", tmp.display(), path.display())
        }),
    }
}

// ---- line-delimited framing ---------------------------------------------

/// Why a [`read_frame`] call yielded no frame. `Truncated` and `TooLarge`
/// are protocol violations the peer caused — the serve wire layer maps
/// them to typed error replies instead of wedging or killing the process.
#[derive(Debug)]
pub enum FrameError {
    /// The stream ended mid-frame (bytes arrived, but no terminator).
    Truncated,
    /// The frame exceeded the size cap before its terminator arrived.
    TooLarge { max: usize },
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated => {
                write!(f, "stream ended mid-frame (missing terminator)")
            }
            FrameError::TooLarge { max } => {
                write!(f, "frame exceeds the {max}-byte cap")
            }
            FrameError::Io(e) => write!(f, "frame i/o: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Write one newline-terminated frame. Frames must not contain a raw
/// `\n` (JSON compact encoding never emits one — it escapes newlines
/// inside strings), so embedding one is a caller bug, reported as
/// `InvalidInput` rather than silently splitting the frame in two.
pub fn write_frame<W: std::io::Write>(
    w: &mut W,
    bytes: &[u8],
) -> std::io::Result<()> {
    if bytes.contains(&b'\n') {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            "frame payload contains a raw newline",
        ));
    }
    w.write_all(bytes)?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Read one newline-terminated frame of at most `max` bytes (terminator
/// excluded). `Ok(None)` is a clean end-of-stream on a frame boundary;
/// `Truncated` means the peer hung up mid-frame; `TooLarge` fires before
/// the oversized payload is ever buffered whole, so a hostile peer
/// cannot balloon memory.
pub fn read_frame<R: std::io::BufRead>(
    r: &mut R,
    max: usize,
) -> std::result::Result<Option<Vec<u8>>, FrameError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        };
        if chunk.is_empty() {
            return if buf.is_empty() {
                Ok(None)
            } else {
                Err(FrameError::Truncated)
            };
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if buf.len() + pos > max {
                    r.consume(pos + 1);
                    return Err(FrameError::TooLarge { max });
                }
                buf.extend_from_slice(&chunk[..pos]);
                r.consume(pos + 1);
                return Ok(Some(buf));
            }
            None => {
                let n = chunk.len();
                if buf.len() + n > max {
                    r.consume(n);
                    return Err(FrameError::TooLarge { max });
                }
                buf.extend_from_slice(chunk);
                r.consume(n);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_creates_parents_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("out.json");
        write_atomic(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(siblings, vec!["out.json"], "no .tmp residue: {siblings:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_overwrites_existing() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test2");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("out.bin");
        write_atomic(&path, b"first version, longer").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_exclusive_first_wins_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("cpt_publish_exclusive_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("token.json");
        assert!(publish_exclusive(&path, b"alpha").unwrap());
        assert!(!publish_exclusive(&path, b"beta").unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"alpha");
        let siblings: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(siblings, vec!["token.json"], "tmp residue: {siblings:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn frames_round_trip_back_to_back() {
        let mut wire: Vec<u8> = Vec::new();
        write_frame(&mut wire, b"{\"v\":1}").unwrap();
        write_frame(&mut wire, b"").unwrap();
        write_frame(&mut wire, b"second \\n frame").unwrap();
        let mut r = std::io::BufReader::new(&wire[..]);
        assert_eq!(read_frame(&mut r, 64).unwrap().unwrap(), b"{\"v\":1}");
        assert_eq!(read_frame(&mut r, 64).unwrap().unwrap(), b"");
        assert_eq!(
            read_frame(&mut r, 64).unwrap().unwrap(),
            b"second \\n frame"
        );
        assert!(read_frame(&mut r, 64).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncated_frame_is_distinguished_from_clean_eof() {
        let mut r = std::io::BufReader::new(&b"no terminator"[..]);
        match read_frame(&mut r, 64) {
            Err(FrameError::Truncated) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn oversized_frame_is_refused_without_buffering_it() {
        // terminator present but past the cap
        let mut wire: Vec<u8> = vec![b'x'; 100];
        wire.push(b'\n');
        wire.extend_from_slice(b"after\n");
        let mut r = std::io::BufReader::new(&wire[..]);
        match read_frame(&mut r, 10) {
            Err(FrameError::TooLarge { max: 10 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // no terminator at all, endless-looking payload
        let big = vec![b'y'; 4096];
        let mut r = std::io::BufReader::new(&big[..]);
        match read_frame(&mut r, 16) {
            Err(FrameError::TooLarge { max: 16 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn write_frame_rejects_embedded_newlines() {
        let mut wire: Vec<u8> = Vec::new();
        let err = write_frame(&mut wire, b"two\nframes").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(wire.is_empty(), "nothing may hit the wire: {wire:?}");
    }

    #[test]
    fn publish_exclusive_admits_exactly_one_concurrent_winner() {
        let dir = std::env::temp_dir().join("cpt_publish_exclusive_race");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("cell.json");
        let wins: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let path = path.clone();
                    scope.spawn(move || {
                        publish_exclusive(&path, format!("writer-{i}").as_bytes())
                            .unwrap()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|&won| won)
                .count()
        });
        assert_eq!(wins, 1, "exactly one publisher must win");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("writer-"), "torn content: {body:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

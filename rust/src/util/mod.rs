//! Hand-rolled substrates: PRNG, JSON, hashing, property testing, and
//! filesystem helpers.
//!
//! The offline vendor set contains only the `xla` crate and its build
//! chain, so everything usually pulled from crates.io (rand, serde,
//! proptest, csv) is implemented here, scoped to exactly what the
//! experiment harness needs.

pub mod hash;
pub mod json;
pub mod prng;
pub mod propcheck;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Stage a unique `.tmp` sibling of `path` holding `bytes`, fsynced.
/// The name embeds the pid and a process-wide counter so two writers —
/// threads or *processes* sharing a directory — can never truncate each
/// other's in-flight staging file (a fixed `.tmp` name would: the second
/// `File::create` empties the inode the first is still writing).
fn stage_tmp(path: &Path, bytes: &[u8]) -> Result<std::path::PathBuf> {
    use std::io::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};

    static SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create dir {}", dir.display()))?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("write_atomic: no file name in {}", path.display()))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(
        ".{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = path.with_file_name(tmp_name);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    // data must hit disk before link/rename publishes the new name — else
    // a power loss could leave the final path pointing at unwritten blocks
    f.sync_all()
        .with_context(|| format!("fsync {}", tmp.display()))?;
    Ok(tmp)
}

/// Best-effort directory fsync so a just-published name is durable;
/// non-fatal if the platform disallows opening directories (the file
/// contents are already safe).
fn sync_parent(path: &Path) {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
}

/// Write `bytes` to `path` atomically and durably: write a uniquely
/// named `.tmp` sibling, fsync it, then rename it over the target (and
/// best-effort fsync the parent directory so the rename itself is
/// durable). On POSIX the rename is atomic, so neither a process crash
/// nor a power loss can leave a truncated `path` — readers either see
/// the old complete file or the new one. A stale `.tmp` may survive a
/// crash; `cpt gc` sweeps those orphans. Parent directories are created
/// as needed.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let tmp = stage_tmp(path, bytes)?;
    std::fs::rename(&tmp, path).with_context(|| {
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    sync_parent(path);
    Ok(())
}

/// Publish `bytes` at `path` if and only if nothing exists there yet.
/// The staged tmp is hard-linked into place: `link(2)` fails with
/// `EEXIST` when the name is taken, so among any number of concurrent
/// callers — across processes — exactly one ever succeeds, and the file
/// is complete and fsynced from the first instant it is visible. Returns
/// `true` if this caller published, `false` if the path already existed.
/// This is the commit primitive of the lease protocol (see
/// `coordinator::lease` and rust/DESIGN-sharding.md).
pub fn publish_exclusive(path: impl AsRef<Path>, bytes: &[u8]) -> Result<bool> {
    let path = path.as_ref();
    let tmp = stage_tmp(path, bytes)?;
    let res = std::fs::hard_link(&tmp, path);
    std::fs::remove_file(&tmp).ok();
    match res {
        Ok(()) => {
            sync_parent(path);
            Ok(true)
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(e).with_context(|| {
            format!("link {} -> {}", tmp.display(), path.display())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_creates_parents_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("out.json");
        write_atomic(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(siblings, vec!["out.json"], "no .tmp residue: {siblings:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_overwrites_existing() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test2");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("out.bin");
        write_atomic(&path, b"first version, longer").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_exclusive_first_wins_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("cpt_publish_exclusive_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("token.json");
        assert!(publish_exclusive(&path, b"alpha").unwrap());
        assert!(!publish_exclusive(&path, b"beta").unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"alpha");
        let siblings: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(siblings, vec!["token.json"], "tmp residue: {siblings:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_exclusive_admits_exactly_one_concurrent_winner() {
        let dir = std::env::temp_dir().join("cpt_publish_exclusive_race");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("cell.json");
        let wins: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let path = path.clone();
                    scope.spawn(move || {
                        publish_exclusive(&path, format!("writer-{i}").as_bytes())
                            .unwrap()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|&won| won)
                .count()
        });
        assert_eq!(wins, 1, "exactly one publisher must win");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("writer-"), "torn content: {body:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Hand-rolled substrates: PRNG, JSON, property testing.
//!
//! The offline vendor set contains only the `xla` crate and its build
//! chain, so everything usually pulled from crates.io (rand, serde,
//! proptest, csv) is implemented here, scoped to exactly what the
//! experiment harness needs.

pub mod json;
pub mod prng;
pub mod propcheck;

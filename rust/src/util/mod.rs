//! Hand-rolled substrates: PRNG, JSON, hashing, property testing, and
//! filesystem helpers.
//!
//! The offline vendor set contains only the `xla` crate and its build
//! chain, so everything usually pulled from crates.io (rand, serde,
//! proptest, csv) is implemented here, scoped to exactly what the
//! experiment harness needs.

pub mod hash;
pub mod json;
pub mod prng;
pub mod propcheck;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Write `bytes` to `path` atomically and durably: write a `.tmp`
/// sibling, fsync it, then rename it over the target (and best-effort
/// fsync the parent directory so the rename itself is durable). On POSIX
/// the rename is atomic, so neither a process crash nor a power loss can
/// leave a truncated `path` — readers either see the old complete file
/// or the new one. A stale `.tmp` may survive a crash; it is simply
/// overwritten by the next save. Parent directories are created as
/// needed.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;

    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create dir {}", dir.display()))?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("write_atomic: no file name in {}", path.display()))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    // data must hit disk before the rename commits the new name — else a
    // power loss could leave the final path pointing at unwritten blocks
    f.sync_all()
        .with_context(|| format!("fsync {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path).with_context(|| {
        format!("rename {} -> {}", tmp.display(), path.display())
    })?;
    // make the rename durable too; non-fatal if the platform disallows
    // opening directories (the file contents are already safe)
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_creates_parents_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("out.json");
        write_atomic(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(siblings, vec!["out.json"], "no .tmp residue: {siblings:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_atomic_overwrites_existing() {
        let dir = std::env::temp_dir().join("cpt_write_atomic_test2");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("out.bin");
        write_atomic(&path, b"first version, longer").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Tiny property-testing harness (proptest is not in the offline vendor
//! set). Generates seeded random cases, runs a property, and on failure
//! reports the seed + case index so the exact case replays deterministically.
//!
//! Usage:
//! ```ignore
//! propcheck(200, |rng| {
//!     let n = 1 + rng.below(64) as usize;
//!     let sched = Schedule::suite("CR", 3.0, 8.0, n * 10, 2).unwrap();
//!     for t in 0..n * 10 {
//!         let q = sched.q_at(t);
//!         prop_assert!(q >= 3 && q <= 8, "q out of range: {q}");
//!     }
//!     Ok(())
//! });
//! ```

use crate::util::prng::Pcg32;

/// Result of a single property case: Err carries the failure message.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`. Panics (test failure) on the first
/// failing case with enough context to replay it.
pub fn propcheck<F>(cases: u32, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> PropResult,
{
    propcheck_seeded(0xC0FFEE, cases, &mut prop);
}

/// Like [propcheck] with an explicit base seed.
pub fn propcheck_seeded<F>(seed: u64, cases: u32, prop: &mut F)
where
    F: FnMut(&mut Pcg32) -> PropResult,
{
    for case in 0..cases {
        let mut rng = Pcg32::new(seed, case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed (seed={seed:#x}, case={case}/{cases}): {msg}"
            );
        }
    }
}

/// Assertion helpers producing PropResult-friendly errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {a} != {b} = {} (tol {})",
                stringify!($a),
                stringify!($b),
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        propcheck(50, |rng| {
            let x = rng.next_f32();
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_context() {
        propcheck(50, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "got {x}");
            Ok(())
        });
    }
}

//! Minimal JSON parser + writer.
//!
//! Used for the artifact manifest (written by python/compile/aot.py) and
//! for emitting machine-readable experiment results. serde is not in the
//! offline vendor set, so this is a small, strict, recursive-descent
//! implementation covering the full JSON grammar we produce/consume.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// round-tripping in tests and result files.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- writer ----------------------------------------------------------

    /// Pretty-print to `path` atomically (tmp sibling + rename) — the
    /// write path for run manifests and cell artifacts, where a crash
    /// mid-save must never leave a truncated file.
    pub fn write_atomic(
        &self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<()> {
        crate::util::write_atomic(path, self.to_string_pretty().as_bytes())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // -0.0 must keep its sign bit (the integer shortcut would
                // print "0" and break bit-exact f64 round-trips)
                if x.fract() == 0.0
                    && x.abs() < 1e15
                    && !(*x == 0.0 && x.is_sign_negative())
                {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                b => {
                    // collect UTF-8 continuation bytes verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(
                        &self.bytes[start..self.pos],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("zz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"π""#).unwrap();
        assert_eq!(v, Json::Str("A\t\"π".into()));
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn negative_zero_roundtrips_with_sign() {
        let v = Json::Num(-0.0);
        let txt = v.to_string_compact();
        assert_eq!(txt, "-0");
        let back = Json::parse(&txt).unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // positive zero still takes the integer shortcut
        assert_eq!(Json::Num(0.0).to_string_compact(), "0");
    }

    #[test]
    fn write_atomic_emits_parseable_file() {
        let dir = std::env::temp_dir().join("cpt_json_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let p = dir.join("doc.json");
        let v = Json::parse(r#"{"a": [1, 2], "b": "x"}"#).unwrap();
        v.write_atomic(&p).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(v, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_real_manifest() {
        // mirror of the aot.py manifest structure
        let src = r#"{"version":1,"chunk":8,"models":{"mlp":{
            "param_count":2372,"files":{"init":"mlp_init.hlo.txt"},
            "data_inputs":[{"name":"x","shape":[32,32],"dtype":"f32","stacked":true}]}}}"#;
        let v = Json::parse(src).unwrap();
        let m = v.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_usize().unwrap(), 2372);
        let di = &m.get("data_inputs").unwrap().as_arr().unwrap()[0];
        assert!(di.get("stacked").unwrap().as_bool().unwrap());
    }
}

//! FNV-1a 64-bit hashing — the content-address primitive for sweep
//! plans and run artifacts.
//!
//! Not cryptographic: the hashes defend against accidental mixing of
//! incompatible shards and against torn/corrupt artifact files, not
//! against an adversary. FNV-1a is deterministic across platforms and
//! has no dependencies, which is what the offline vendor set allows.

/// Incremental FNV-1a 64 hasher, for content that arrives in chunks
/// (e.g. a model fingerprint over metadata + several HLO files).
pub struct Fnv1a64(u64);

impl Fnv1a64 {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Fnv1a64 {
        Fnv1a64(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }

    /// Finish as a fixed-width lowercase hex string (16 chars).
    pub fn finish_hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// FNV-1a over a byte slice (64-bit offset basis / prime).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update(bytes);
    h.finish()
}

/// FNV-1a as a fixed-width lowercase hex string (16 chars).
pub fn fnv1a64_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_35c8_43ba_3b48);
    }

    #[test]
    fn hex_is_fixed_width() {
        let h = fnv1a64_hex(b"");
        assert_eq!(h.len(), 16);
        assert_eq!(h, "cbf29ce484222325");
        // leading zeros preserved
        assert!(fnv1a64_hex(b"anything").chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn sensitive_to_single_byte() {
        assert_ne!(fnv1a64(b"cell-0001"), fnv1a64(b"cell-0002"));
    }

    #[test]
    fn incremental_matches_one_shot() {
        let mut h = Fnv1a64::new();
        h.update(b"foo");
        h.update(b"");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
        assert_eq!(h.finish_hex(), fnv1a64_hex(b"foobar"));
    }
}

//! The `cpt serve` daemon: a TCP accept loop, one connection-handler
//! thread per client, and `--concurrent-jobs` executor threads that
//! drain the job queue through the existing campaign machinery — in
//! production every executor routes its job onto one persistent
//! [`crate::coordinator::pool::WorkerPool`], so concurrent jobs
//! multiplex over shared workers (fair-share claiming) and a job
//! sharing a model fingerprint with an earlier one reuses the workers'
//! warm executable caches instead of recompiling.
//!
//! Execution is injected as a [`CampaignExec`] closure so the whole
//! daemon — protocol, dedupe, job lifecycle, crash recovery — is
//! testable with fabricated cell runners and no PJRT runtime;
//! production wires `coordinator::campaign::run_campaign_pooled` over
//! the artifact manifest, plus a [`DrainHook`] that shuts the pool down
//! when the daemon stops (in-flight cells finish, each interrupted job
//! reports [`crate::coordinator::pool::Drained`] and is demoted back to
//! `queued` — durable for resume on the next daemon start).
//!
//! Dedupe semantics: the job ticket is the campaign content hash, and
//! the daemon derives it server-side from the submitted spec bytes.
//! Identical submissions therefore collide on the ticket — a queued or
//! running job is attached to, and a done job answers straight from its
//! `csv/` directory with zero new cells and zero new compiles.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::jobs::{self, JobRecord, JobState, JobStats};
use super::proto::{self, ErrorCode, Request, Response, ServeStats};
use crate::config::toml::TomlDoc;
use crate::coordinator::campaign::{
    CampaignPlan, CampaignRunOpts, CampaignRunResult, CampaignSpec,
    SchedulerKind, SchedulerStats,
};
use crate::coordinator::lease::Clock;
use crate::coordinator::{pool, report, ShardId};
use crate::obs::metrics::Registry;
use crate::util::{self, FrameError};

/// How accepted jobs are executed. Production: a closure over
/// `run_campaign_pooled(plan, opts, ..)` sharing one [`pool::WorkerPool`]
/// across jobs. Tests: `run_campaign_global` (or a pooled equivalent)
/// with a fabricated `CellRunner` and an execution counter.
pub type CampaignExec = Arc<
    dyn Fn(&CampaignPlan, &CampaignRunOpts) -> Result<CampaignRunResult>
        + Send
        + Sync,
>;

/// Invoked once when the daemon begins stopping, before the executor
/// threads are joined — production passes `pool.shutdown()` so in-flight
/// cells finish and interrupted jobs drain as [`pool::Drained`].
pub type DrainHook = Arc<dyn Fn() + Send + Sync>;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// The serve root (marker, job records, nested campaign roots).
    pub root: PathBuf,
    /// Bind address, e.g. `127.0.0.1:0` (the bound address — with the
    /// real port — is written to `<root>/serve-addr`).
    pub listen: String,
    /// Worker-pool size shared by all concurrent jobs.
    pub jobs: usize,
    /// Jobs admitted to the pool at once (executor threads). The pool's
    /// fair-share claiming splits workers across them.
    pub concurrent: usize,
    /// Allow non-loopback `--listen` binds. The daemon has no
    /// authentication, so exposing it beyond localhost is opt-in.
    pub allow_remote: bool,
    pub verbose: bool,
}

struct ServeState {
    jobs: HashMap<String, JobRecord>,
    /// Tickets awaiting execution, FIFO.
    queue: VecDeque<String>,
    /// Built plans for queued jobs (moved out when execution starts).
    plans: HashMap<String, CampaignPlan>,
}

struct Inner {
    root: PathBuf,
    exec_jobs: usize,
    verbose: bool,
    exec: CampaignExec,
    drain: Option<DrainHook>,
    clock: Arc<dyn Clock>,
    state: Mutex<ServeState>,
    wake: Condvar,
    stop: AtomicBool,
    addr: String,
    /// Daemon start time (clock seconds) — the `stats` uptime base.
    start: f64,
    /// Per-daemon counters (request/error/latency); deliberately not the
    /// process-global registry so parallel test daemons stay isolated.
    metrics: Registry,
}

impl Inner {
    fn count_error(&self, code: ErrorCode) {
        self.metrics
            .inc(&format!("serve.errors.{}", code.as_str()), 1);
    }
}

/// A running daemon. Dropping it does NOT stop the threads — call
/// [`Server::wait`] (blocks until a `shutdown` request arrives) or
/// [`Server::stop`].
pub struct Server {
    inner: Arc<Inner>,
    accept: Option<std::thread::JoinHandle<()>>,
    executors: Vec<std::thread::JoinHandle<()>>,
}

/// Whether a `host:port` listen string names a loopback interface.
fn is_loopback_listen(listen: &str) -> bool {
    let host = match listen.rsplit_once(':') {
        Some((h, _)) => h,
        None => listen,
    };
    let host = host.trim_start_matches('[').trim_end_matches(']');
    if host.eq_ignore_ascii_case("localhost") {
        return true;
    }
    host.parse::<std::net::IpAddr>().map_or(false, |ip| ip.is_loopback())
}

impl Server {
    /// Initialize the root, recover interrupted jobs, bind, publish the
    /// bound address, and spawn the accept + executor threads.
    pub fn start(
        opts: ServeOpts,
        exec: CampaignExec,
        drain: Option<DrainHook>,
        clock: Arc<dyn Clock>,
    ) -> Result<Server> {
        if !opts.allow_remote && !is_loopback_listen(&opts.listen) {
            bail!(
                "refusing to bind non-localhost listen address '{}': the \
                 daemon has no authentication; pass --allow-remote to \
                 expose it beyond loopback",
                opts.listen
            );
        }
        jobs::init_serve_root(&opts.root)?;
        let mut state = ServeState {
            jobs: HashMap::new(),
            queue: VecDeque::new(),
            plans: HashMap::new(),
        };
        // Crash recovery: a job found `running` belongs to a dead
        // daemon. Demote it to `queued`; the nested campaign root
        // resumes every cell it recorded before the crash.
        for mut rec in jobs::list_jobs(&opts.root)? {
            if !rec.state.is_terminal() {
                match recover_plan(&opts.root, &rec) {
                    Ok(plan) => {
                        if rec.state != JobState::Queued {
                            rec.state = JobState::Queued;
                            rec.store(&opts.root)?;
                        }
                        state.plans.insert(rec.ticket.clone(), plan);
                        state.queue.push_back(rec.ticket.clone());
                    }
                    Err(e) => {
                        rec.state = JobState::Failed;
                        rec.error = Some(format!("recovery: {e:#}"));
                        rec.finished = Some(clock.now());
                        rec.store(&opts.root)?;
                    }
                }
            }
            state.jobs.insert(rec.ticket.clone(), rec);
        }
        let listener = TcpListener::bind(opts.listen.as_str())
            .with_context(|| format!("bind {}", opts.listen))?;
        let addr = listener
            .local_addr()
            .context("read bound address")?
            .to_string();
        util::write_atomic(
            opts.root.join(jobs::SERVE_ADDR_FILE),
            addr.as_bytes(),
        )?;
        let start = clock.now();
        let inner = Arc::new(Inner {
            root: opts.root,
            exec_jobs: opts.jobs,
            verbose: opts.verbose,
            exec,
            drain,
            clock,
            state: Mutex::new(state),
            wake: Condvar::new(),
            stop: AtomicBool::new(false),
            addr,
            start,
            metrics: Registry::new(),
        });
        let executors = (0..opts.concurrent.max(1))
            .map(|_| {
                let inner = inner.clone();
                std::thread::spawn(move || executor_loop(&inner))
            })
            .collect();
        let accept = {
            let inner = inner.clone();
            std::thread::spawn(move || accept_loop(&inner, listener))
        };
        Ok(Server { inner, accept: Some(accept), executors })
    }

    /// The bound address (host:port), useful with `--listen *:0`.
    pub fn addr(&self) -> &str {
        &self.inner.addr
    }

    /// Ask the daemon to stop (same path as the `shutdown` verb).
    pub fn stop(&self) {
        trigger_stop(&self.inner);
    }

    /// Block until the daemon stops (a `shutdown` request or
    /// [`Server::stop`]), then join both threads.
    pub fn wait(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow!("accept thread panicked"))?;
        }
        for h in self.executors.drain(..) {
            h.join().map_err(|_| anyhow!("executor thread panicked"))?;
        }
        Ok(())
    }
}

/// Parse + validate a submitted spec and build its plan. The plan's
/// `campaign_hash` is the job ticket.
fn build_plan(spec_toml: &str) -> Result<CampaignPlan> {
    let doc = TomlDoc::parse(spec_toml).context("parse campaign TOML")?;
    let spec = CampaignSpec::from_toml(&doc)?;
    CampaignPlan::build(&spec)
}

/// Rebuild a recovered job's plan from its persisted spec bytes, and
/// fence it against the recorded ticket — a content mismatch means the
/// job dir was tampered with or half-written, so the job fails rather
/// than executing the wrong spec under a cached ticket.
fn recover_plan(root: &std::path::Path, rec: &JobRecord) -> Result<CampaignPlan> {
    let path = jobs::job_dir(root, &rec.ticket).join(jobs::JOB_SPEC_FILE);
    let src = std::fs::read_to_string(&path)
        .with_context(|| format!("read {}", path.display()))?;
    let plan = build_plan(&src)?;
    if plan.campaign_hash != rec.ticket {
        bail!(
            "persisted spec hashes to {}, job record says {}",
            plan.campaign_hash,
            rec.ticket
        );
    }
    Ok(plan)
}

fn trigger_stop(inner: &Arc<Inner>) {
    inner.stop.store(true, Ordering::SeqCst);
    // drain the shared worker pool (idempotent): in-flight cells finish,
    // interrupted jobs return `Drained` and demote themselves to queued
    if let Some(drain) = &inner.drain {
        drain();
    }
    inner.wake.notify_all();
    // the accept loop blocks in accept(2); a throwaway self-connection
    // unblocks it so it can observe the stop flag
    let _ = TcpStream::connect(inner.addr.as_str());
}

fn accept_loop(inner: &Arc<Inner>, listener: TcpListener) {
    for conn in listener.incoming() {
        if inner.stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let inner = inner.clone();
                std::thread::spawn(move || handle_conn(&inner, stream));
            }
            // transient accept failures (peer reset mid-handshake, fd
            // pressure) must not kill the daemon
            Err(_) => continue,
        }
    }
}

fn send(stream: &mut TcpStream, resp: &Response) -> std::io::Result<()> {
    util::write_frame(stream, proto::encode_response(resp).as_bytes())
}

/// One client connection: frames are handled in order; malformed frames
/// get a typed error reply. Only a compromised *stream* (truncated or
/// oversized frame — resync is impossible) closes the connection; every
/// in-frame error leaves it usable for the next request.
fn handle_conn(inner: &Arc<Inner>, stream: TcpStream) {
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    loop {
        let frame = match util::read_frame(&mut reader, proto::MAX_FRAME_BYTES)
        {
            Ok(Some(frame)) => frame,
            Ok(None) => return, // clean EOF on a frame boundary
            Err(FrameError::Truncated) => {
                inner.count_error(ErrorCode::BadFrame);
                let _ = send(
                    &mut writer,
                    &Response::Error {
                        code: ErrorCode::BadFrame,
                        message: "stream ended mid-frame (missing \
                                  terminator)"
                            .to_string(),
                    },
                );
                return;
            }
            Err(FrameError::TooLarge { max }) => {
                inner.count_error(ErrorCode::FrameTooLarge);
                let _ = send(
                    &mut writer,
                    &Response::Error {
                        code: ErrorCode::FrameTooLarge,
                        message: format!(
                            "frame exceeds the {max}-byte cap"
                        ),
                    },
                );
                return;
            }
            Err(FrameError::Io(_)) => return,
        };
        match proto::decode_request(&frame) {
            Ok(Request::Shutdown) => {
                // reply first so the client sees the acknowledgement,
                // then stop the world
                let _ = send(&mut writer, &Response::ShuttingDown);
                trigger_stop(inner);
                return;
            }
            Ok(req) => {
                let resp = handle_request(inner, &req);
                if send(&mut writer, &resp).is_err() {
                    return;
                }
            }
            Err((code, message)) => {
                inner.metrics.inc("serve.requests", 1);
                inner.count_error(code);
                if send(&mut writer, &Response::Error { code, message })
                    .is_err()
                {
                    return;
                }
            }
        }
    }
}

fn internal(e: anyhow::Error) -> Response {
    Response::Error { code: ErrorCode::Internal, message: format!("{e:#}") }
}

fn handle_request(inner: &Arc<Inner>, req: &Request) -> Response {
    let t0 = std::time::Instant::now();
    inner.metrics.inc("serve.requests", 1);
    let resp = dispatch(inner, req);
    inner
        .metrics
        .observe("serve.request_seconds", t0.elapsed().as_secs_f64());
    if let Response::Error { code, .. } = &resp {
        inner.count_error(*code);
    }
    resp
}

fn dispatch(inner: &Arc<Inner>, req: &Request) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Submit { spec_toml } => submit(inner, spec_toml),
        Request::Status { ticket } => status(inner, ticket),
        Request::Result { ticket } => result(inner, ticket),
        Request::Jobs => jobs_list(inner),
        Request::Gc { max_age, max_bytes } => gc(inner, *max_age, *max_bytes),
        Request::Stats => stats(inner),
        // handled by the connection loop; answering here keeps the
        // match total
        Request::Shutdown => Response::ShuttingDown,
    }
}

/// The `stats` verb: uptime, job counts by state, the request/error
/// counters, and pool compile/cache work summed over finished jobs.
fn stats(inner: &Arc<Inner>) -> Response {
    let (jobs_by_state, pool) = {
        let st = inner.state.lock().unwrap();
        let mut by_state: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        let mut pool = JobStats::default();
        for rec in st.jobs.values() {
            *by_state.entry(rec.state.as_str()).or_insert(0) += 1;
            if let Some(s) = &rec.stats {
                pool.compiles += s.compiles;
                pool.compile_seconds += s.compile_seconds;
                pool.hits += s.hits;
                pool.disk_hits += s.disk_hits;
                pool.misses += s.misses;
            }
        }
        let by_state: Vec<(String, usize)> = by_state
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect();
        (by_state, pool)
    };
    let snap = inner.metrics.snapshot();
    Response::Stats {
        stats: ServeStats {
            uptime_seconds: (inner.clock.now() - inner.start).max(0.0),
            jobs_by_state,
            requests: snap.counter("serve.requests"),
            errors_by_code: snap.counters_with_prefix("serve.errors"),
            pool,
        },
    }
}

/// Prune finished job dirs by age/byte budget. Runs under the state
/// lock so no job can transition (or be submitted) mid-prune; queued and
/// running jobs are never touched.
fn gc(
    inner: &Arc<Inner>,
    max_age: Option<f64>,
    max_bytes: Option<u64>,
) -> Response {
    let mut st = inner.state.lock().unwrap();
    let now = inner.clock.now();
    match jobs::gc_serve_root(&inner.root, max_age, max_bytes, now) {
        Ok(out) => {
            for t in &out.removed {
                st.jobs.remove(t);
            }
            if inner.verbose && !out.removed.is_empty() {
                crate::log_info!(
                    "[serve] gc pruned {} job(s), {} bytes",
                    out.removed.len(),
                    out.bytes_freed
                );
            }
            Response::GcDone {
                removed: out.removed.len(),
                bytes_freed: out.bytes_freed,
            }
        }
        Err(e) => internal(e),
    }
}

fn submit(inner: &Arc<Inner>, spec_toml: &str) -> Response {
    let plan = match build_plan(spec_toml) {
        Ok(p) => p,
        Err(e) => {
            return Response::Error {
                code: ErrorCode::BadSpec,
                message: format!("{e:#}"),
            }
        }
    };
    let ticket = plan.campaign_hash.clone();
    let mut st = inner.state.lock().unwrap();
    if let Some(rec) = st.jobs.get(&ticket) {
        // the dedupe path: same hash ⇒ same bytes ⇒ the existing job
        // (in flight or done) IS this submission's result
        return Response::Submitted {
            ticket,
            state: rec.state,
            attached: true,
            planned: rec.planned,
        };
    }
    let rec = JobRecord {
        ticket: ticket.clone(),
        name: plan.name.clone(),
        state: JobState::Queued,
        planned: plan.total_cells(),
        submitted: inner.clock.now(),
        finished: None,
        error: None,
        stats: None,
    };
    // durable before visible: spec bytes + job record hit disk before
    // the registry/queue learn the ticket, so a crash between the two
    // leaves a recoverable job dir, never a queued ghost
    let spec_path =
        jobs::job_dir(&inner.root, &ticket).join(jobs::JOB_SPEC_FILE);
    if let Err(e) = util::write_atomic(&spec_path, spec_toml.as_bytes())
        .and_then(|()| rec.store(&inner.root))
    {
        return internal(e);
    }
    let planned = rec.planned;
    st.jobs.insert(ticket.clone(), rec);
    st.plans.insert(ticket.clone(), plan);
    st.queue.push_back(ticket.clone());
    inner.wake.notify_all();
    if inner.verbose {
        crate::log_info!("[serve] queued job {ticket} ({planned} cells)");
    }
    Response::Submitted {
        ticket,
        state: JobState::Queued,
        attached: false,
        planned,
    }
}

fn status(inner: &Arc<Inner>, ticket: &str) -> Response {
    let st = inner.state.lock().unwrap();
    match st.jobs.get(ticket) {
        Some(rec) => {
            Response::Status { job: jobs::view(&inner.root, rec) }
        }
        None => Response::Error {
            code: ErrorCode::UnknownTicket,
            message: format!("no job with ticket '{ticket}'"),
        },
    }
}

fn result(inner: &Arc<Inner>, ticket: &str) -> Response {
    let state = {
        let st = inner.state.lock().unwrap();
        match st.jobs.get(ticket) {
            Some(rec) => (rec.state, rec.error.clone()),
            None => {
                return Response::Error {
                    code: ErrorCode::UnknownTicket,
                    message: format!("no job with ticket '{ticket}'"),
                }
            }
        }
    };
    match state {
        (JobState::Failed, error) => Response::Error {
            code: ErrorCode::JobFailed,
            message: error.unwrap_or_else(|| "job failed".to_string()),
        },
        (JobState::Queued, _) | (JobState::Running, _) => Response::Error {
            code: ErrorCode::NotDone,
            message: format!("job '{ticket}' has not finished yet"),
        },
        (JobState::Done, _) => {
            match jobs::read_result_files(&inner.root, ticket) {
                Ok(files) => Response::ResultFiles {
                    ticket: ticket.to_string(),
                    files,
                },
                Err(e) => internal(e),
            }
        }
    }
}

fn jobs_list(inner: &Arc<Inner>) -> Response {
    let st = inner.state.lock().unwrap();
    let mut recs: Vec<&JobRecord> = st.jobs.values().collect();
    recs.sort_by(|a, b| {
        a.submitted
            .partial_cmp(&b.submitted)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.ticket.cmp(&b.ticket))
    });
    Response::Jobs {
        jobs: recs.iter().map(|r| jobs::view(&inner.root, r)).collect(),
    }
}

/// Persist + publish a job state transition.
fn set_state(
    inner: &Arc<Inner>,
    ticket: &str,
    state: JobState,
    error: Option<String>,
    stats: Option<JobStats>,
) {
    let mut st = inner.state.lock().unwrap();
    if let Some(rec) = st.jobs.get_mut(ticket) {
        rec.state = state;
        rec.error = error;
        if let Some(s) = stats {
            rec.stats = Some(s);
        }
        if state.is_terminal() {
            rec.finished = Some(inner.clock.now());
        }
        if let Err(e) = rec.store(&inner.root) {
            // the in-memory registry is still correct; the durable copy
            // will be healed by the next transition or recovery pass
            crate::log_warn!("[serve] warning: persisting job {ticket}: {e:#}");
        }
    }
}

/// This job's share of the shared pool's work, summed over the workers
/// that ran its cells.
fn job_stats_of(sched: &SchedulerStats) -> JobStats {
    let mut s = JobStats::default();
    for w in &sched.workers {
        s.compiles += w.compiles;
        s.compile_seconds += w.compile_seconds;
        s.hits += w.hits;
        s.disk_hits += w.disk_hits;
        s.misses += w.misses;
    }
    s
}

/// One of `--concurrent-jobs` executors: each claims the next queued
/// ticket FIFO and runs it through the injected exec over a nested
/// campaign root opened with resume semantics (fresh and recovered jobs
/// share one path). Concurrent executors multiplex over the shared
/// worker pool, whose fair-share claiming keeps a small job from
/// queueing behind a large one. Stop is checked *before* claiming, so a
/// shutdown leaves queued jobs durable for the next daemon start.
fn executor_loop(inner: &Arc<Inner>) {
    loop {
        let (ticket, plan) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(t) = st.queue.pop_front() {
                    match st.plans.remove(&t) {
                        Some(p) => break (t, p),
                        // unreachable by construction; skip defensively
                        None => continue,
                    }
                }
                st = inner.wake.wait(st).unwrap();
            }
        };
        run_job(inner, &ticket, &plan);
    }
}

fn run_job(inner: &Arc<Inner>, ticket: &str, plan: &CampaignPlan) {
    set_state(inner, ticket, JobState::Running, None, None);
    if inner.verbose {
        crate::log_info!("[serve] running job {ticket}");
    }
    let dir = jobs::job_dir(&inner.root, ticket);
    let opts = CampaignRunOpts {
        root: dir.join(jobs::JOB_RUN_DIR),
        shard: ShardId::single(),
        jobs: inner.exec_jobs,
        resume: true,
        verbose: inner.verbose,
        scheduler: SchedulerKind::Global,
    };
    let outcome = (inner.exec)(plan, &opts).and_then(|result| {
        // the same CSV-tree writer `cpt campaign` reports through, so a
        // fetched result is byte-identical to a direct run of the spec
        report::write_campaign_csv_tree(
            &dir.join(jobs::JOB_CSV_DIR),
            result
                .members
                .iter()
                .map(|m| (m.name.as_str(), m.outcomes.as_slice())),
        )
        .map(|()| result)
    });
    match outcome {
        Ok(result) => {
            let stats = result.scheduler.as_ref().map(job_stats_of);
            set_state(inner, ticket, JobState::Done, None, stats);
            if inner.verbose {
                crate::log_info!("[serve] job {ticket} done");
            }
        }
        Err(e) if e.downcast_ref::<pool::Drained>().is_some() => {
            // shutdown drained the pool mid-job: every recorded cell is
            // durable in the nested campaign root, so demote to queued —
            // the next daemon start resumes it instead of reporting a
            // failure
            set_state(inner, ticket, JobState::Queued, None, None);
            if inner.verbose {
                crate::log_info!(
                    "[serve] job {ticket} drained; queued for resume"
                );
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            crate::log_warn!("[serve] job {ticket} failed: {msg}");
            set_state(inner, ticket, JobState::Failed, Some(msg), None);
        }
    }
}

//! The `cpt serve` wire protocol: one compact JSON object per
//! newline-terminated frame (see `util::read_frame`/`write_frame`), in
//! both directions, over a localhost TCP connection.
//!
//! Every request carries the schema version (`"v": 1`) and a `verb`;
//! every reply carries the version and either `"ok": true` plus a typed
//! payload or `"ok": false` plus a typed error (`code` + `message`).
//! Decoding is total: any malformed frame maps to a specific
//! [`ErrorCode`] — never a panic — so the daemon can always answer with
//! a typed error reply and the connection stays usable (or is closed
//! cleanly when the stream itself is compromised, i.e. truncated or
//! oversized frames).
//!
//! Compact JSON never emits a raw newline (they are escaped inside
//! strings), so the line framing can never be split by payload content.

use anyhow::{bail, Context, Result};

use super::jobs::{JobState, JobStats, JobView};
use crate::util::json::{self, Json};

/// Wire schema version. A request with any other `v` is answered with
/// `bad_schema_version` and otherwise ignored.
pub const PROTO_VERSION: usize = 1;

/// Frame size cap in both directions. Campaign specs are a few KiB and
/// result CSVs a few hundred KiB; 4 MiB leaves generous headroom while
/// keeping a hostile peer from ballooning daemon memory.
pub const MAX_FRAME_BYTES: usize = 4 << 20;

/// Typed failure classes. The code is machine-readable (stable strings
/// on the wire); the accompanying message is for humans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The stream ended mid-frame (peer hung up before the terminator).
    BadFrame,
    /// A frame exceeded [`MAX_FRAME_BYTES`].
    FrameTooLarge,
    /// The frame is not UTF-8 or not valid JSON.
    BadJson,
    /// Missing or unsupported `v` field.
    BadSchemaVersion,
    /// Well-formed request with a verb this daemon does not know.
    UnknownVerb,
    /// Known verb, but missing or ill-typed fields.
    BadRequest,
    /// `submit` carried a spec that does not parse/validate as a
    /// campaign TOML.
    BadSpec,
    /// `status`/`result` named a ticket this daemon has no job for.
    UnknownTicket,
    /// `result` on a job that is still queued or running.
    NotDone,
    /// `result` on a job that failed; the message carries the job error.
    JobFailed,
    /// Daemon-side fault (I/O on the serve root, ...).
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::FrameTooLarge => "frame_too_large",
            ErrorCode::BadJson => "bad_json",
            ErrorCode::BadSchemaVersion => "bad_schema_version",
            ErrorCode::UnknownVerb => "unknown_verb",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::BadSpec => "bad_spec",
            ErrorCode::UnknownTicket => "unknown_ticket",
            ErrorCode::NotDone => "not_done",
            ErrorCode::JobFailed => "job_failed",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Result<ErrorCode> {
        Ok(match s {
            "bad_frame" => ErrorCode::BadFrame,
            "frame_too_large" => ErrorCode::FrameTooLarge,
            "bad_json" => ErrorCode::BadJson,
            "bad_schema_version" => ErrorCode::BadSchemaVersion,
            "unknown_verb" => ErrorCode::UnknownVerb,
            "bad_request" => ErrorCode::BadRequest,
            "bad_spec" => ErrorCode::BadSpec,
            "unknown_ticket" => ErrorCode::UnknownTicket,
            "not_done" => ErrorCode::NotDone,
            "job_failed" => ErrorCode::JobFailed,
            "internal" => ErrorCode::Internal,
            other => bail!("unknown error code '{other}'"),
        })
    }
}

/// A client request. `Submit` carries the campaign TOML verbatim — the
/// daemon parses and hashes it server-side, so the ticket is derived
/// from content, never trusted from the client.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Submit { spec_toml: String },
    Status { ticket: String },
    Result { ticket: String },
    Jobs,
    /// Prune finished job dirs by age and/or byte budget (queued and
    /// running jobs are never touched). Both fields optional; with
    /// neither, the daemon prunes nothing.
    Gc { max_age: Option<f64>, max_bytes: Option<u64> },
    /// Daemon self-description: uptime, job counts by state, request and
    /// typed-error counters, pool compile/cache totals.
    Stats,
    Shutdown,
}

/// The `stats` reply payload: a point-in-time snapshot of the daemon's
/// metrics registry plus durable job accounting. Count lists are
/// `(key, count)` pairs in the daemon's (sorted) emission order and
/// round-trip verbatim.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeStats {
    pub uptime_seconds: f64,
    /// Jobs per lifecycle state (`queued`/`running`/`done`/`failed`),
    /// only states with at least one job.
    pub jobs_by_state: Vec<(String, usize)>,
    /// Total request frames answered (including error replies).
    pub requests: u64,
    /// Error replies per [`ErrorCode`] string, only codes seen.
    pub errors_by_code: Vec<(String, u64)>,
    /// Pool compile/cache work summed over finished jobs.
    pub pool: JobStats,
}

impl ServeStats {
    pub fn to_json(&self) -> Json {
        let counts = |pairs: &[(String, f64)]| {
            Json::Arr(
                pairs
                    .iter()
                    .map(|(k, n)| {
                        json::obj(vec![
                            ("key", json::s(k)),
                            ("n", json::num(*n)),
                        ])
                    })
                    .collect(),
            )
        };
        let jobs: Vec<(String, f64)> = self
            .jobs_by_state
            .iter()
            .map(|(k, n)| (k.clone(), *n as f64))
            .collect();
        let errs: Vec<(String, f64)> = self
            .errors_by_code
            .iter()
            .map(|(k, n)| (k.clone(), *n as f64))
            .collect();
        json::obj(vec![
            ("uptime_seconds", json::num(self.uptime_seconds)),
            ("jobs_by_state", counts(&jobs)),
            ("requests", json::num(self.requests as f64)),
            ("errors_by_code", counts(&errs)),
            ("pool", self.pool.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeStats> {
        let mut jobs_by_state = Vec::new();
        for e in j.get("jobs_by_state")?.as_arr()? {
            jobs_by_state.push((
                e.get("key")?.as_str()?.to_string(),
                e.get("n")?.as_usize()?,
            ));
        }
        let mut errors_by_code = Vec::new();
        for e in j.get("errors_by_code")?.as_arr()? {
            errors_by_code.push((
                e.get("key")?.as_str()?.to_string(),
                e.get("n")?.as_f64()? as u64,
            ));
        }
        Ok(ServeStats {
            uptime_seconds: j.get("uptime_seconds")?.as_f64()?,
            jobs_by_state,
            requests: j.get("requests")?.as_f64()? as u64,
            errors_by_code,
            pool: JobStats::from_json(j.get("pool")?)?,
        })
    }
}

/// A daemon reply.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Pong,
    /// The submit outcome: `attached` means an identical spec was
    /// already known (queued, running, or done) — no new job was
    /// created and no new cells will run for this submission.
    Submitted {
        ticket: String,
        state: JobState,
        attached: bool,
        planned: usize,
    },
    Status {
        job: JobView,
    },
    /// The finished job's CSV tree as `(file name, contents)` pairs in
    /// name order (member CSVs + `campaign.csv`).
    ResultFiles {
        ticket: String,
        files: Vec<(String, String)>,
    },
    Jobs {
        jobs: Vec<JobView>,
    },
    /// What a `gc` request pruned.
    GcDone {
        removed: usize,
        bytes_freed: u64,
    },
    Stats {
        stats: ServeStats,
    },
    ShuttingDown,
    Error {
        code: ErrorCode,
        message: String,
    },
}

// ---- encoding -----------------------------------------------------------

pub fn encode_request(req: &Request) -> String {
    let mut pairs = vec![("v", json::num(PROTO_VERSION as f64))];
    match req {
        Request::Ping => pairs.push(("verb", json::s("ping"))),
        Request::Submit { spec_toml } => {
            pairs.push(("verb", json::s("submit")));
            pairs.push(("spec_toml", json::s(spec_toml)));
        }
        Request::Status { ticket } => {
            pairs.push(("verb", json::s("status")));
            pairs.push(("ticket", json::s(ticket)));
        }
        Request::Result { ticket } => {
            pairs.push(("verb", json::s("result")));
            pairs.push(("ticket", json::s(ticket)));
        }
        Request::Jobs => pairs.push(("verb", json::s("jobs"))),
        Request::Gc { max_age, max_bytes } => {
            pairs.push(("verb", json::s("gc")));
            if let Some(age) = max_age {
                pairs.push(("max_age", json::num(*age)));
            }
            if let Some(bytes) = max_bytes {
                pairs.push(("max_bytes", json::num(*bytes as f64)));
            }
        }
        Request::Stats => pairs.push(("verb", json::s("stats"))),
        Request::Shutdown => pairs.push(("verb", json::s("shutdown"))),
    }
    json::obj(pairs).to_string_compact()
}

pub fn encode_response(resp: &Response) -> String {
    let mut pairs = vec![("v", json::num(PROTO_VERSION as f64))];
    match resp {
        Response::Pong => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("pong")));
        }
        Response::Submitted { ticket, state, attached, planned } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("submitted")));
            pairs.push(("ticket", json::s(ticket)));
            pairs.push(("state", json::s(state.as_str())));
            pairs.push(("attached", Json::Bool(*attached)));
            pairs.push(("planned", json::num(*planned as f64)));
        }
        Response::Status { job } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("status")));
            pairs.push(("job", job.to_json()));
        }
        Response::ResultFiles { ticket, files } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("result")));
            pairs.push(("ticket", json::s(ticket)));
            let fs = files
                .iter()
                .map(|(name, data)| {
                    json::obj(vec![
                        ("name", json::s(name)),
                        ("data", json::s(data)),
                    ])
                })
                .collect();
            pairs.push(("files", Json::Arr(fs)));
        }
        Response::Jobs { jobs } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("jobs")));
            pairs.push((
                "jobs",
                Json::Arr(jobs.iter().map(|j| j.to_json()).collect()),
            ));
        }
        Response::GcDone { removed, bytes_freed } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("gc_done")));
            pairs.push(("removed", json::num(*removed as f64)));
            pairs.push(("bytes_freed", json::num(*bytes_freed as f64)));
        }
        Response::Stats { stats } => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("stats")));
            pairs.push(("stats", stats.to_json()));
        }
        Response::ShuttingDown => {
            pairs.push(("ok", Json::Bool(true)));
            pairs.push(("reply", json::s("shutting_down")));
        }
        Response::Error { code, message } => {
            pairs.push(("ok", Json::Bool(false)));
            pairs.push((
                "error",
                json::obj(vec![
                    ("code", json::s(code.as_str())),
                    ("message", json::s(message)),
                ]),
            ));
        }
    }
    json::obj(pairs).to_string_compact()
}

// ---- decoding -----------------------------------------------------------

/// Decode one request frame. Every failure maps to the typed error the
/// daemon should answer with; this function cannot panic on any input.
pub fn decode_request(
    frame: &[u8],
) -> std::result::Result<Request, (ErrorCode, String)> {
    let text = std::str::from_utf8(frame)
        .map_err(|e| (ErrorCode::BadJson, format!("frame is not UTF-8: {e}")))?;
    let j = Json::parse(text)
        .map_err(|e| (ErrorCode::BadJson, format!("bad JSON: {e:#}")))?;
    let v = match j.opt("v") {
        Some(v) => v.as_usize().map_err(|_| {
            (
                ErrorCode::BadSchemaVersion,
                "schema version 'v' is not a number".to_string(),
            )
        })?,
        None => {
            return Err((
                ErrorCode::BadSchemaVersion,
                "missing schema version field 'v'".to_string(),
            ))
        }
    };
    if v != PROTO_VERSION {
        return Err((
            ErrorCode::BadSchemaVersion,
            format!("schema version {v} unsupported (this daemon speaks {PROTO_VERSION})"),
        ));
    }
    let verb = match j.opt("verb") {
        Some(s) => s.as_str().map_err(|_| {
            (ErrorCode::BadRequest, "'verb' is not a string".to_string())
        })?,
        None => {
            return Err((
                ErrorCode::BadRequest,
                "missing field 'verb'".to_string(),
            ))
        }
    };
    let str_field = |key: &str| -> std::result::Result<String, (ErrorCode, String)> {
        match j.opt(key) {
            Some(s) => s.as_str().map(|s| s.to_string()).map_err(|_| {
                (
                    ErrorCode::BadRequest,
                    format!("'{key}' is not a string"),
                )
            }),
            None => Err((
                ErrorCode::BadRequest,
                format!("verb '{verb}' requires field '{key}'"),
            )),
        }
    };
    let opt_num_field =
        |key: &str| -> std::result::Result<Option<f64>, (ErrorCode, String)> {
            match j.opt(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => v.as_f64().map(Some).map_err(|_| {
                    (
                        ErrorCode::BadRequest,
                        format!("'{key}' is not a number"),
                    )
                }),
            }
        };
    match verb {
        "ping" => Ok(Request::Ping),
        "jobs" => Ok(Request::Jobs),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "submit" => Ok(Request::Submit { spec_toml: str_field("spec_toml")? }),
        "status" => Ok(Request::Status { ticket: str_field("ticket")? }),
        "result" => Ok(Request::Result { ticket: str_field("ticket")? }),
        "gc" => Ok(Request::Gc {
            max_age: opt_num_field("max_age")?,
            max_bytes: opt_num_field("max_bytes")?.map(|b| b as u64),
        }),
        other => Err((
            ErrorCode::UnknownVerb,
            format!(
                "unknown verb '{other}' (known: ping, submit, status, \
                 result, jobs, gc, stats, shutdown)"
            ),
        )),
    }
}

/// Decode one response frame (the client side; a daemon speaking a
/// different schema or garbage yields an error, never a panic).
pub fn decode_response(frame: &[u8]) -> Result<Response> {
    let text =
        std::str::from_utf8(frame).context("response frame is not UTF-8")?;
    let j = Json::parse(text).context("response frame is not JSON")?;
    let v = j.get("v")?.as_usize()?;
    if v != PROTO_VERSION {
        bail!("server speaks schema version {v}, this client speaks {PROTO_VERSION}");
    }
    if !j.get("ok")?.as_bool()? {
        let e = j.get("error")?;
        return Ok(Response::Error {
            code: ErrorCode::parse(e.get("code")?.as_str()?)?,
            message: e.get("message")?.as_str()?.to_string(),
        });
    }
    let reply = j.get("reply")?.as_str()?;
    match reply {
        "pong" => Ok(Response::Pong),
        "shutting_down" => Ok(Response::ShuttingDown),
        "submitted" => Ok(Response::Submitted {
            ticket: j.get("ticket")?.as_str()?.to_string(),
            state: JobState::parse(j.get("state")?.as_str()?)?,
            attached: j.get("attached")?.as_bool()?,
            planned: j.get("planned")?.as_usize()?,
        }),
        "status" => Ok(Response::Status { job: JobView::from_json(j.get("job")?)? }),
        "result" => {
            let mut files = Vec::new();
            for f in j.get("files")?.as_arr()? {
                files.push((
                    f.get("name")?.as_str()?.to_string(),
                    f.get("data")?.as_str()?.to_string(),
                ));
            }
            Ok(Response::ResultFiles {
                ticket: j.get("ticket")?.as_str()?.to_string(),
                files,
            })
        }
        "jobs" => {
            let mut jobs = Vec::new();
            for entry in j.get("jobs")?.as_arr()? {
                jobs.push(JobView::from_json(entry)?);
            }
            Ok(Response::Jobs { jobs })
        }
        "gc_done" => Ok(Response::GcDone {
            removed: j.get("removed")?.as_usize()?,
            bytes_freed: j.get("bytes_freed")?.as_f64()? as u64,
        }),
        "stats" => Ok(Response::Stats {
            stats: ServeStats::from_json(j.get("stats")?)?,
        }),
        other => bail!("unknown reply kind '{other}'"),
    }
}

//! `cpt serve`: a long-running campaign service with spec-hash result
//! caching.
//!
//! The daemon accepts campaign specs over a typed, line-delimited-JSON
//! protocol on a localhost TCP socket. A submission's job ticket is the
//! spec's campaign content hash, so identical submissions dedupe for
//! free: a queued or running job is attached to, a finished one answers
//! straight from its cached CSVs — zero new compiles, zero new cells.
//!
//! Layout of the module:
//! - [`proto`] — wire format: framing constants, request/response
//!   enums, encode/decode (see `rust/DESIGN-serve.md` for the spec).
//! - [`jobs`] — durable job records and the serve-root directory
//!   layout (`serve.json`, `serve-addr`, `jobs/<ticket>/...`).
//! - [`daemon`] — the server: accept loop, connection handlers, and
//!   `--concurrent-jobs` executor threads that drain the queue through
//!   one persistent shared worker pool (fair-share scheduling,
//!   cross-job warm compiles, graceful drain on shutdown).
//! - [`client`] — the blocking client behind `cpt submit|jobs|result`.

pub mod client;
pub mod daemon;
pub mod jobs;
pub mod proto;

pub use client::Client;
pub use daemon::{CampaignExec, DrainHook, ServeOpts, Server};
pub use jobs::{JobRecord, JobState, JobStats, JobView};
pub use proto::ServeStats;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::toml::TomlDoc;

/// Default bind address: loopback, OS-assigned port (the real port is
/// published to `<root>/serve-addr`).
pub const DEFAULT_LISTEN: &str = "127.0.0.1:0";

/// `[serve]` section of a config file; every field optional so CLI
/// flags can fill the gaps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeConfig {
    pub root: Option<PathBuf>,
    pub listen: Option<String>,
    pub jobs: Option<usize>,
    /// Jobs admitted to the shared worker pool at once
    /// (`--concurrent-jobs`).
    pub concurrent_jobs: Option<usize>,
}

impl ServeConfig {
    /// Read the `[serve]` table. Unknown keys are rejected (a typo
    /// would otherwise silently fall back to a default).
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        let Some(sec) = doc.section("serve") else {
            return Ok(cfg);
        };
        for (k, v) in sec {
            match k.as_str() {
                "root" => cfg.root = Some(PathBuf::from(v.as_str()?)),
                "listen" => cfg.listen = Some(v.as_str()?.to_string()),
                "jobs" => {
                    cfg.jobs = Some(
                        v.as_usize().context("serve key 'jobs'")?,
                    )
                }
                "concurrent_jobs" => {
                    cfg.concurrent_jobs = Some(
                        v.as_usize().context("serve key 'concurrent_jobs'")?,
                    )
                }
                other => bail!(
                    "unknown [serve] key '{other}' (known: root, listen, \
                     jobs, concurrent_jobs)"
                ),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_reads_the_serve_section() {
        let doc = TomlDoc::parse(
            "[serve]\nroot = \"/tmp/sroot\"\nlisten = \"127.0.0.1:7777\"\n\
             jobs = 3\nconcurrent_jobs = 2\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.root.as_deref(), Some(std::path::Path::new("/tmp/sroot")));
        assert_eq!(cfg.listen.as_deref(), Some("127.0.0.1:7777"));
        assert_eq!(cfg.jobs, Some(3));
        assert_eq!(cfg.concurrent_jobs, Some(2));
        // absent section → all defaults
        let doc = TomlDoc::parse("[sweep]\nmodel = \"mlp\"\n").unwrap();
        assert_eq!(
            ServeConfig::from_toml(&doc).unwrap(),
            ServeConfig::default()
        );
    }

    #[test]
    fn serve_config_rejects_unknown_keys() {
        let doc = TomlDoc::parse("[serve]\nroot = \"/x\"\nprot = 1\n").unwrap();
        let err = ServeConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown [serve] key 'prot'"), "{err}");
    }
}

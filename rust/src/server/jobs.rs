//! Durable job records under a `cpt serve` root.
//!
//! Layout:
//!
//! ```text
//! <root>/serve.json            serve-root marker (kind + schema version)
//! <root>/serve-addr            the daemon's bound address, written at
//!                              startup (lets `--listen 127.0.0.1:0`
//!                              pick a free port and still be found)
//! <root>/jobs/<ticket>/job.json    atomic job record (state machine)
//! <root>/jobs/<ticket>/spec.toml   the submitted campaign spec, verbatim
//! <root>/jobs/<ticket>/run/        nested campaign root (RunStore dirs)
//! <root>/jobs/<ticket>/csv/        result CSVs once the job is done
//! ```
//!
//! The ticket IS the campaign content hash, so the directory doubles as
//! a result cache: resubmitting an identical spec lands on the same
//! ticket and a done job serves `csv/` straight from disk — zero new
//! cells, zero new compiles.
//!
//! `job.json` is rewritten via `util::write_atomic` on every state
//! transition (queued → running → done|failed), so a crashed daemon
//! can never leave a torn record. Crash recovery is cheap by
//! construction: at startup any job found `running` is demoted back to
//! `queued`, and re-execution opens the nested campaign root with
//! `--resume` semantics, so cells recorded before the crash are reused,
//! not recomputed.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::campaign::{self, Status, CAMPAIGN_MANIFEST_FILE};
use crate::coordinator::{store, RunStore};
use crate::util::json::{self, Json};

pub const SERVE_MARKER_FILE: &str = "serve.json";
pub const SERVE_ADDR_FILE: &str = "serve-addr";
pub const SERVE_JOBS_DIR: &str = "jobs";
pub const JOB_FILE: &str = "job.json";
pub const JOB_SPEC_FILE: &str = "spec.toml";
pub const JOB_RUN_DIR: &str = "run";
pub const JOB_CSV_DIR: &str = "csv";

const SERVE_KIND: &str = "cpt-serve";
const JOB_KIND: &str = "cpt-serve-job";
const SERVE_SCHEMA_VERSION: usize = 1;

/// Job lifecycle. `Done` and `Failed` are terminal; everything else is
/// owned by the daemon's executor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            other => bail!("unknown job state '{other}'"),
        })
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// This job's share of the shared worker pool's compile/cache work,
/// recorded when the job completes. Zero `compiles` with nonzero `hits`
/// is the cross-job warm-start signature: every executable this job
/// needed was already compiled by an earlier job on the same pool.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct JobStats {
    pub compiles: usize,
    pub compile_seconds: f64,
    pub hits: usize,
    pub disk_hits: usize,
    pub misses: usize,
}

impl JobStats {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("compiles", json::num(self.compiles as f64)),
            ("compile_seconds", json::num(self.compile_seconds)),
            ("hits", json::num(self.hits as f64)),
            ("disk_hits", json::num(self.disk_hits as f64)),
            ("misses", json::num(self.misses as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobStats> {
        Ok(JobStats {
            compiles: j.get("compiles")?.as_usize()?,
            compile_seconds: j.get("compile_seconds")?.as_f64()?,
            hits: j.get("hits")?.as_usize()?,
            disk_hits: j.get("disk_hits")?.as_usize()?,
            misses: j.get("misses")?.as_usize()?,
        })
    }
}

fn opt_stats(j: &Json, key: &str) -> Result<Option<JobStats>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(JobStats::from_json(v)?)),
    }
}

/// The durable per-job record behind `jobs/<ticket>/job.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    /// Campaign content hash — the job's identity and cache key.
    pub ticket: String,
    /// Campaign name (a label; deliberately outside the hash).
    pub name: String,
    pub state: JobState,
    /// Total planned cells, fixed at submit time.
    pub planned: usize,
    /// Submission time (seconds; daemon clock — injectable in tests).
    pub submitted: f64,
    /// Completion/failure time, once terminal.
    pub finished: Option<f64>,
    /// Failure message, for `Failed` jobs.
    pub error: Option<String>,
    /// Pool accounting for this job, once done. Optional in the JSON
    /// (readers of older records see `None`), so the schema stays v1.
    pub stats: Option<JobStats>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::s(JOB_KIND)),
            ("schema_version", json::num(SERVE_SCHEMA_VERSION as f64)),
            ("cpt_version", json::s(RunStore::code_version())),
            ("ticket", json::s(&self.ticket)),
            ("name", json::s(&self.name)),
            ("state", json::s(self.state.as_str())),
            ("planned", json::num(self.planned as f64)),
            ("submitted", json::num(self.submitted)),
            (
                "finished",
                match self.finished {
                    Some(t) => json::num(t),
                    None => Json::Null,
                },
            ),
            (
                "error",
                match &self.error {
                    Some(e) => json::s(e),
                    None => Json::Null,
                },
            ),
            (
                "stats",
                match &self.stats {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobRecord> {
        let kind = j.get("kind")?.as_str()?;
        if kind != JOB_KIND {
            bail!("not a serve job record (kind '{kind}')");
        }
        let sv = j.get("schema_version")?.as_usize()?;
        if sv != SERVE_SCHEMA_VERSION {
            bail!(
                "job record schema version {sv}, this binary speaks \
                 {SERVE_SCHEMA_VERSION}"
            );
        }
        Ok(JobRecord {
            ticket: j.get("ticket")?.as_str()?.to_string(),
            name: j.get("name")?.as_str()?.to_string(),
            state: JobState::parse(j.get("state")?.as_str()?)?,
            planned: j.get("planned")?.as_usize()?,
            submitted: j.get("submitted")?.as_f64()?,
            finished: opt_f64(j, "finished")?,
            error: opt_str(j, "error")?,
            stats: opt_stats(j, "stats")?,
        })
    }

    /// Persist the record atomically under its job dir.
    pub fn store(&self, root: &Path) -> Result<()> {
        let path = job_dir(root, &self.ticket).join(JOB_FILE);
        self.to_json()
            .write_atomic(&path)
            .with_context(|| format!("write job record {}", path.display()))
    }

    pub fn load(dir: &Path) -> Result<JobRecord> {
        let path = dir.join(JOB_FILE);
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&src)
            .with_context(|| format!("parse {}", path.display()))?;
        JobRecord::from_json(&j)
            .with_context(|| format!("decode {}", path.display()))
    }
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(v.as_f64()?)),
    }
}

fn opt_str(j: &Json, key: &str) -> Result<Option<String>> {
    match j.opt(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(v.as_str()?.to_string())),
    }
}

/// What a client (or `cpt status` on the serve root) sees of one job:
/// the durable record plus a live done-cell count read from the nested
/// campaign manifests — the same source `cpt status` reads everywhere.
#[derive(Clone, Debug, PartialEq)]
pub struct JobView {
    pub ticket: String,
    pub name: String,
    pub state: JobState,
    pub planned: usize,
    /// Cells recorded so far (`None` when the run dir has no readable
    /// manifest yet).
    pub done: Option<usize>,
    pub submitted: f64,
    pub error: Option<String>,
    /// Pool accounting, once the job is done.
    pub stats: Option<JobStats>,
}

impl JobView {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("ticket", json::s(&self.ticket)),
            ("name", json::s(&self.name)),
            ("state", json::s(self.state.as_str())),
            ("planned", json::num(self.planned as f64)),
            (
                "done",
                match self.done {
                    Some(d) => json::num(d as f64),
                    None => Json::Null,
                },
            ),
            ("submitted", json::num(self.submitted)),
            (
                "error",
                match &self.error {
                    Some(e) => json::s(e),
                    None => Json::Null,
                },
            ),
            (
                "stats",
                match &self.stats {
                    Some(s) => s.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobView> {
        let done = match j.opt("done") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_usize()?),
        };
        Ok(JobView {
            ticket: j.get("ticket")?.as_str()?.to_string(),
            name: j.get("name")?.as_str()?.to_string(),
            state: JobState::parse(j.get("state")?.as_str()?)?,
            planned: j.get("planned")?.as_usize()?,
            done,
            submitted: j.get("submitted")?.as_f64()?,
            error: opt_str(j, "error")?,
            stats: opt_stats(j, "stats")?,
        })
    }
}

/// Tickets come off the wire, so validate before using one as a path
/// component: campaign hashes are short hex strings, and anything else
/// (separators, dots, empty) is refused — a hostile ticket can never
/// escape the jobs dir.
pub fn validate_ticket(ticket: &str) -> Result<()> {
    if ticket.is_empty() || ticket.len() > 64 {
        bail!("bad ticket length");
    }
    if !ticket.chars().all(|c| c.is_ascii_alphanumeric()) {
        bail!("ticket contains non-alphanumeric characters");
    }
    Ok(())
}

pub fn job_dir(root: &Path, ticket: &str) -> PathBuf {
    root.join(SERVE_JOBS_DIR).join(ticket)
}

/// Does `dir` carry the serve-root marker?
pub fn is_serve_root(dir: &Path) -> bool {
    dir.join(SERVE_MARKER_FILE).is_file()
}

/// Create the serve root (marker + jobs dir), or validate an existing
/// one. Refuses to take over a sweep run dir or campaign root — status
/// and gc dispatch on which marker/manifest is present, so mixing kinds
/// in one directory would hide recorded progress.
pub fn init_serve_root(root: &Path) -> Result<()> {
    let marker = root.join(SERVE_MARKER_FILE);
    if marker.is_file() {
        let src = std::fs::read_to_string(&marker)
            .with_context(|| format!("read {}", marker.display()))?;
        let j = Json::parse(&src)
            .with_context(|| format!("parse {}", marker.display()))?;
        let kind = j.get("kind")?.as_str()?;
        if kind != SERVE_KIND {
            bail!(
                "{} exists but has kind '{kind}' — not a cpt serve root",
                marker.display()
            );
        }
        let sv = j.get("schema_version")?.as_usize()?;
        if sv != SERVE_SCHEMA_VERSION {
            bail!(
                "serve root {} has schema version {sv}; this binary \
                 speaks {SERVE_SCHEMA_VERSION}",
                root.display()
            );
        }
        return Ok(());
    }
    if root.join(CAMPAIGN_MANIFEST_FILE).exists()
        || root.join(store::MANIFEST_FILE).exists()
    {
        bail!(
            "{} is already a campaign root or sweep run dir; point \
             `cpt serve --root` at a fresh directory",
            root.display()
        );
    }
    std::fs::create_dir_all(root.join(SERVE_JOBS_DIR))
        .with_context(|| format!("create {}", root.display()))?;
    json::obj(vec![
        ("kind", json::s(SERVE_KIND)),
        ("schema_version", json::num(SERVE_SCHEMA_VERSION as f64)),
        ("cpt_version", json::s(RunStore::code_version())),
    ])
    .write_atomic(&marker)
}

/// Load every job record under the root, sorted by submission time then
/// ticket (a stable, human-sensible order for `jobs` listings).
pub fn list_jobs(root: &Path) -> Result<Vec<JobRecord>> {
    let dir = root.join(SERVE_JOBS_DIR);
    let mut out = Vec::new();
    if !dir.is_dir() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(&dir)
        .with_context(|| format!("read {}", dir.display()))?
    {
        let path = entry
            .with_context(|| format!("read entry in {}", dir.display()))?
            .path();
        if !path.join(JOB_FILE).is_file() {
            // staging residue or a foreign file — not a job
            continue;
        }
        out.push(JobRecord::load(&path)?);
    }
    out.sort_by(|a, b| {
        a.submitted
            .partial_cmp(&b.submitted)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.ticket.cmp(&b.ticket))
    });
    Ok(out)
}

/// Live (done, planned) cell counts for a job, read from the nested
/// campaign root's manifests — exactly what `cpt status` reads. `None`
/// while the run dir has no manifest yet (job still queued) or the
/// manifest is unreadable.
pub fn job_progress(root: &Path, ticket: &str) -> Option<(usize, usize)> {
    let run = job_dir(root, ticket).join(JOB_RUN_DIR);
    match campaign::status(&run) {
        Ok(Status::Campaign(c)) => Some((c.done(), c.planned())),
        _ => None,
    }
}

/// Build the client-facing view of one record.
pub fn view(root: &Path, rec: &JobRecord) -> JobView {
    let done = match rec.state {
        JobState::Queued => Some(0),
        _ => job_progress(root, &rec.ticket).map(|(d, _)| d),
    };
    JobView {
        ticket: rec.ticket.clone(),
        name: rec.name.clone(),
        state: rec.state,
        planned: rec.planned,
        done,
        submitted: rec.submitted,
        error: rec.error.clone(),
        stats: rec.stats,
    }
}

/// The job-level view `cpt status` prints for a serve root.
pub fn serve_status(root: &Path) -> Result<Vec<JobView>> {
    Ok(list_jobs(root)?.iter().map(|r| view(root, r)).collect())
}

/// Read a done job's CSV tree as `(file name, contents)` pairs in name
/// order.
pub fn read_result_files(
    root: &Path,
    ticket: &str,
) -> Result<Vec<(String, String)>> {
    let dir = job_dir(root, ticket).join(JOB_CSV_DIR);
    let mut files = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .with_context(|| format!("read {}", dir.display()))?
    {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if !name.ends_with(".csv") {
            continue;
        }
        let data = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        files.push((name.to_string(), data));
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    if files.is_empty() {
        bail!("no result CSVs under {}", dir.display());
    }
    Ok(files)
}

/// What [`gc_serve_root`] pruned.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GcOutcome {
    /// Tickets whose job dirs were removed, in removal order.
    pub removed: Vec<String>,
    pub bytes_freed: u64,
}

fn dir_size(path: &Path) -> u64 {
    let Ok(entries) = std::fs::read_dir(path) else { return 0 };
    let mut total = 0u64;
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            total += dir_size(&p);
        } else if let Ok(md) = entry.metadata() {
            total += md.len();
        }
    }
    total
}

/// Prune finished job dirs (the serve root's result cache) by budget.
/// Only terminal jobs are candidates — queued and running jobs are never
/// touched. Two independent budgets compose:
///
/// * `max_age`: remove terminal jobs whose completion time is more than
///   this many seconds before `now`.
/// * `max_bytes`: if the remaining terminal job dirs still exceed this
///   many bytes, evict least-recently-finished first until they fit.
///
/// With both `None` this is a no-op that reports nothing removed.
pub fn gc_serve_root(
    root: &Path,
    max_age: Option<f64>,
    max_bytes: Option<u64>,
    now: f64,
) -> Result<GcOutcome> {
    let mut out = GcOutcome::default();
    if max_age.is_none() && max_bytes.is_none() {
        return Ok(out);
    }
    // terminal jobs, least-recently-finished first (never-finished
    // terminal records sort oldest — they predate the finished field)
    let mut terminal: Vec<(JobRecord, u64)> = list_jobs(root)?
        .into_iter()
        .filter(|r| r.state.is_terminal())
        .map(|r| {
            let size = dir_size(&job_dir(root, &r.ticket));
            (r, size)
        })
        .collect();
    terminal.sort_by(|a, b| {
        let fa = a.0.finished.unwrap_or(f64::NEG_INFINITY);
        let fb = b.0.finished.unwrap_or(f64::NEG_INFINITY);
        fa.partial_cmp(&fb)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.0.ticket.cmp(&b.0.ticket))
    });
    let mut live_bytes: u64 = terminal.iter().map(|(_, s)| s).sum();
    for (rec, size) in terminal {
        let expired = max_age.map_or(false, |age| {
            rec.finished.map_or(true, |f| f + age <= now)
        });
        let over_budget = max_bytes.map_or(false, |cap| live_bytes > cap);
        if !expired && !over_budget {
            if max_bytes.is_none() {
                continue; // age-only pass: keep scanning younger jobs
            }
            break; // within byte budget, and the list only gets younger
        }
        let dir = job_dir(root, &rec.ticket);
        std::fs::remove_dir_all(&dir)
            .with_context(|| format!("remove {}", dir.display()))?;
        live_bytes = live_bytes.saturating_sub(size);
        out.bytes_freed += size;
        out.removed.push(rec.ticket);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cpt_serve_jobs_{name}"));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn record(ticket: &str, submitted: f64) -> JobRecord {
        JobRecord {
            ticket: ticket.to_string(),
            name: "camp".to_string(),
            state: JobState::Queued,
            planned: 4,
            submitted,
            finished: None,
            error: None,
            stats: None,
        }
    }

    fn done_record(ticket: &str, finished: f64, payload: usize) -> JobRecord {
        let mut rec = record(ticket, finished - 1.0);
        rec.state = JobState::Done;
        rec.finished = Some(finished);
        rec.stats = Some(JobStats {
            compiles: 1,
            compile_seconds: 0.5,
            hits: payload,
            disk_hits: 0,
            misses: 1,
        });
        rec
    }

    /// Store a terminal record plus `payload` bytes of fake artifacts.
    fn store_done(root: &Path, ticket: &str, finished: f64, payload: usize) {
        let rec = done_record(ticket, finished, payload);
        rec.store(root).unwrap();
        let csv = job_dir(root, ticket).join(JOB_CSV_DIR);
        std::fs::create_dir_all(&csv).unwrap();
        std::fs::write(csv.join("a.csv"), vec![b'x'; payload]).unwrap();
    }

    #[test]
    fn job_record_round_trips_through_disk() {
        let root = tmp("roundtrip");
        init_serve_root(&root).unwrap();
        let mut rec = record("abc123", 17.5);
        rec.store(&root).unwrap();
        assert_eq!(JobRecord::load(&job_dir(&root, "abc123")).unwrap(), rec);
        rec.state = JobState::Failed;
        rec.finished = Some(21.25);
        rec.error = Some("compile exploded".to_string());
        rec.store(&root).unwrap();
        assert_eq!(JobRecord::load(&job_dir(&root, "abc123")).unwrap(), rec);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn list_jobs_sorts_by_submission_time() {
        let root = tmp("list");
        init_serve_root(&root).unwrap();
        record("bbb", 2.0).store(&root).unwrap();
        record("aaa", 3.0).store(&root).unwrap();
        record("ccc", 1.0).store(&root).unwrap();
        let tickets: Vec<String> = list_jobs(&root)
            .unwrap()
            .into_iter()
            .map(|r| r.ticket)
            .collect();
        assert_eq!(tickets, vec!["ccc", "bbb", "aaa"]);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn init_refuses_foreign_roots_and_validates_marker() {
        let root = tmp("foreign");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join(store::MANIFEST_FILE), b"{}").unwrap();
        assert!(init_serve_root(&root).is_err(), "sweep run dir refused");
        std::fs::remove_dir_all(&root).ok();

        let root = tmp("marker");
        init_serve_root(&root).unwrap();
        // idempotent reopen
        init_serve_root(&root).unwrap();
        std::fs::write(
            root.join(SERVE_MARKER_FILE),
            b"{\"kind\": \"other\", \"schema_version\": 1}",
        )
        .unwrap();
        assert!(init_serve_root(&root).is_err(), "wrong kind refused");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn job_stats_round_trip_and_stay_optional() {
        let root = tmp("stats");
        init_serve_root(&root).unwrap();
        let rec = done_record("aa11", 9.0, 3);
        rec.store(&root).unwrap();
        let back = JobRecord::load(&job_dir(&root, "aa11")).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.stats.unwrap().hits, 3);
        // records without the stats field (older daemons) still decode
        let plain = record("bb22", 1.0);
        let j = plain.to_json();
        let decoded = JobRecord::from_json(&j).unwrap();
        assert_eq!(decoded.stats, None);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_prunes_by_age_without_touching_live_jobs() {
        let root = tmp("gc_age");
        init_serve_root(&root).unwrap();
        store_done(&root, "old1", 10.0, 8);
        store_done(&root, "new1", 90.0, 8);
        record("live", 5.0).store(&root).unwrap(); // queued: untouchable
        let out = gc_serve_root(&root, Some(50.0), None, 100.0).unwrap();
        assert_eq!(out.removed, vec!["old1"]);
        assert!(out.bytes_freed > 0);
        assert!(!job_dir(&root, "old1").exists());
        assert!(job_dir(&root, "new1").exists());
        assert!(job_dir(&root, "live").exists());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_evicts_least_recently_finished_to_fit_the_byte_budget() {
        let root = tmp("gc_bytes");
        init_serve_root(&root).unwrap();
        store_done(&root, "t1", 10.0, 4000);
        store_done(&root, "t2", 20.0, 4000);
        store_done(&root, "t3", 30.0, 4000);
        // budget fits roughly one job dir: the two oldest go, LRU first
        let total = dir_size(&job_dir(&root, "t1"));
        let out =
            gc_serve_root(&root, None, Some(total + total / 2), 100.0)
                .unwrap();
        assert_eq!(out.removed, vec!["t1", "t2"]);
        assert!(job_dir(&root, "t3").exists());
        // already within budget: nothing more to do
        let out2 =
            gc_serve_root(&root, None, Some(total * 2), 100.0).unwrap();
        assert!(out2.removed.is_empty());
        // no budgets: explicit no-op
        let out3 = gc_serve_root(&root, None, None, 100.0).unwrap();
        assert_eq!(out3, GcOutcome::default());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn tickets_are_validated_as_path_components() {
        assert!(validate_ticket("00ab34cd9900aabb").is_ok());
        assert!(validate_ticket("").is_err());
        assert!(validate_ticket("../evil").is_err());
        assert!(validate_ticket("a/b").is_err());
        assert!(validate_ticket("a.b").is_err());
        assert!(validate_ticket(&"x".repeat(65)).is_err());
    }
}

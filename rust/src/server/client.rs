//! Blocking client for the `cpt serve` protocol. One request in flight
//! at a time per connection; replies arrive in request order, so a
//! plain call/response loop is all the state we need.

use std::io::BufReader;
use std::net::TcpStream;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::jobs::{JobState, JobView};
use super::proto::{self, Request, Response, ServeStats};
use crate::util;

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let writer = TcpStream::connect(addr)
            .with_context(|| format!("connect to cpt serve at {addr}"))?;
        let reader = BufReader::new(
            writer.try_clone().context("clone connection for reading")?,
        );
        Ok(Client { reader, writer })
    }

    /// One request/response round trip; transport and decode errors
    /// only. A typed server error comes back as `Response::Error`.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        util::write_frame(
            &mut self.writer,
            proto::encode_request(req).as_bytes(),
        )
        .context("send request")?;
        let frame = util::read_frame(&mut self.reader, proto::MAX_FRAME_BYTES)
            .map_err(|e| anyhow::anyhow!("read reply: {e}"))?;
        match frame {
            Some(frame) => proto::decode_response(&frame),
            None => bail!("server closed the connection without replying"),
        }
    }

    /// Like [`Client::call`], but a typed server error becomes an
    /// `Err` carrying its code and message.
    pub fn call_ok(&mut self, req: &Request) -> Result<Response> {
        match self.call(req)? {
            Response::Error { code, message } => {
                bail!("server error [{}]: {message}", code.as_str())
            }
            resp => Ok(resp),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call_ok(&Request::Ping)? {
            Response::Pong => Ok(()),
            other => bail!("unexpected reply to ping: {other:?}"),
        }
    }

    /// Submit a campaign spec. Returns `(ticket, state, attached)`;
    /// `attached` means the spec deduped onto an existing job.
    pub fn submit(
        &mut self,
        spec_toml: &str,
    ) -> Result<(String, JobState, bool)> {
        let req = Request::Submit { spec_toml: spec_toml.to_string() };
        match self.call_ok(&req)? {
            Response::Submitted { ticket, state, attached, .. } => {
                Ok((ticket, state, attached))
            }
            other => bail!("unexpected reply to submit: {other:?}"),
        }
    }

    pub fn status(&mut self, ticket: &str) -> Result<JobView> {
        let req = Request::Status { ticket: ticket.to_string() };
        match self.call_ok(&req)? {
            Response::Status { job } => Ok(job),
            other => bail!("unexpected reply to status: {other:?}"),
        }
    }

    /// Poll until the job reaches a terminal state; `Failed` becomes an
    /// `Err` carrying the job's recorded error.
    pub fn wait_done(
        &mut self,
        ticket: &str,
        poll_ms: u64,
    ) -> Result<JobView> {
        loop {
            let v = self.status(ticket)?;
            match v.state {
                JobState::Done => return Ok(v),
                JobState::Failed => bail!(
                    "job {ticket} failed: {}",
                    v.error.as_deref().unwrap_or("(no error recorded)")
                ),
                JobState::Queued | JobState::Running => {
                    std::thread::sleep(std::time::Duration::from_millis(
                        poll_ms,
                    ));
                }
            }
        }
    }

    /// Fetch a finished job's CSVs as `(file name, contents)` pairs.
    pub fn result_files(
        &mut self,
        ticket: &str,
    ) -> Result<Vec<(String, String)>> {
        let req = Request::Result { ticket: ticket.to_string() };
        match self.call_ok(&req)? {
            Response::ResultFiles { files, .. } => Ok(files),
            other => bail!("unexpected reply to result: {other:?}"),
        }
    }

    /// Fetch a finished job's CSVs into `out_dir`, returning the paths
    /// written (atomically, so a re-fetch never tears a file).
    pub fn fetch_result(
        &mut self,
        ticket: &str,
        out_dir: &Path,
    ) -> Result<Vec<PathBuf>> {
        let files = self.result_files(ticket)?;
        let mut written = Vec::with_capacity(files.len());
        for (name, contents) in &files {
            let path = out_dir.join(name);
            util::write_atomic(&path, contents.as_bytes())?;
            written.push(path);
        }
        Ok(written)
    }

    pub fn jobs(&mut self) -> Result<Vec<JobView>> {
        match self.call_ok(&Request::Jobs)? {
            Response::Jobs { jobs } => Ok(jobs),
            other => bail!("unexpected reply to jobs: {other:?}"),
        }
    }

    /// Ask the daemon to prune finished job dirs by age and/or byte
    /// budget. Returns `(jobs removed, bytes freed)`.
    pub fn gc(
        &mut self,
        max_age: Option<f64>,
        max_bytes: Option<u64>,
    ) -> Result<(usize, u64)> {
        match self.call_ok(&Request::Gc { max_age, max_bytes })? {
            Response::GcDone { removed, bytes_freed } => {
                Ok((removed, bytes_freed))
            }
            other => bail!("unexpected reply to gc: {other:?}"),
        }
    }

    /// Fetch the daemon's self-description: uptime, jobs by state,
    /// request/error counters, pool compile/cache totals.
    pub fn stats(&mut self) -> Result<ServeStats> {
        match self.call_ok(&Request::Stats)? {
            Response::Stats { stats } => Ok(stats),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_ok(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => bail!("unexpected reply to shutdown: {other:?}"),
        }
    }
}

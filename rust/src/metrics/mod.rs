//! Run metrics: training history, aggregation over trials, CSV emission.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// Per-run training history.
#[derive(Clone, Debug, Default)]
pub struct History {
    /// (step, train loss) — one entry per optimizer step.
    pub losses: Vec<(usize, f32)>,
    /// (step, train metric).
    pub metrics: Vec<(usize, f32)>,
    /// (step, eval loss, eval metric) at each evaluation point.
    pub evals: Vec<(usize, f32, f32)>,
    /// (step, q_t) — the precision actually used.
    pub precisions: Vec<(usize, u32)>,
    /// cumulative effective GBitOps at the end of the run.
    pub gbitops: f64,
    /// realized mean q_t / q_max over the run (exact, from every executed
    /// step — not subject to `log_every`). 1.0 for a static-q_max run.
    pub mean_q: f64,
    /// realized relative training cost vs static q_max (the
    /// `schedule::cost` formula applied to the executed trace). Adaptive
    /// policies make this data-dependent, so it is recorded, not derived.
    pub realized_cost: f64,
    /// wall-clock seconds spent in executable calls.
    pub exec_seconds: f64,
    /// wall-clock seconds for the full run.
    pub total_seconds: f64,
}

impl History {
    pub fn final_eval_metric(&self) -> Option<f32> {
        self.evals.last().map(|&(_, _, m)| m)
    }

    pub fn final_eval_loss(&self) -> Option<f32> {
        self.evals.last().map(|&(_, l, _)| l)
    }

    /// Best (max) eval metric over the run.
    pub fn best_eval_metric(&self) -> Option<f32> {
        self.evals
            .iter()
            .map(|&(_, _, m)| m)
            .fold(None, |acc, m| Some(acc.map_or(m, |a: f32| a.max(m))))
    }

    /// Mean train loss over the last `n` recorded steps.
    pub fn tail_train_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

/// Simple CSV writer (csv crate unavailable offline).
pub struct CsvWriter {
    buf: String,
    cols: usize,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        let mut buf = String::new();
        let _ = writeln!(buf, "{}", header.join(","));
        CsvWriter { buf, cols: header.len() }
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.cols, "csv row arity mismatch");
        let escaped: Vec<String> = fields
            .iter()
            .map(|f| {
                if f.contains(',') || f.contains('"') || f.contains('\n') {
                    format!("\"{}\"", f.replace('"', "\"\""))
                } else {
                    f.clone()
                }
            })
            .collect();
        let _ = writeln!(self.buf, "{}", escaped.join(","));
    }

    pub fn write_to(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, &self.buf)
            .with_context(|| format!("write {}", path.display()))
    }

    pub fn as_str(&self) -> &str {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_accessors() {
        let mut h = History::default();
        assert!(h.final_eval_metric().is_none());
        h.evals.push((10, 2.0, 0.5));
        h.evals.push((20, 1.5, 0.7));
        h.evals.push((30, 1.6, 0.65));
        assert_eq!(h.final_eval_metric(), Some(0.65));
        assert_eq!(h.best_eval_metric(), Some(0.7));
        h.losses = vec![(0, 4.0), (1, 2.0), (2, 1.0)];
        assert!((h.tail_train_loss(2) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn csv_escaping() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["x,y".into(), "pla\"in".into()]);
        assert_eq!(w.as_str(), "a,b\n\"x,y\",\"pla\"\"in\"\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".into()]);
    }
}

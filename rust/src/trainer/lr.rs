//! Learning-rate schedules used by the paper's training recipes.
//!
//! * `StepDecay` — ×0.1 at 50% and 75% of training (CIFAR/ImageNet, §4.2);
//! * `Cosine` — cosine annealing over the run (OGBN, §4.3);
//! * `LinearDecay` — linear to `end_factor` (XNLI fine-tuning, §4.4);
//! * `Constant` — fixed lr (PascalVOC, §4.2);
//! * `Plateau` — divide by `factor` when the observed loss stops improving
//!   (Penn Treebank LSTM, §4.4). The trainer calls `observe_loss` after
//!   every chunk; because lr is a *runtime input* to the train artifact,
//!   plateau decisions take effect on the very next chunk without any
//!   recompilation.

/// Learning-rate schedule (stateful only for Plateau).
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant {
        lr: f32,
    },
    StepDecay {
        base: f32,
        total: usize,
        /// (fraction of training, multiplier) milestones.
        milestones: Vec<(f32, f32)>,
    },
    Cosine {
        base: f32,
        total: usize,
        final_factor: f32,
    },
    LinearDecay {
        base: f32,
        total: usize,
        end_factor: f32,
    },
    Plateau {
        current: f32,
        factor: f32,
        /// epochs (observation windows) without improvement tolerated
        patience: usize,
        best: f32,
        stale: usize,
        min_lr: f32,
    },
}

impl LrSchedule {
    /// Paper §4.2 CIFAR recipe: 0.1, ×0.1 at 50% and 75%.
    pub fn paper_step_decay(base: f32, total: usize) -> LrSchedule {
        LrSchedule::StepDecay {
            base,
            total,
            milestones: vec![(0.5, 0.1), (0.75, 0.01)],
        }
    }

    pub fn cosine(base: f32, total: usize) -> LrSchedule {
        LrSchedule::Cosine { base, total, final_factor: 0.1 }
    }

    pub fn plateau(base: f32, factor: f32, patience: usize) -> LrSchedule {
        LrSchedule::Plateau {
            current: base,
            factor,
            patience,
            best: f32::INFINITY,
            stale: 0,
            min_lr: 1e-6,
        }
    }

    /// lr for optimizer step `t`.
    pub fn at(&self, t: usize) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::StepDecay { base, total, milestones } => {
                let frac = t as f32 / (*total).max(1) as f32;
                let mut mult = 1.0;
                for &(at, m) in milestones {
                    if frac >= at {
                        mult = m;
                    }
                }
                base * mult
            }
            LrSchedule::Cosine { base, total, final_factor } => {
                let frac = (t as f32 / (*total).max(1) as f32).clamp(0.0, 1.0);
                let c = 0.5 * (1.0 + (std::f32::consts::PI * frac).cos());
                base * (final_factor + (1.0 - final_factor) * c)
            }
            LrSchedule::LinearDecay { base, total, end_factor } => {
                let frac = (t as f32 / (*total).max(1) as f32).clamp(0.0, 1.0);
                base * (1.0 + (end_factor - 1.0) * frac)
            }
            LrSchedule::Plateau { current, .. } => *current,
        }
    }

    /// Feed the last observed training loss (per chunk). Only Plateau
    /// reacts.
    pub fn observe_loss(&mut self, _t: usize, loss: f32) {
        if let LrSchedule::Plateau {
            current, factor, patience, best, stale, min_lr,
        } = self
        {
            if loss.is_finite() && loss < *best * 0.999 {
                *best = loss;
                *stale = 0;
            } else {
                *stale += 1;
                if *stale > *patience {
                    *current = (*current * *factor).max(*min_lr);
                    *stale = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_milestones() {
        let s = LrSchedule::paper_step_decay(0.1, 100);
        assert!((s.at(0) - 0.1).abs() < 1e-7);
        assert!((s.at(49) - 0.1).abs() < 1e-7);
        assert!((s.at(50) - 0.01).abs() < 1e-7);
        assert!((s.at(75) - 0.001).abs() < 1e-8);
        assert!((s.at(99) - 0.001).abs() < 1e-8);
    }

    #[test]
    fn cosine_endpoints_and_monotone() {
        let s = LrSchedule::cosine(1.0, 100);
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
        for t in 0..99 {
            assert!(s.at(t + 1) <= s.at(t) + 1e-7);
        }
    }

    #[test]
    fn linear_decay() {
        let s = LrSchedule::LinearDecay { base: 1.0, total: 10, end_factor: 0.1 };
        assert!((s.at(0) - 1.0).abs() < 1e-7);
        assert!((s.at(10) - 0.1).abs() < 1e-7);
    }

    #[test]
    fn plateau_divides_when_stale() {
        let mut s = LrSchedule::plateau(20.0, 0.2, 1);
        assert_eq!(s.at(0), 20.0);
        s.observe_loss(0, 5.0); // improves (best=5)
        s.observe_loss(1, 5.0); // stale 1 (within patience)
        assert_eq!(s.at(2), 20.0);
        s.observe_loss(2, 5.0); // stale 2 > patience -> divide
        assert!((s.at(3) - 4.0).abs() < 1e-6);
        // improvement resets
        s.observe_loss(3, 1.0);
        s.observe_loss(4, 0.5);
        assert!((s.at(5) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn plateau_respects_min_lr() {
        let mut s = LrSchedule::plateau(1e-5, 0.1, 0);
        for t in 0..10 {
            s.observe_loss(t, 1.0);
        }
        assert!(s.at(11) >= 1e-6);
    }
}

//! Checkpoint container: save/restore the flat param + opt vectors.
//!
//! Simple length-prefixed binary format (magic, version, step, named f32
//! sections). No serde offline; the format is versioned and self-checking
//! (per-section element counts + a whole-file checksum). Saves are atomic
//! (tmp sibling + rename via `util::write_atomic`), so a crash mid-save
//! can never leave a truncated file that `load` rejects — the previous
//! complete checkpoint survives.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"CPTCKPT1";

/// A checkpoint: named flat f32 vectors + the step counter.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn new(step: u64) -> Self {
        Checkpoint { step, sections: Vec::new() }
    }

    pub fn add(&mut self, name: &str, data: Vec<f32>) {
        self.sections.push((name.to_string(), data));
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        // serialize into memory, then write atomically: the target path
        // only ever holds a complete, checksummed checkpoint
        let payload: usize = self
            .sections
            .iter()
            .map(|(n, d)| 12 + n.len() + d.len() * 4)
            .sum();
        let mut buf = Vec::with_capacity(8 + 8 + 4 + payload + 8);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        let mut checksum = 0u64;
        for (name, data) in &self.sections {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for &x in data {
                let b = x.to_le_bytes();
                checksum = checksum
                    .wrapping_mul(31)
                    .wrapping_add(u32::from_le_bytes(b) as u64);
                buf.extend_from_slice(&b);
            }
        }
        buf.extend_from_slice(&checksum.to_le_bytes());
        crate::util::write_atomic(path, &buf)
            .with_context(|| format!("save checkpoint {}", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a CPT checkpoint", path.display());
        }
        let step = read_u64(&mut f)?;
        let n_sections = read_u32(&mut f)? as usize;
        let mut sections = Vec::with_capacity(n_sections);
        let mut checksum = 0u64;
        for _ in 0..n_sections {
            let name_len = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let len = read_u64(&mut f)? as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let mut data = Vec::with_capacity(len);
            for c in bytes.chunks_exact(4) {
                let w = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                checksum = checksum.wrapping_mul(31).wrapping_add(w as u64);
                data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            sections.push((name, data));
        }
        let want = read_u64(&mut f)?;
        if want != checksum {
            bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
        }
        Ok(Checkpoint { step, sections })
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cpt_ckpt_test");
        let path = dir.join("a.ckpt");
        let mut c = Checkpoint::new(123);
        c.add("params", vec![1.0, -2.5, 3.25]);
        c.add("opt", vec![0.0; 10]);
        c.save(&path).unwrap();
        let r = Checkpoint::load(&path).unwrap();
        assert_eq!(c, r);
        assert_eq!(r.get("params").unwrap(), &[1.0, -2.5, 3.25]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_corruption() {
        let dir = std::env::temp_dir().join("cpt_ckpt_test2");
        let path = dir.join("b.ckpt");
        let mut c = Checkpoint::new(1);
        c.add("x", vec![1.0; 64]);
        c.save(&path).unwrap();
        // flip a payload byte
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_and_overwrites_cleanly() {
        let dir = std::env::temp_dir().join("cpt_ckpt_test_atomic");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("d.ckpt");
        let mut c = Checkpoint::new(1);
        c.add("params", vec![1.0; 32]);
        c.save(&path).unwrap();
        // overwriting an existing checkpoint goes through the same
        // tmp+rename path
        let mut c2 = Checkpoint::new(2);
        c2.add("params", vec![2.0; 8]);
        c2.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), c2);
        // no .tmp residue after successful saves
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("cpt_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"NOTACKPT____").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The training loop: drives a `LoadedModel` over a `Dataset` with a
//! precision `Schedule` — the L3 hot path.
//!
//! Per chunk of K optimizer steps:
//!   1. evaluate the CPT schedule -> q_fwd[K] (integer-rounded bit-widths),
//!   2. evaluate the LR schedule  -> lr[K],
//!   3. assemble K minibatches (stacked) + shared inputs,
//!   4. one PJRT call on the train-chunk executable,
//!   5. account BitOps, record history, run periodic eval.
//!
//! Python is never involved; the schedule decisions (the paper's
//! contribution) all happen here.

pub mod checkpoint;
pub mod lr;

pub use lr::LrSchedule;

use std::time::Instant;

use anyhow::{Context, Result};
use xla::Literal;

use crate::data::Dataset;
use crate::metrics::History;
use crate::quant::BitOpsAccountant;
use crate::runtime::{HostTensor, LoadedModel, TrainState};
use crate::schedule::Schedule;
use crate::util::prng::Pcg32;

/// Configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub total_steps: usize,
    /// Backward precision (pinned to q_max per paper §3.1).
    pub q_bwd: f32,
    /// Evaluate every this many steps (0 = only at the end).
    pub eval_every: usize,
    /// PRNG seed for the run (init seed + per-step dropout seeds).
    pub seed: i32,
    /// Log train loss every this many steps into History (1 = all).
    pub log_every: usize,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            total_steps: 200,
            q_bwd: 8.0,
            eval_every: 0,
            seed: 0,
            log_every: 1,
            verbose: false,
        }
    }
}

/// Trainer: owns the run state and produces a History.
pub struct Trainer<'m, 'd> {
    pub model: &'m LoadedModel,
    pub data: &'d mut dyn Dataset,
    pub schedule: Schedule,
    pub lr: LrSchedule,
    pub cfg: TrainConfig,
}

impl<'m, 'd> Trainer<'m, 'd> {
    pub fn new(
        model: &'m LoadedModel,
        data: &'d mut dyn Dataset,
        schedule: Schedule,
        lr: LrSchedule,
        cfg: TrainConfig,
    ) -> Self {
        Trainer { model, data, schedule, lr, cfg }
    }

    /// Run the full training loop, returning the history.
    pub fn run(&mut self) -> Result<History> {
        let t_start = Instant::now();
        let mut state = self.model.init_state(self.cfg.seed)?;
        let mut hist = History::default();
        let mut acc = BitOpsAccountant::new(
            &self.model.spec,
            self.cfg.q_bwd as f64,
            self.data.agg_density(),
        );
        let mut seed_rng = Pcg32::new(self.cfg.seed as u64, 0x5EED);

        let chunk = self.model.spec.chunk;
        let total = self.cfg.total_steps;
        let mut step = 0usize;
        let mut exec_s = 0.0f64;

        while step < total {
            let k = chunk.min(total - step);
            // the chunk executable is fixed at K; use K or fall back to
            // k=1 remainder steps
            let k = if k == chunk { chunk } else { 1 };

            let q_fwd = self.schedule.q_vec(step, k);
            let lr_v: Vec<f32> =
                (step..step + k).map(|t| self.lr.at(t)).collect();
            let seeds: Vec<i32> =
                (0..k).map(|_| seed_rng.next_u32() as i32).collect();

            let (stacked, shared) = self.assemble_inputs(step, k)?;

            let t0 = Instant::now();
            let res = self.model.advance(
                &mut state, k, stacked, shared, &q_fwd, &lr_v, &seeds,
                self.cfg.q_bwd,
            )?;
            exec_s += t0.elapsed().as_secs_f64();

            acc.record_steps(&q_fwd);
            for (i, (&l, &m)) in
                res.losses.iter().zip(res.metrics.iter()).enumerate()
            {
                let t = step + i;
                if t % self.cfg.log_every == 0 {
                    hist.losses.push((t, l));
                    hist.metrics.push((t, m));
                    hist.precisions.push((t, q_fwd[i] as u32));
                }
            }
            // plateau-style LR schedules need feedback
            self.lr.observe_loss(step + k, res.losses[k - 1]);

            step += k;

            if self.cfg.eval_every > 0
                && (step % self.cfg.eval_every == 0 || step >= total)
            {
                let (el, em) = self.evaluate(&state)?;
                hist.evals.push((step, el, em));
                if self.cfg.verbose {
                    eprintln!(
                        "[train {}] step {step}/{total} q={} loss={:.4} eval_loss={el:.4} eval_metric={em:.4}",
                        self.model.spec.name,
                        q_fwd[k - 1],
                        res.losses[k - 1],
                    );
                }
            }
        }

        if self.cfg.eval_every == 0 {
            let (el, em) = self.evaluate(&state)?;
            hist.evals.push((step, el, em));
        }

        hist.gbitops = acc.total().gbitops;
        hist.exec_seconds = exec_s;
        hist.total_seconds = t_start.elapsed().as_secs_f64();
        Ok(hist)
    }

    /// Mean eval loss/metric over the dataset's eval batches.
    pub fn evaluate(&mut self, state: &TrainState) -> Result<(f32, f32)> {
        let n = self.data.eval_batches();
        let mut sl = 0.0f32;
        let mut sm = 0.0f32;
        for i in 0..n {
            let batch = self.data.eval_batch(i)?;
            let lits = to_literals(&batch)?;
            let (l, m) = self.model.evaluate(state, lits)?;
            sl += l;
            sm += m;
        }
        Ok((sl / n as f32, sm / n as f32))
    }

    /// Build (stacked, shared) literals for a k-step chunk at `step`.
    fn assemble_inputs(
        &mut self,
        step: usize,
        k: usize,
    ) -> Result<(Vec<Literal>, Vec<Literal>)> {
        // collect k per-step batches and stack along a new leading axis
        let mut per_input: Vec<Vec<HostTensor>> = Vec::new();
        for i in 0..k {
            let batch = self.data.train_batch(step + i)?;
            if per_input.is_empty() {
                per_input = batch.into_iter().map(|t| vec![t]).collect();
            } else {
                for (slot, t) in per_input.iter_mut().zip(batch) {
                    slot.push(t);
                }
            }
        }
        let mut stacked = Vec::with_capacity(per_input.len());
        for ts in &per_input {
            stacked.push(HostTensor::stack(ts)?.to_literal()?);
        }
        let shared = self
            .data
            .shared_inputs(step)?
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()
            .context("shared inputs")?;
        Ok((stacked, shared))
    }
}

fn to_literals(ts: &[HostTensor]) -> Result<Vec<Literal>> {
    ts.iter().map(|t| t.to_literal()).collect()
}
